"""jit-able step factories: train_step / prefill_step / serve_step.

These close over (cfg, mesh, hyper) so the jitted signature carries only
arrays — the dry-run lowers exactly what production would run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, encode, forward, lm_loss
from repro.models.config import ArchConfig
from repro.optim import AdamWHyper, adamw_update
from repro.optim.schedules import cosine_warmup

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step", "hyper_for"]


def hyper_for(cfg: ArchConfig) -> AdamWHyper:
    # bf16 optimizer states for the 398B config so params+states fit a pod
    # (DESIGN.md §6); fp32 otherwise.
    state_dtype = "bfloat16" if cfg.n_params() > 5e10 else "float32"
    return AdamWHyper(lr=3e-4, state_dtype=state_dtype)


def make_train_step(cfg: ArchConfig, mesh=None, hyper: AdamWHyper | None = None,
                    total_steps: int = 10_000):
    hyper = hyper or hyper_for(cfg)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = lm_loss(p, batch, cfg, mesh=mesh)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = cosine_warmup(step, peak=hyper.lr, warmup=200, total=total_steps)
        new_params, new_opt = adamw_update(params, grads, opt_state, step, hyper, lr=lr)
        gnorm = jnp.sqrt(sum(jnp.vdot(g.astype(jnp.float32), g.astype(jnp.float32))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh=None):
    def prefill_step(params, batch):
        enc_out = None
        if cfg.encdec:
            enc_out = encode(params, batch["frames"], cfg, mesh)
        logits, _ = forward(params, batch["tokens"], cfg, mesh=mesh,
                            enc_out=enc_out, patch_embeds=batch.get("patch_embeds"))
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh=None):
    def serve_step(params, cache, token, pos):
        return decode_step(params, cache, token, pos, cfg, mesh=mesh)

    return serve_step
