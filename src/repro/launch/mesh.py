"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before the first device query.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_elastic_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run under "
            "launch/dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_elastic_mesh(*, model_parallel: int = 16):
    """Best-effort mesh from whatever devices exist right now.

    Used by the trainer's restart path: after losing a pod (or shrinking to
    1 CPU device in tests) training resumes on ``n // model_parallel × mp``
    devices; checkpoint restore resharding handles the layout change.
    """
    import numpy as np

    devices = jax.devices()
    mp = model_parallel
    while mp > 1 and len(devices) % mp:
        mp //= 2
    dp = len(devices) // mp
    return jax.sharding.Mesh(np.asarray(devices[: dp * mp]).reshape(dp, mp),
                             ("data", "model"))
