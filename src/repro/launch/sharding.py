"""Sharding rules: logical activation axes + path-based parameter layouts.

Megatron-style TP on the ``model`` axis:
  column-parallel:  wq/wk/wv, wi_gate/wi_up, w_x/w_z (output dim sharded)
  row-parallel:     wo, w_out (input dim sharded → XLA inserts the
                    all-reduce the TP pattern requires)
  vocab-parallel:   embed / head (+ sharded CE via logits constraint)
  expert-parallel:  experts' leading E dim on ``model``
Batch-like activation dims shard over ("pod","data").  Every rule checks
divisibility and falls back to replication (e.g. smollm's 15 heads, kv=5).

ZeRO-1: optimizer states take the param layout plus the first still-
unsharded, divisible dim over the batch axes.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["axis_rules", "param_sharding", "opt_sharding", "batch_sharding",
           "cache_sharding", "install"]


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def axis_rules(mesh: Mesh, profile: str = "tp", vp_embed: bool = False) -> dict:
    """Logical-axis rules consumed by models.layers.shard().

    profile='tp'   — Megatron TP on the model axis (baseline).
    profile='fsdp' — fully-sharded data parallel over ALL axes: batch dims
        shard over (pod, data, model); no head/ffn activation sharding (the
        model axis carries parameter shards, gathered per use by SPMD).
        §Perf lever for small models where TP's per-layer activation
        all-reduces dwarf an FSDP parameter all-gather.
    vp_embed       — Megatron vocab-parallel embedding lookup (shard_map
        local-range gather + psum) instead of gathering the vocab-sharded
        table.
    """
    if profile == "fsdp":
        all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        return {
            "mesh": mesh,
            "profile": profile,
            "pad_to": 1,
            "vp_embed": False,
            "rules": {"batch": all_axes, "seq": all_axes},
        }
    return {
        "mesh": mesh,
        "profile": profile,
        "pad_to": mesh.shape.get("model", 1),
        "vp_embed": vp_embed,
        "rules": {
            "batch": batch_axes(mesh),
            "heads": "model",
            "kv_heads": "model",
            "ffn": "model",
            "vocab": "model",
            "expert": "model",
            "seq": batch_axes(mesh),  # context parallelism (long_500k caches)
        },
    }


def install(mesh: Mesh | None, profile: str = "tp", vp_embed: bool = False):
    """Install activation-sharding rules process-wide (None to clear)."""
    from repro.models.layers import set_axis_rules

    set_axis_rules(axis_rules(mesh, profile, vp_embed) if mesh is not None else None)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

_COL = ("wq", "wk", "wv", "wi_gate", "wi_up", "w_x", "w_z", "w_dt")
_ROW = ("wo", "w_out")
_VOCAB = ("embed", "head")
_REPL = ("norm", "router", "bias", "A_log", "D", "dt_bias", "w_bc", "conv_bc",
         "w_dkv", "w_krope", "kv_norm")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _spec_for(path: str, shape: tuple[int, ...], model: int) -> P:
    """PartitionSpec for one param leaf (model-axis TP only)."""
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]

    def ok(dim):  # shardable?
        return shape[dim] % model == 0

    spec: list = [None] * nd
    if "experts" in path:
        # stacked (scan?, E, d, ff): shard E — first dim of the trailing 3
        e_dim = nd - 3
        if shape[e_dim] % model == 0:
            spec[e_dim] = "model"
        return P(*spec)
    if leaf in ("w_uk", "w_uv"):  # (scan?, lora, H, hd) — shard heads
        h_dim = nd - 2
        if ok(h_dim):
            spec[h_dim] = "model"
        return P(*spec)
    if leaf == "conv_x":  # (scan?, channels, width)
        if ok(nd - 2):
            spec[nd - 2] = "model"
        return P(*spec)
    if any(k in leaf for k in _REPL):
        return P(*spec)
    if leaf in _VOCAB and nd >= 2:
        if ok(nd - 2):
            spec[nd - 2] = "model"
        return P(*spec)
    if leaf in _COL and nd >= 2:
        if ok(nd - 1):
            spec[nd - 1] = "model"
        return P(*spec)
    if leaf in _ROW and nd >= 2:
        if ok(nd - 2):
            spec[nd - 2] = "model"
        return P(*spec)
    return P(*spec)


def _spec_fsdp(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Fully-sharded layout: prefer one dim divisible by ALL devices; else
    split data/model across two dims; else single-axis; else replicate."""
    axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    spec: list = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % total == 0 and shape[i] >= total:
            spec[i] = tuple(axes)
            return P(*spec)
    # two-dim split: model on one dim, (pod,data) on another
    model = mesh.shape.get("model", 1)
    dp = total // model
    m_dim = next((i for i in order if shape[i] % model == 0 and shape[i] >= model), None)
    if m_dim is not None:
        spec[m_dim] = "model"
    d_dim = next((i for i in order if i != m_dim and shape[i] % dp == 0 and shape[i] >= dp), None)
    if d_dim is not None and dp > 1:
        dax = tuple(a for a in axes if a != "model")
        spec[d_dim] = dax if len(dax) > 1 else dax[0]
    return P(*spec)


def param_sharding(param_shapes, mesh: Mesh, profile: str = "tp"):
    model = mesh.shape.get("model", 1)

    def one(path, leaf):
        if profile == "fsdp":
            return NamedSharding(mesh, _spec_fsdp(leaf.shape, mesh))
        return NamedSharding(mesh, _spec_for(_path_str(path), leaf.shape, model))

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def opt_sharding(param_shapes, mesh: Mesh, profile: str = "tp"):
    """ZeRO-1: param layout + first free divisible dim over the batch axes."""
    model = mesh.shape.get("model", 1)
    baxes = batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]

    def one(path, leaf):
        if profile == "fsdp":
            return NamedSharding(mesh, _spec_fsdp(leaf.shape, mesh))
        spec = list(_spec_for(_path_str(path), leaf.shape, model))
        if baxes and dp > 1:
            for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
                if s is None and dim % dp == 0 and dim >= dp:
                    spec[i] = baxes if len(baxes) > 1 else baxes[0]
                    break
        return NamedSharding(mesh, P(*spec))

    shard_one_tree = jax.tree_util.tree_map_with_path(one, param_shapes)
    return {"m": shard_one_tree, "v": shard_one_tree}


# ---------------------------------------------------------------------------
# batches and caches
# ---------------------------------------------------------------------------


def batch_sharding(batch_shapes, mesh: Mesh, profile: str = "tp"):
    if profile == "fsdp":
        baxes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    else:
        baxes = batch_axes(mesh)
    def one(leaf):
        spec = [None] * len(leaf.shape)
        # largest axis prefix that divides the batch dim
        cand = list(baxes)
        while cand:
            dp = 1
            for a in cand:
                dp *= mesh.shape[a]
            if dp > 1 and leaf.shape and leaf.shape[0] % dp == 0:
                spec[0] = tuple(cand) if len(cand) > 1 else cand[0]
                break
            cand.pop()  # drop the innermost axis and retry
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shapes)


def cache_sharding(cache_shapes, mesh: Mesh, *, seq_shard: bool = False):
    """Decode caches: shard batch dim; kv-head/SSM-head dims over model when
    divisible; optionally the sequence dim over the batch axes (long_500k,
    batch=1 context parallelism)."""
    model = mesh.shape.get("model", 1)
    baxes = batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    # (batch dim, seq dim, model dim) anchored from the END of each leaf's
    # shape — stacked caches carry leading scan dims, trailing dims are fixed
    _ANCHORS = {
        "k": (-4, -3, -2), "v": (-4, -3, -2),          # (..., B, S, K, hd)
        "ckv": (-3, -2, None), "k_rope": (-3, -2, None),  # (..., B, S, lora)
        "state": (-4, None, -3),                        # (..., B, H, P, N)
        "conv_x": (-3, None, -1), "conv_bc": (-3, None, None),  # (..., B, w, C)
    }

    def one(path, leaf):
        pstr = _path_str(path)
        leafname = pstr.rsplit("/", 1)[-1]
        shape = leaf.shape
        spec: list = [None] * len(shape)
        anchors = _ANCHORS.get(leafname)
        if anchors is None:
            return NamedSharding(mesh, P(*spec))
        b_dim, s_dim, m_dim = anchors
        if dp > 1 and shape[b_dim] % dp == 0:
            spec[b_dim] = b
        elif seq_shard and s_dim is not None and dp > 1 and shape[s_dim] % dp == 0:
            spec[s_dim] = b  # context parallelism when batch can't shard
        if m_dim is not None and model > 1 and shape[m_dim] % model == 0:
            spec[m_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
