import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script
  1. builds the production mesh (16×16 single-pod, 2×16×16 multi-pod),
  2. lowers the real step function (train/prefill/serve) against
     ShapeDtypeStruct inputs — no allocation,
  3. compiles it (SPMD partitioning for 256/512 devices must succeed),
  4. records memory_analysis(), cost_analysis() and the collective-byte
     census parsed from the compiled HLO into a JSON artifact consumed by
     launch/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out runs/dryrun
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _lower_cell(cfg, shape_name: str, mesh, profile: str = "tp"):
    """Lower the cell's step function on ``mesh``; returns the Lowered."""
    import jax.numpy as jnp

    from repro.launch import sharding as sh
    from repro.launch.specs import input_specs
    from repro.launch.steps import hyper_for, make_prefill_step, make_serve_step, make_train_step
    from repro.models import init_params
    from repro.optim import adamw_init

    spec = input_specs(cfg, shape_name)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = sh.param_sharding(params, mesh, profile)

    if spec["kind"] == "train":
        hyper = hyper_for(cfg)
        opt = jax.eval_shape(lambda: adamw_init(params, hyper))
        o_sh = sh.opt_sharding(params, mesh, profile)
        b_sh = sh.batch_sharding(spec["batch"], mesh, profile)
        step = make_train_step(cfg, mesh, hyper)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, None),
                     out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        with mesh:
            return fn.lower(params, opt, spec["batch"], jnp.int32(0))
    if spec["kind"] == "prefill":
        b_sh = sh.batch_sharding(spec["batch"], mesh)
        fn = jax.jit(make_prefill_step(cfg, mesh), in_shardings=(p_sh, b_sh))
        with mesh:
            return fn.lower(params, spec["batch"])
    c_sh = sh.cache_sharding(spec["cache"], mesh, seq_shard=spec["seq_shard"])
    t_sh = sh.batch_sharding(spec["token"], mesh)
    fn = jax.jit(make_serve_step(cfg, mesh),
                 in_shardings=(p_sh, c_sh, t_sh, None),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    with mesh:
        return fn.lower(params, spec["cache"], spec["token"], jnp.int32(17))


def _truncated_cfg(cfg, k: int):
    """Config with k scan units (prologue kept) for cost extrapolation."""
    import dataclasses

    from repro.models import stack_pattern

    prologue, pattern, n_scan = stack_pattern(cfg)
    changes = {"n_layers": len(prologue) + len(pattern) * k}
    if cfg.encdec:
        changes["n_enc_layers"] = k
    return dataclasses.replace(cfg, **changes)


def _analysis_counts(cfg, shape_name: str, mesh, profile: str = "tp") -> tuple[dict, dict]:
    """Two-point scan-body extrapolation of flops/bytes/collectives.

    XLA's cost_analysis counts while-loop bodies once, so the production
    (rolled-scan) artifact undercounts per-step work by ~n_layers.  Lowering
    k=1 and k=2 scan units with scans unrolled gives body = f(2) − f(1)
    exactly; total = f(1) − body + n_scan·body.
    """
    from repro.launch.roofline import collective_census
    from repro.models import stack_pattern
    from repro.models.model import set_scan_unroll

    _, _, n_scan = stack_pattern(cfg)
    costs, censuses = [], []
    set_scan_unroll(True)
    try:
        for k in (1, 2):
            lowered = _lower_cell(_truncated_cfg(cfg, k), shape_name, mesh, profile)
            compiled = lowered.compile()
            costs.append(compiled.cost_analysis())
            censuses.append(collective_census(compiled.as_text()))
    finally:
        set_scan_unroll(False)

    def extrap(a, b):
        body = b - a
        return max(a - body, 0.0) + n_scan * body

    cost = {}
    for key in ("flops", "bytes accessed", "transcendentals"):
        if key in costs[0]:
            cost[key] = extrap(float(costs[0].get(key, 0)), float(costs[1].get(key, 0)))
    census: dict = {}
    kinds = set(censuses[0]) | set(censuses[1])
    for kind in kinds:
        z = {"count": 0, "operand_bytes": 0, "result_bytes": 0}
        a = censuses[0].get(kind, z)
        b = censuses[1].get(kind, z)
        census[kind] = {
            f: int(round(extrap(float(a[f]), float(b[f])))) for f in z
        }
    return cost, census


def _apply_opts(opts: tuple[str, ...]):
    import jax.numpy as jnp

    from repro.models.attention import set_flash
    from repro.models.layers import set_reduce_dtype

    set_reduce_dtype(jnp.bfloat16 if "bf16_reduce" in opts else jnp.float32)
    set_flash("flash" in opts)
    profile = "fsdp" if "fsdp" in opts else "tp"
    vp_embed = "vp_embed" in opts
    return profile, vp_embed


def _build_cell(arch: str, shape_name: str, multi_pod: bool,
                opts: tuple[str, ...] = ()):
    from repro.configs import get_config
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, skip_reason
    from repro.launch.steps import hyper_for, make_prefill_step, make_serve_step, make_train_step
    from repro.models import init_params
    from repro.optim import adamw_init

    cfg = get_config(arch)
    skip = skip_reason(cfg, shape_name)
    if skip:
        return {"status": "skip", "reason": skip}

    profile, vp_embed = _apply_opts(opts)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sh.install(mesh, profile=profile, vp_embed=vp_embed)
    try:
        spec = input_specs(cfg, shape_name)
        params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        p_sh = sh.param_sharding(params, mesh, profile)

        if spec["kind"] == "train":
            hyper = hyper_for(cfg)
            opt = jax.eval_shape(lambda: adamw_init(params, hyper))
            o_sh = sh.opt_sharding(params, mesh, profile)
            b_sh = sh.batch_sharding(spec["batch"], mesh, profile)
            step = make_train_step(cfg, mesh, hyper)
            fn = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            with mesh:
                lowered = fn.lower(params, opt, spec["batch"], jnp.int32(0))
        elif spec["kind"] == "prefill":
            b_sh = sh.batch_sharding(spec["batch"], mesh)
            step = make_prefill_step(cfg, mesh)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            with mesh:
                lowered = fn.lower(params, spec["batch"])
        else:  # decode
            c_sh = sh.cache_sharding(spec["cache"], mesh, seq_shard=spec["seq_shard"])
            t_sh = sh.batch_sharding(spec["token"], mesh)
            step = make_serve_step(cfg, mesh)
            fn = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, t_sh, None),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            with mesh:
                lowered = fn.lower(params, spec["cache"], spec["token"], jnp.int32(17))
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

        mem = compiled.memory_analysis()
        cost_raw = compiled.cost_analysis()
        from repro.launch.roofline import collective_census

        hlo = compiled.as_text()
        coll_raw = collective_census(hlo)
        # honest per-step counts: scan bodies extrapolated (see helper)
        cost, coll = _analysis_counts(cfg, shape_name, mesh, profile)
        n_dev = mesh.devices.size
        result = {
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "n_devices": int(n_dev),
            "compile_s": round(compile_s, 1),
            "cost": cost,
            "cost_rolled_raw": {k: cost_raw.get(k) for k in
                                ("flops", "bytes accessed") if k in cost_raw},
            "memory": _mem_dict(mem),
            "collectives": coll,
            "collectives_rolled_raw": coll_raw,
            "n_params": get_n_params(arch),
            "opts": list(opts),
        }
        return result, hlo
    finally:
        sh.install(None)


def get_n_params(arch):
    from repro.configs import get_config

    c = get_config(arch)
    return {"total": c.n_params(), "active": c.n_active_params()}


def _mem_dict(mem):
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch, shape_name, mesh_kind, outdir: pathlib.Path, save_hlo=True,
             opts: tuple[str, ...] = ()):
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    if opts:
        tag += "__" + "-".join(opts)
    t0 = time.time()
    try:
        res = _build_cell(arch, shape_name, mesh_kind == "multi", opts)
        if isinstance(res, tuple):
            result, hlo = res
            if save_hlo:
                (outdir / f"{tag}.hlo.txt").write_text(hlo)
        else:
            result = res
    except Exception as e:
        result = {"status": "error", "arch": arch, "shape": shape_name,
                  "mesh": mesh_kind, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    result["wall_s"] = round(time.time() - t0, 1)
    (outdir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    status = result["status"]
    extra = result.get("reason", result.get("error", ""))[:120]
    print(f"[dryrun] {tag:60s} {status:6s} {result['wall_s']:7.1f}s {extra}",
          flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: bf16_reduce,fsdp,vp_embed (§Perf knobs)")
    args = ap.parse_args(argv)
    opts = tuple(o for o in args.opt.split(",") if o)

    from repro.configs import ARCH_NAMES
    from repro.launch.specs import SHAPES

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape_name}__{mesh_kind}"
                if opts:
                    tag += "__" + "-".join(opts)
                done = outdir / f"{tag}.json"
                if done.exists():
                    prev = json.loads(done.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[dryrun] {tag:60s} cached", flush=True)
                        continue
                r = run_cell(arch, shape_name, mesh_kind, outdir,
                             save_hlo=not args.no_hlo, opts=opts)
                failures += r["status"] == "error"
    print(f"[dryrun] done, {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
