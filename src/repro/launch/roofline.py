"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Hardware model (assignment): TPU v5e-class chip — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.  From each cell's compiled artifact:

  compute_s    = HLO flops (per-device — the SPMD module is the per-device
                 program) / 197e12
  memory_s     = HLO 'bytes accessed' / 819e9  (upper bound: XLA's counter
                 includes VMEM-resident reuse)
  collective_s = Σ operand bytes of collectives × ring-factor / 50e9
                 (ring factor 2 for all-reduce = reduce-scatter+all-gather,
                 1 otherwise; single-link conservative model)

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (forward/
decode); the MODEL/HLO ratio flags remat & dispatch waste.
"""

from __future__ import annotations

import json
import pathlib
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(r"%([\w\.\-]+) = ((?:\([^=]*?\))|(?:[\w\[\]{},: ]+?)) ([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_census(hlo_text: str) -> dict:
    """Per-collective-type operand/result byte totals from HLO text."""
    sizes: dict[str, int] = {}
    ops: list[tuple[str, str, str]] = []  # (kind, result_type, args_str)
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, rtype, op = m.group(1), m.group(2), m.group(3)
        sizes[name] = _shape_bytes(rtype)
        kind = op.removesuffix("-start").removesuffix("-done")
        if kind in _COLLECTIVES and not op.endswith("-done"):
            args = line[m.end() - 1:]
            ops.append((kind, rtype, args, name))

    census: dict[str, dict] = {
        k: {"count": 0, "operand_bytes": 0, "result_bytes": 0} for k in _COLLECTIVES
    }
    for kind, rtype, args, name in ops:
        census[kind]["count"] += 1
        census[kind]["result_bytes"] += _shape_bytes(rtype)
        operand_names = re.findall(r"%([\w\.\-]+)", args)
        ob = sum(sizes.get(n, 0) for n in operand_names if n != name)
        if ob == 0:  # fall back to inline operand types
            ob = _shape_bytes(args)
        census[kind]["operand_bytes"] += ob
    census = {k: v for k, v in census.items() if v["count"]}
    return census


def analytic_memory_floor(cell: dict) -> float:
    """Lower bound on per-device HBM traffic (bytes) for one step.

    Train:  params read (fwd+bwd, bf16) + grad write + Adam m/v read+write
            + param write + remat-boundary activations (save+reload).
    Decode: active params read once + KV/state cache read+write.
    Prefill: params read + activations written once.
    XLA's 'bytes accessed' is the matching upper bound (no VMEM-reuse
    credit); the truth lives between the two.
    """
    from repro.launch.specs import SHAPES

    n = cell["n_params"]["total"] / cell["n_devices"]
    n_act = cell["n_params"]["active"] / cell["n_devices"]
    sp = SHAPES[cell["shape"]]
    if sp.kind == "train":
        opt_state_bytes = 4  # fp32 m/v (bf16 for jamba; keep conservative)
        traffic = n * 2 * 2 + n * 2 + n * 4 * opt_state_bytes + n * 2
        # one (B,S,d)-ish boundary activation per layer, saved + reloaded
        traffic += 2 * cell.get("act_boundary_bytes", 0)
        return traffic
    if sp.kind == "prefill":
        return n * 2 * 2
    # decode: every active weight + the whole cache once (+ cache write)
    cache_bytes = cell.get("memory", {}).get("argument_size_in_bytes", 0)
    return n_act * 2 + cache_bytes


def roofline_terms(cell: dict, *, tokens: int | None = None) -> dict:
    """Three roofline terms (seconds) + bottleneck for one dry-run cell."""
    cost = cell.get("cost", {})
    flops = float(cost.get("flops") or 0.0)
    bytes_acc = float(cost.get("bytes accessed") or 0.0)
    coll = cell.get("collectives", {})
    coll_bytes = 0.0
    for kind, v in coll.items():
        if kind == "all-reduce":
            coll_bytes += 2.0 * v["operand_bytes"]  # ring RS + AG
        elif kind == "all-gather":
            coll_bytes += v["result_bytes"]  # each device receives the gather
        else:
            coll_bytes += v["operand_bytes"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    out = dict(terms, bottleneck=dom.removesuffix("_s"))
    out["memory_floor_s"] = analytic_memory_floor(cell) / HBM_BW
    # bottleneck under the optimistic memory model (perfect VMEM reuse)
    lb_terms = dict(terms, memory_s=out["memory_floor_s"])
    out["bottleneck_floor"] = max(lb_terms, key=lb_terms.get).removesuffix("_s")
    if tokens is not None and cell.get("n_params"):
        n_active = cell["n_params"]["active"]
        mult = 6 if cell["shape"].startswith("train") else 2
        model_flops = mult * n_active * tokens / cell["n_devices"]
        out["model_flops"] = model_flops
        out["hlo_flops"] = flops
        out["model_over_hlo"] = model_flops / flops if flops else 0.0
        # roofline fraction: useful model flops per device over peak,
        # evaluated at the step's bound (= max of the three terms)
        bound = max(terms.values())
        out["roofline_fraction"] = (model_flops / PEAK_FLOPS) / bound if bound else 0.0
        bound_f = max(lb_terms.values())
        out["roofline_fraction_floor"] = (
            (model_flops / PEAK_FLOPS) / bound_f if bound_f else 0.0
        )
    return out


def cell_tokens(cell: dict) -> int:
    from repro.launch.specs import SHAPES

    sp = SHAPES[cell["shape"]]
    if sp.kind == "decode":
        return sp.global_batch  # one token per sequence per step
    return sp.global_batch * sp.seq_len


def load_cells(outdir: str | pathlib.Path) -> list[dict]:
    cells = []
    for f in sorted(pathlib.Path(outdir).glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def summarize(outdir: str | pathlib.Path, mesh: str = "single") -> str:
    """Markdown roofline table for EXPERIMENTS.md §Roofline."""
    rows = []
    header = (
        "| arch | shape | compute_s | mem_ub_s | mem_floor_s | coll_s | bound(ub/floor) | "
        "MODEL/HLO | frac(ub) | frac(floor) |\n"
        "|---|---|---|---|---|---|---|---|---|---|"
    )
    for cell in load_cells(outdir):
        if cell.get("status") != "ok" or cell.get("mesh") != mesh:
            continue
        t = roofline_terms(cell, tokens=cell_tokens(cell))
        rows.append(
            f"| {cell['arch']} | {cell['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['memory_floor_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['bottleneck']}/{t['bottleneck_floor']} | "
            f"{t['model_over_hlo']:.2f} | {t['roofline_fraction']:.3f} | "
            f"{t['roofline_fraction_floor']:.3f} |"
        )
    return header + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import sys

    outdir = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun"
    print(summarize(outdir))
