"""Assigned input shapes and ShapeDtypeStruct stand-ins per (arch × shape).

The four LM shapes (assignment):
  train_4k     seq 4096,    global_batch 256   → train_step
  prefill_32k  seq 32768,   global_batch 32    → prefill (forward logits)
  decode_32k   seq 32768,   global_batch 128   → serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1     → serve_step, sub-quadratic
                                                  archs only (DESIGN.md §4)

Multimodal stubs: whisper gets encoder frame embeddings at seq/2 frames for
train/prefill (decode uses the native 1500-frame cross cache); llava gets
576 patch embeddings spliced ahead of the text tokens (text len shrinks so
the total sequence matches the assigned seq).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import init_cache
from repro.models.config import ArchConfig

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ArchConfig, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "SKIP(full-attn): long_500k requires sub-quadratic attention"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns {kind, batch | (cache, token, pos)}; no device allocation.
    """
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    out: dict[str, Any] = {"kind": sp.kind, "shape": sp}

    if sp.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        text_len = S - (cfg.n_patches or 0)
        batch["tokens"] = _sds((B, text_len), jnp.int32)
        if cfg.encdec:
            batch["frames"] = _sds((B, S // 2, cfg.d_model), cfg.adtype)
        if cfg.n_patches:
            batch["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), cfg.adtype)
        out["batch"] = batch
        return out

    # decode: 1 new token against an S-long cache
    enc_frames = cfg.enc_frames if cfg.encdec else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, enc_frames=enc_frames)
    )
    out["cache"] = cache
    out["token"] = _sds((B, 1), jnp.int32)
    out["pos"] = _sds((), jnp.int32)
    out["seq_shard"] = sp.name == "long_500k"
    return out
