"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 8 --seq 128 --slope

Full-size configs require the production mesh (run under the dry-run's
XLA_FLAGS or on real hardware); ``--reduced`` trains the same-family small
config on whatever devices exist (the examples use this).
"""

from __future__ import annotations

import argparse

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--slope", action="store_true",
                    help="enable SLOPE-path regularization of the embedding")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.launch.mesh import make_elastic_mesh
    from repro.launch import sharding as sh
    from repro.models.slope_reg import SlopeRegConfig
    from repro.optim import AdamWHyper
    from repro.train import TrainConfig, Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh = None
    if len(jax.devices()) > 1:
        mesh = make_elastic_mesh(model_parallel=min(16, len(jax.devices())))
        sh.install(mesh)
        print(f"[train] mesh {dict(mesh.shape)}")

    slope = None
    if args.slope:
        slope = SlopeRegConfig(total_steps=args.steps, screen_every=max(args.steps // 10, 1))

    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, slope=slope)
    trainer = Trainer(cfg, tc, mesh=mesh, hyper=AdamWHyper(lr=args.lr),
                      global_batch=args.batch, seq_len=args.seq)
    out = trainer.run()
    print(f"[train] done at step {out['final_step']}; "
          f"final loss {out['metrics'][-1]['loss']:.4f}; "
          f"{len(out['stragglers'])} straggler events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
