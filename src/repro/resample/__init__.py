"""``repro.resample`` — materialize-free bootstrap / permutation /
subsample replicates for SLOPE paths.

A :class:`ResamplePlan` turns one seed into B replicate problems
represented as per-member ``(B, n)`` row weights against ONE shared
``(n, p)`` X; the weight-fused replicate engines solve all B paths without
ever materializing a ``(B, n, p)`` batch (O(n·p + B·n) memory — ROADMAP
item 4).  On top ride the paper-adjacent inference workloads: stability
selection with per-predictor selection frequencies, Westfall–Young
max-|gradient| permutation p-values, and bagged SLOPE aggregation.
"""

from .metrics import RESAMPLE_METRICS, resample_stats
from .plans import RESAMPLE_KINDS, ResamplePlan
from .select import (
    BaggedResult,
    PermutationResult,
    ReplicateResult,
    StabilityResult,
    bagged_slope,
    fit_replicates,
    permutation_pvalues,
    selection_frequencies,
    stability_selection,
)

__all__ = [
    "ResamplePlan",
    "RESAMPLE_KINDS",
    "RESAMPLE_METRICS",
    "resample_stats",
    "ReplicateResult",
    "StabilityResult",
    "PermutationResult",
    "BaggedResult",
    "fit_replicates",
    "selection_frequencies",
    "stability_selection",
    "permutation_pvalues",
    "bagged_slope",
]
