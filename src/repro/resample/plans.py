"""Replicate representation layer: one seed → B resampled problems, no
materialized ``(B, n, p)`` X.

A :class:`ResamplePlan` describes a whole resampling experiment with four
scalars — kind, replicate count, seed, subsample fraction — and expands it
deterministically into per-member *row weights* (and, for permutations,
per-member response orderings) via per-member jax PRNG key derivation:
``fold_in(PRNGKey(seed), b)`` gives replicate b its own key, so member b
of a B=256 plan draws exactly the same replicate as member b of a B=8 plan
with the same seed (prefix stability — the property that makes incremental
B sweeps and served chunking reproducible).

The weight representation is what makes replicates materialize-free:

* ``bootstrap``  — w_b ∈ ℕⁿ is the multinomial count vector of n draws
  with replacement; f_{w_b} is *exactly* the loss of the row-duplicated
  bootstrap sample (``Family.weighted_value``), so the engines solve B
  bootstrap problems against ONE shared ``(n, p)`` X.
* ``subsample``  — w_b ∈ {0,1}ⁿ keeps ⌈fraction·n⌉ rows (complementary
  -pairs-style subsampling for stability selection).
* ``permutation`` — w_b ≡ 1 and the *response* is permuted per member
  (:meth:`permuted_targets`); X never moves, which is what the
  max-|gradient| null calibration in :mod:`repro.resample.select` exploits.

``replicate_indices`` derives the equivalent row-index arrays *from the
same generated draws*, so the materialized row-duplication reference used
by the tests and benchmarks agrees with the weighted path by construction.

Memory: a plan occupies O(B·n) (the weights) next to the O(n·p) shared X —
the ROADMAP item-4 budget — versus O(B·n·p) for materialized replicates.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["ResamplePlan", "RESAMPLE_KINDS"]

RESAMPLE_KINDS = ("bootstrap", "permutation", "subsample")


@dataclasses.dataclass(frozen=True, eq=False)
class ResamplePlan:
    """Declarative description of a B-replicate resampling experiment.

    ``kind`` ∈ ``{"bootstrap", "permutation", "subsample"}``;
    ``n_replicates`` is B; ``seed`` feeds one ``jax.random.PRNGKey`` whose
    B-way split generates every member; ``fraction`` is the subsample
    keep-fraction (ignored by the other kinds).
    """

    kind: str = "bootstrap"
    n_replicates: int = 100
    seed: int = 0
    fraction: float = 0.5

    def __post_init__(self):
        if self.kind not in RESAMPLE_KINDS:
            raise ValueError(
                f"unknown resample kind {self.kind!r}; choose from "
                f"{RESAMPLE_KINDS}")
        if isinstance(self.n_replicates, bool) or not isinstance(
                self.n_replicates, int) or self.n_replicates < 1:
            raise ValueError(
                f"n_replicates must be a positive int, got "
                f"{self.n_replicates!r}")
        if not 0.0 < float(self.fraction) <= 1.0:
            raise ValueError(
                f"fraction must be in (0, 1], got {self.fraction!r}")

    # -- deterministic generation --------------------------------------------

    def keys(self) -> jax.Array:
        """The (B, 2) per-replicate key array.

        ``fold_in(PRNGKey(seed), b)`` rather than ``split(key, B)``: a
        member's key depends only on (seed, b), never on B, which is what
        makes the prefix-stability property above true.
        """
        base = jax.random.PRNGKey(self.seed)
        return jax.vmap(lambda b: jax.random.fold_in(base, b))(
            jnp.arange(self.n_replicates))

    def _subsample_count(self, n: int) -> int:
        return max(1, int(round(float(self.fraction) * n)))

    def row_weights(self, n: int, dtype=jnp.float64) -> jax.Array:
        """Per-member row weights ``(B, n)`` — counts, 0/1 masks or ones.

        This is the array the replicate engines thread through
        ``Family.loss_and_gradient``; it is the *only* per-member state of
        O(n) size the fused execution needs.
        """
        keys = self.keys()
        if self.kind == "bootstrap":
            def one(key):
                draws = jax.random.randint(key, (n,), 0, n)
                return jnp.zeros((n,), dtype).at[draws].add(
                    jnp.ones((), dtype))
        elif self.kind == "subsample":
            k = self._subsample_count(n)

            def one(key):
                perm = jax.random.permutation(key, n)
                return jnp.zeros((n,), dtype).at[perm[:k]].set(
                    jnp.ones((), dtype))
        else:  # permutation: the *response* moves, every row keeps weight 1
            def one(key):
                return jnp.ones((n,), dtype)
        return jax.vmap(one)(keys)

    def permutations(self, n: int) -> jax.Array:
        """Per-member row orderings ``(B, n)`` int32 (permutation kind)."""
        if self.kind != "permutation":
            raise ValueError(
                f"permutations are only defined for kind='permutation' "
                f"plans, got {self.kind!r}")
        return jax.vmap(lambda key: jax.random.permutation(key, n))(
            self.keys())

    def permuted_targets(self, y) -> jax.Array:
        """The ``(B, n[, ...])`` stack of per-member permuted responses."""
        y = jnp.asarray(y)
        perms = self.permutations(y.shape[0])
        return jax.vmap(lambda idx: jnp.take(y, idx, axis=0))(perms)

    # -- materialized reference ----------------------------------------------

    def replicate_indices(self, n: int) -> list[np.ndarray]:
        """Host-side row-index arrays equivalent to each member.

        Derived from the *same* device draws as :meth:`row_weights` /
        :meth:`permutations`, so ``X[idx], y[idx]`` is the materialized
        problem whose loss the weighted path reproduces exactly — the
        reference the property tests and the bench baseline fit against.
        """
        if self.kind == "permutation":
            return [np.asarray(p) for p in self.permutations(n)]
        w = np.asarray(self.row_weights(n))
        if self.kind == "bootstrap":
            return [np.repeat(np.arange(n), w[b].astype(np.int64))
                    for b in range(self.n_replicates)]
        return [np.flatnonzero(w[b]) for b in range(self.n_replicates)]


def _register(cls, leaf_fields: tuple[str, ...]):
    # same pytree idiom as repro.api.specs._register (kept local so the
    # resample package never imports the api/serve layers — the services
    # import *us* for the metrics read-through)
    aux_fields = tuple(f.name for f in dataclasses.fields(cls)
                       if f.name not in leaf_fields)

    def flatten(obj):
        return (tuple(getattr(obj, f) for f in leaf_fields),
                tuple(getattr(obj, f) for f in aux_fields))

    def unflatten(aux, children):
        kw = dict(zip(leaf_fields, children))
        kw.update(zip(aux_fields, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


# fully static: a plan is four scalars; the arrays it *generates* are
# recomputed on demand, never carried as leaves
_register(ResamplePlan, ())
