"""Resampling workload drivers: stability selection, permutation
inference and bagging over weight-fused SLOPE paths.

Every driver here fits B replicates against ONE shared ``(n, p)`` X via
the replicate engines (:func:`repro.core.engine.replicate_path_engine` /
``replicate_compact_path_engine``) — the per-member state is the
``(B, n)`` row-weight matrix a :class:`~repro.resample.plans.ResamplePlan`
generates, never a ``(B, n, p)`` materialized batch.

* :func:`stability_selection` — per-predictor selection frequencies over
  bootstrap/subsample replicates at every path point, plus a
  frequency-threshold selector (Meinshausen–Bühlmann-style; the σ grid is
  shared across replicates so frequencies are comparable per grid point).
* :func:`permutation_pvalues` — Westfall–Young max-|gradient| null
  calibration for the SLOPE path entry statistic: under permuted y the
  strongest null predictor score ``T_b = max_j |∇f(0)_j|`` calibrates
  family-wise p-values ``p_j = (1 + #{b : T_b ≥ |g_j|}) / (B + 1)``.
  This is exactly the statistic the strong screening rule thresholds
  (c = |∇f(β)| against λ), so the null draws reuse the engines' gradient
  convention verbatim.
* :func:`bagged_slope` — bootstrap-aggregated coefficients (mean ± sd over
  replicates, per path point).

All drivers publish telemetry to the shared ``ns=resample``
:class:`~repro.obs.MetricsRegistry` (``repro.resample.metrics``): replicate
gauge, selection-frequency histogram, null-calibration draw counter.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from ..core.engine import (
    CompactStats,
    EnginePath,
    null_gradient,
    null_sigma_grid,
    replicate_compact_path_engine,
    replicate_path_engine,
)
from ..core.losses import Family, ols
from ..core.solver import (
    DEFAULT_KKT_TOL,
    DEFAULT_MAX_REFITS,
    DEFAULT_PATH_MAX_ITER,
    DEFAULT_PATH_TOL,
)
from .metrics import RESAMPLE_METRICS
from .plans import ResamplePlan

__all__ = [
    "ReplicateResult",
    "StabilityResult",
    "PermutationResult",
    "BaggedResult",
    "fit_replicates",
    "selection_frequencies",
    "stability_selection",
    "permutation_pvalues",
    "bagged_slope",
]


@dataclasses.dataclass(frozen=True)
class ReplicateResult:
    """B weight-fused replicate paths against one shared X."""

    betas: np.ndarray        # (B, L, p, m)
    sigmas: np.ndarray       # (L,) shared σ grid
    lam: np.ndarray
    weights: np.ndarray      # (B, n) count/mask/unit row weights
    health: np.ndarray       # (B, L) int32 HEALTH_* words
    plan: ResamplePlan
    stats: CompactStats | None = None  # compact backend only

    @property
    def n_replicates(self) -> int:
        return self.betas.shape[0]


@dataclasses.dataclass(frozen=True)
class StabilityResult:
    """Selection frequencies + threshold selector over the path."""

    frequencies: np.ndarray     # (L, p) selection frequency per path point
    max_frequency: np.ndarray   # (p,) max over the path — the selector input
    selected: np.ndarray        # (p,) bool, max_frequency ≥ threshold
    threshold: float
    replicates: ReplicateResult


@dataclasses.dataclass(frozen=True)
class PermutationResult:
    """Max-|gradient| permutation calibration for path entry."""

    pvalues: np.ndarray         # (p,) family-wise adjusted p-values
    observed: np.ndarray        # (p,) observed |∇f(0)| per predictor
    null_max: np.ndarray        # (B,) permutation-null max-|gradient| draws
    plan: ResamplePlan


@dataclasses.dataclass(frozen=True)
class BaggedResult:
    """Bootstrap-aggregated coefficients along the path."""

    betas_mean: np.ndarray      # (L, p, m) replicate mean
    betas_sd: np.ndarray        # (L, p, m) replicate sd
    replicates: ReplicateResult


def fit_replicates(
    X,
    y,
    lam,
    plan: ResamplePlan,
    family: Family = ols,
    *,
    sigmas=None,
    path_length: int = 100,
    sigma_ratio: float | None = None,
    working_set: int | None = None,
    ws_tiers: int | None = None,
    screening: str = "strong",
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
) -> ReplicateResult:
    """Fit B replicate paths with the weight-fused engines.

    The σ grid is computed once from the *original* problem and shared by
    every member, so downstream per-grid-point statistics (selection
    frequencies, bagged means) compare like with like.  ``working_set``
    picks the compact gather engine (width = working_set, optional second
    tier at ``ws_tiers``·W); ``None`` runs the masked engine.
    """
    X = np.asarray(X)
    y = np.asarray(y)
    lam = np.asarray(lam)
    n = X.shape[0]
    if sigmas is None:
        sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                                 sigma_ratio=sigma_ratio)
    sigmas = np.asarray(sigmas)

    weights = plan.row_weights(n, dtype=jnp.asarray(X).dtype)
    y_fit = plan.permuted_targets(y) if plan.kind == "permutation" else \
        jnp.asarray(y)

    RESAMPLE_METRICS.set_gauge("replicates_in_flight", plan.n_replicates,
                               kind=plan.kind)
    RESAMPLE_METRICS.inc("replicates", plan.n_replicates, kind=plan.kind,
                         backend="compact" if working_set else "masked")
    try:
        if working_set is None:
            res = replicate_path_engine(
                jnp.asarray(X), y_fit, jnp.asarray(lam), jnp.asarray(sigmas),
                weights, family, screening=screening, max_iter=max_iter,
                tol=solver_tol, kkt_tol=kkt_tol, max_refits=max_refits)
            stats = None
        else:
            width2 = None if not ws_tiers or ws_tiers < 2 else \
                min(2 * int(working_set), X.shape[1] * max(family.n_classes, 1))
            res, cstats = replicate_compact_path_engine(
                jnp.asarray(X), y_fit, jnp.asarray(lam), jnp.asarray(sigmas),
                weights, family, width=int(working_set), width2=width2,
                screening=screening, max_iter=max_iter, tol=solver_tol,
                kkt_tol=kkt_tol, max_refits=max_refits)
            stats = CompactStats(*(np.asarray(s) for s in cstats))
    finally:
        RESAMPLE_METRICS.set_gauge("replicates_in_flight", 0, kind=plan.kind)

    return ReplicateResult(
        betas=np.asarray(res.betas),
        sigmas=sigmas,
        lam=lam,
        weights=np.asarray(weights),
        health=np.asarray(res.health),
        plan=plan,
        stats=stats,
    )


def selection_frequencies(betas, *, tol: float = 0.0) -> np.ndarray:
    """Per-predictor selection frequency ``(L, p)`` over replicate paths.

    ``betas`` is ``(B, L, p, m)`` (a multiclass predictor counts as
    selected when *any* of its class coefficients exceeds ``tol``).
    """
    b = np.asarray(betas)
    active = np.abs(b).max(axis=-1) > tol  # (B, L, p)
    return active.mean(axis=0)


def stability_selection(
    X,
    y,
    lam,
    plan: ResamplePlan | None = None,
    family: Family = ols,
    *,
    threshold: float = 0.6,
    tol: float = 0.0,
    **fit_kwargs,
) -> StabilityResult:
    """Stability-selection frequencies + threshold selector for SLOPE.

    Defaults to a 100-replicate half-subsample plan (the classical
    stability-selection resampling scheme); pass a bootstrap plan for
    bagged-frequency variants.  A predictor is selected when its maximal
    selection frequency along the path reaches ``threshold``.
    """
    if plan is None:
        plan = ResamplePlan(kind="subsample", n_replicates=100, fraction=0.5)
    if plan.kind == "permutation":
        raise ValueError(
            "stability selection needs a bootstrap or subsample plan; "
            "permutation plans are for permutation_pvalues")
    rep = fit_replicates(X, y, lam, plan, family, **fit_kwargs)
    freq = selection_frequencies(rep.betas, tol=tol)
    max_freq = freq.max(axis=0)
    for f in max_freq:
        RESAMPLE_METRICS.observe("selection_frequency", float(f))
    return StabilityResult(
        frequencies=freq,
        max_frequency=max_freq,
        selected=max_freq >= threshold,
        threshold=float(threshold),
        replicates=rep,
    )


def permutation_pvalues(
    X,
    y,
    plan: ResamplePlan | None = None,
    family: Family = ols,
) -> PermutationResult:
    """Westfall–Young max-|gradient| permutation p-values for path entry.

    The observed statistic per predictor is ``g_j = max_m |∇f(0)_{jm}|`` —
    the same null-gradient magnitude the σ grid and the strong rule key
    off.  Each permutation draw recomputes it against permuted y (X fixed,
    one shared matmul batch) and keeps the *max* over predictors, giving
    family-wise-error-controlling adjusted p-values
    ``p_j = (1 + #{b : T_b ≥ g_j}) / (B + 1)``.
    """
    if plan is None:
        plan = ResamplePlan(kind="permutation", n_replicates=200)
    if plan.kind != "permutation":
        raise ValueError(
            f"permutation_pvalues needs a permutation plan, got "
            f"{plan.kind!r}")
    X = np.asarray(X)
    y = np.asarray(y)
    p = X.shape[1]
    m = max(family.n_classes, 1)

    g_obs = np.abs(null_gradient(X, y, family)).reshape(p, m).max(axis=1)

    Xj = jnp.asarray(X)
    beta0 = jnp.zeros((p,) if m == 1 else (p, m), Xj.dtype)
    y_perm = plan.permuted_targets(y)

    def null_stat(yb):
        g = family.gradient(Xj, yb, beta0)
        return jnp.max(jnp.abs(g))

    null_max = np.asarray(jax.vmap(null_stat)(y_perm))
    RESAMPLE_METRICS.inc("null_calibration_draws", plan.n_replicates)

    B = plan.n_replicates
    exceed = (null_max[:, None] >= g_obs[None, :]).sum(axis=0)
    pvalues = (1.0 + exceed) / (B + 1.0)
    return PermutationResult(pvalues=pvalues, observed=g_obs,
                             null_max=null_max, plan=plan)


def bagged_slope(
    X,
    y,
    lam,
    plan: ResamplePlan | None = None,
    family: Family = ols,
    **fit_kwargs,
) -> BaggedResult:
    """Bagged SLOPE: bootstrap-aggregated coefficients along the path."""
    if plan is None:
        plan = ResamplePlan(kind="bootstrap", n_replicates=100)
    if plan.kind == "permutation":
        raise ValueError(
            "bagging aggregates refitted coefficients; permutation plans "
            "destroy the signal being aggregated — use bootstrap/subsample")
    rep = fit_replicates(X, y, lam, plan, family, **fit_kwargs)
    return BaggedResult(
        betas_mean=rep.betas.mean(axis=0),
        betas_sd=rep.betas.std(axis=0),
        replicates=rep,
    )
