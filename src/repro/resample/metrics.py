"""The shared ``ns=resample`` metrics registry.

One process-wide :class:`~repro.obs.MetricsRegistry` for the resampling
subsystem, split into its own module so the serve layer can read it
(``PathService.stats()["resample"]``) by importing
``repro.resample.metrics`` without pulling the jax-heavy driver modules
into its import graph ordering.

Series:

* ``replicates_in_flight{kind=...}`` (gauge) — members of the currently
  executing replicate batch, 0 when idle.
* ``replicates{kind=...,backend=...}`` (counter) — total replicate paths
  fitted, by plan kind and engine backend.
* ``selection_frequency`` (histogram) — per-predictor max selection
  frequencies from stability-selection runs.
* ``null_calibration_draws`` (counter) — permutation-null max-|gradient|
  draws taken by :func:`repro.resample.permutation_pvalues`.
"""

from __future__ import annotations

from ..obs.registry import MetricsRegistry

__all__ = ["RESAMPLE_METRICS", "resample_stats", "track_in_flight"]

RESAMPLE_METRICS = MetricsRegistry("resample")


def track_in_flight(kind: str, delta: int) -> None:
    """Adjust the ``replicates_in_flight`` gauge by ``delta`` members
    (floored at 0) — the serve layer's submit/collect bookkeeping, where
    several resample requests can be in flight at once."""
    g = RESAMPLE_METRICS.gauge("replicates_in_flight", kind=kind)
    RESAMPLE_METRICS.set_gauge("replicates_in_flight",
                               max(0.0, g.value + delta), kind=kind)


def resample_stats() -> dict:
    """JSON-safe read-through view for the services' ``stats()``."""
    reg = RESAMPLE_METRICS
    gauges = reg.snapshot()["gauges"]
    in_flight = sum(v for series, v in gauges.items()
                    if series.startswith("replicates_in_flight"))
    return {
        "replicates_in_flight": in_flight,
        "replicates": reg.label_values("replicates", "kind"),
        "selection_frequency": reg.histogram("selection_frequency").summary(),
        "null_calibration_draws": reg.value("null_calibration_draws"),
    }
