"""FISTA solver for SLOPE (paper §3.1: accelerated proximal gradient).

One jit-compiled ``lax.while_loop`` per (n, p, m) shape; the path driver
buckets sub-problem widths to powers of two so the whole regularization
path reuses a handful of compilations.  Backtracking line search covers the
Poisson family (no global Lipschitz bound); adaptive restart (gradient
scheme) is a strict improvement over plain FISTA and is on by default.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .losses import Family
from .sorted_l1 import prox_sorted_l1, sorted_l1_norm

__all__ = ["fista", "FistaResult"]


class FistaResult(NamedTuple):
    beta: jax.Array
    iters: jax.Array
    objective: jax.Array
    converged: jax.Array


class _State(NamedTuple):
    x: jax.Array
    z: jax.Array
    t: jax.Array
    L: jax.Array
    obj: jax.Array
    it: jax.Array
    done: jax.Array


@functools.partial(
    jax.jit, static_argnames=("family", "max_iter", "tol", "restart", "max_backtrack")
)
def fista(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    family: Family,
    *,
    max_iter: int = 1000,
    tol: float = 1e-8,
    restart: bool = True,
    max_backtrack: int = 30,
) -> FistaResult:
    """Minimise f(β) + J(β; λ) with FISTA + backtracking + adaptive restart.

    ``lam`` must have ``beta0.size`` entries (flattened coefficients for the
    multinomial family) and be non-increasing.  Zero-padded columns of X are
    self-consistent: their gradient is identically zero so they stay at 0.
    """
    dtype = X.dtype
    lam = lam.astype(dtype)

    def obj_fn(beta):
        return family.loss(X, y, beta) + sorted_l1_norm(beta, lam)

    # Initial curvature guess: crude row-norm bound, corrected by backtracking.
    L0 = jnp.maximum(jnp.sum(X * X) * (family.hess_bound or 1.0) / X.shape[1], 1e-3)

    def step(state: _State) -> _State:
        z = state.z
        fz = family.loss(X, y, z)
        gz = family.gradient(X, y, z)

        def bt_cond(carry):
            L, x_new, ok, tries = carry
            return (~ok) & (tries < max_backtrack)

        def bt_body(carry):
            L, _, _, tries = carry
            x_new = prox_sorted_l1(jnp.ravel(z - gz / L), lam / L).reshape(z.shape)
            diff = x_new - z
            q = fz + jnp.vdot(gz, diff) + 0.5 * L * jnp.vdot(diff, diff)
            ok = family.loss(X, y, x_new) <= q + 1e-12 * jnp.abs(q)
            L_next = jnp.where(ok, L, L * 2.0)
            return L_next, x_new, ok, tries + 1

        L, x_new, _, _ = lax.while_loop(
            bt_cond, bt_body, (state.L, z, jnp.bool_(False), jnp.int32(0))
        )

        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t**2))
        momentum = (state.t - 1.0) / t_new
        z_new = x_new + momentum * (x_new - state.x)
        if restart:
            # Gradient-scheme restart (O'Donoghue & Candès): kill momentum
            # when the update opposes the trajectory.
            bad = jnp.vdot(z - x_new, x_new - state.x) > 0
            t_new = jnp.where(bad, 1.0, t_new)
            z_new = jnp.where(bad, x_new, z_new)

        obj_new = obj_fn(x_new)
        done = jnp.abs(state.obj - obj_new) <= tol * jnp.maximum(1.0, jnp.abs(obj_new))
        # mild decrease of L lets the step size recover after conservative phases
        return _State(x_new, z_new, t_new, L * 0.95, obj_new, state.it + 1, done)

    def cond(state: _State):
        return (~state.done) & (state.it < max_iter)

    init = _State(
        x=beta0.astype(dtype),
        z=beta0.astype(dtype),
        t=jnp.asarray(1.0, dtype),
        L=L0.astype(dtype),
        obj=obj_fn(beta0.astype(dtype)),
        it=jnp.int32(0),
        done=jnp.bool_(False),
    )
    final = lax.while_loop(cond, step, init)
    return FistaResult(final.x, final.it, final.obj, final.done)
