"""FISTA solver for SLOPE (paper §3.1: accelerated proximal gradient).

One jit-compiled ``lax.while_loop`` per (n, p, m) shape; the path driver
buckets sub-problem widths to powers of two so the whole regularization
path reuses a handful of compilations.  Backtracking line search covers the
Poisson family (no global Lipschitz bound); adaptive restart (gradient
scheme) is a strict improvement over plain FISTA and is on by default.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .losses import Family
from .sorted_l1 import prox_sorted_l1_with_norm, sorted_l1_norm

__all__ = ["fista", "fista_masked", "fista_shared_masked", "fista_compact",
           "default_L0", "FistaResult",
           "DEFAULT_PATH_TOL", "DEFAULT_PATH_MAX_ITER", "DEFAULT_KKT_TOL",
           "DEFAULT_MAX_REFITS", "DEFAULT_WS_TIERS"]

# Path-level solver defaults — the ONE source of truth shared by the host
# driver, the device engines, the serve layer and repro.api.SolverPolicy.
# (fista()'s own max_iter default stays lower: single sub-solves outside a
# path context have no warm start to lean on and callers pass their own.)
DEFAULT_PATH_TOL = 1e-8
DEFAULT_PATH_MAX_ITER = 5000
DEFAULT_KKT_TOL = 1e-4
DEFAULT_MAX_REFITS = 32
# Working-set tier policy for the compact engine: "auto" gives every W
# bucket a second 2W tier (when 2W < p) so a member whose screened set
# creeps just past W promotes its own tier instead of sending the whole
# batch to the masked O(n·p) fallback.  1 pins the single-tier PR-2
# behaviour, 2 demands the second tier (still capped below p).
DEFAULT_WS_TIERS = "auto"


def default_L0(X: jax.Array, family: Family,
               weights: jax.Array | None = None) -> jax.Array:
    """Initial curvature guess: crude row-norm bound, corrected by
    backtracking.  Shared by :func:`fista` and the path engine's scan carry
    so warm-started device solves seed the same curvature as cold ones.

    With per-row ``weights`` the bound is Σᵢ wᵢ‖xᵢ‖² — computed as a dot
    of the weight vector against the (shared) per-row square norms, so a
    batch of weight vectors against one shared X never materializes a
    per-member copy of X under vmap."""
    if weights is None:
        return jnp.maximum(
            jnp.sum(X * X) * (family.hess_bound or 1.0) / X.shape[1], 1e-3
        )
    row_sq = jnp.sum(X * X, axis=1)  # (n,), loop/batch-invariant for shared X
    total = jnp.sum(jnp.where(weights == 0, jnp.zeros((), row_sq.dtype),
                              weights * row_sq))
    return jnp.maximum(total * (family.hess_bound or 1.0) / X.shape[1], 1e-3)


class FistaResult(NamedTuple):
    beta: jax.Array
    iters: jax.Array
    objective: jax.Array
    converged: jax.Array
    L: jax.Array  # final curvature estimate (warm-start for the next solve)


class _State(NamedTuple):
    x: jax.Array
    z: jax.Array
    t: jax.Array
    L: jax.Array
    obj: jax.Array
    it: jax.Array
    done: jax.Array


@functools.partial(
    jax.jit,
    static_argnames=(
        "family", "max_iter", "tol", "restart", "max_backtrack", "prox_method"
    ),
)
def fista(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    family: Family,
    *,
    max_iter: int = 1000,
    tol: float = 1e-8,
    restart: bool = True,
    max_backtrack: int = 30,
    prox_method: str = "stack",
    L0: jax.Array | None = None,
    weights: jax.Array | None = None,
    col_mask: jax.Array | None = None,
) -> FistaResult:
    """Minimise f(β) + J(β; λ) with FISTA + backtracking + adaptive restart.

    ``lam`` must have ``beta0.size`` entries (flattened coefficients for the
    multinomial family) and be non-increasing.  Zero-padded columns of X are
    self-consistent: their gradient is identically zero so they stay at 0.
    ``L0`` overrides the initial curvature guess — the device path engine
    passes the previous path step's learned L so warm solves skip the
    backtracking ramp-up.

    ``weights`` (optional, (n,)) solves the row-reweighted problem
    Σ wᵢ ℓ(zᵢ, yᵢ) + J(β; λ) — the count-vector representation of a
    bootstrap replicate.  ``col_mask`` (optional, (p,) 0/1) restricts the
    solve to a working set by zeroing the *gradient* of masked columns
    instead of the columns of X themselves: for finite X this is bitwise
    the same fixed point as :func:`fista_masked` (masked coefficients stay
    exactly 0, unmasked gradients are untouched), but it keeps a shared X
    unbatched under vmap — ``X * mask`` with a per-member mask would
    materialize the (B, n, p) stack the resampling engine exists to avoid.

    Convergence requires BOTH an objective plateau (|Δobj| ≤ tol·max(1,|obj|))
    and a prox-gradient fixed-point residual ≤ √tol — coefficient-scale
    accuracy tracks √tol, so tol=1e-14 certifies β to ≈1e-7.
    """
    dtype = X.dtype
    lam = lam.astype(dtype)

    def obj_fn(beta):
        return family.loss(X, y, beta, weights=weights) + sorted_l1_norm(beta, lam)

    if L0 is None:
        L0 = default_L0(X, family, weights)

    def mask_grad(g):
        if col_mask is None:
            return g
        cm = col_mask if g.ndim == 1 else col_mask[:, None]
        # where (not multiply): a masked column's gradient becomes an exact
        # 0 even when non-finite, so a poisoned column cannot leak through
        return jnp.where(cm == 0, jnp.zeros((), g.dtype), g)

    def step(state: _State) -> _State:
        z = state.z
        # fused forward pair: one linear predictor feeds both the loss and
        # the residual for the gradient matvec (X streamed once for z)
        fz, gz = family.loss_and_gradient(X, y, z, weights=weights)
        gz = mask_grad(gz)

        def bt_cond(carry):
            L, x_new, fx, J, ok, tries = carry
            return (~ok) & (tries < max_backtrack)

        def bt_body(carry):
            L, _, _, _, _, tries = carry
            # prox at λ/L; its by-product norm is ⟨x_sorted, λ/L⟩, so scale
            # by L to recover J(x_new; λ) — no extra sort for the objective
            x_new, J_scaled = prox_sorted_l1_with_norm(
                jnp.ravel(z - gz / L), lam / L, method=prox_method
            )
            x_new = x_new.reshape(z.shape)
            diff = x_new - z
            q = fz + jnp.vdot(gz, diff) + 0.5 * L * jnp.vdot(diff, diff)
            fx = family.loss(X, y, x_new, weights=weights)
            ok = fx <= q + 1e-12 * jnp.abs(q)
            L_next = jnp.where(ok, L, L * 2.0)
            return L_next, x_new, fx, J_scaled * L, ok, tries + 1

        L, x_new, fx, J_new, _, _ = lax.while_loop(
            bt_cond, bt_body,
            (state.L, z, fz, jnp.zeros_like(fz), jnp.bool_(False), jnp.int32(0)),
        )

        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t**2))
        momentum = (state.t - 1.0) / t_new
        z_new = x_new + momentum * (x_new - state.x)
        if restart:
            # Gradient-scheme restart (O'Donoghue & Candès): kill momentum
            # when the update opposes the trajectory.
            bad = jnp.vdot(z - x_new, x_new - state.x) > 0
            t_new = jnp.where(bad, 1.0, t_new)
            z_new = jnp.where(bad, x_new, z_new)

        obj_new = fx + J_new
        # two-part stop: the objective Cauchy test alone can fire while
        # weakly-determined coefficients still drift (flat directions change
        # the objective at O(step²)), so also require the prox-gradient
        # fixed-point residual ‖x⁺ − z‖∞ ≲ √tol — that bounds coefficient
        # error at the same scale the objective test bounds the value
        plateau = jnp.abs(state.obj - obj_new) <= tol * jnp.maximum(1.0, jnp.abs(obj_new))
        resid = jnp.max(jnp.abs(x_new - z))
        stationary = resid <= jnp.sqrt(tol) * jnp.maximum(1.0, jnp.max(jnp.abs(x_new)))
        done = plateau & stationary
        # mild decrease of L lets the step size recover after conservative phases
        return _State(x_new, z_new, t_new, L * 0.95, obj_new, state.it + 1, done)

    def cond(state: _State):
        return (~state.done) & (state.it < max_iter)

    init = _State(
        x=beta0.astype(dtype),
        z=beta0.astype(dtype),
        t=jnp.asarray(1.0, dtype),
        L=L0.astype(dtype),
        obj=obj_fn(beta0.astype(dtype)),
        it=jnp.int32(0),
        done=jnp.bool_(False),
    )
    final = lax.while_loop(cond, step, init)
    return FistaResult(final.x, final.it, final.obj, final.done, final.L)


def fista_masked(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    mask: jax.Array,
    family: Family,
    **kw,
) -> FistaResult:
    """FISTA restricted to the working set ``mask`` — no column gathers.

    The device-engine analogue of the host driver's bucketed sub-problem:
    masked columns of X are zeroed, so their gradient vanishes and their
    coefficients stay pinned at exactly 0; because those coefficients are 0
    they sort to the tail of |β|, which leaves the working set aligned with
    the *leading* entries of λ — the same rank alignment the host driver
    achieves by slicing ``λ[:|E|·m]`` for the gathered sub-problem.

    ``mask`` is a (p,) predictor mask; for multinomial families it applies
    to every class column of the (p, m) coefficient block.

    Masked coordinates of the result are *exactly* 0 with no exit re-mask:
    their columns of ``Xm`` are zero so their gradient vanishes, momentum
    combines zeros into zeros, and the sorted-ℓ1 prox preserves exact zeros
    (a pooled block containing a zero-magnitude coordinate has mean ≤ 0 and
    clips to 0).  The invariant is asserted in ``tests/test_solver_path.py``.
    """
    mask_col = mask.astype(X.dtype)
    Xm = X * mask_col[None, :]
    beta0 = beta0 * (mask_col if beta0.ndim == 1 else mask_col[:, None])
    return fista(Xm, y, lam, beta0, family, **kw)


def fista_shared_masked(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    mask: jax.Array,
    family: Family,
    **kw,
) -> FistaResult:
    """:func:`fista_masked` for a *shared* design matrix: identical fixed
    point, but the working set restricts the solve by masking the gradient
    (``fista(col_mask=...)``) instead of materializing ``X * mask``.

    For finite X the two are numerically identical coordinate-for-
    coordinate: unmasked gradients are the same partial sums (×1.0 is
    exact), masked coordinates are exact zeros either way, and the z = Xβ
    products agree term-by-term because masked coefficients are exactly 0.
    What changes is the memory profile under vmap — with ``in_axes=None``
    on X and a per-member mask, ``X * mask`` would batch a (B, n, p)
    intermediate; the gradient mask keeps X a single (n, p) operand, which
    is the whole point of the weight-fused replicate engine.
    """
    mask_col = mask.astype(X.dtype)
    beta0 = beta0 * (mask_col if beta0.ndim == 1 else mask_col[:, None])
    return fista(X, y, lam, beta0, family, col_mask=mask_col, **kw)


def fista_compact(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    mask: jax.Array,
    family: Family,
    *,
    width: int,
    **kw,
) -> FistaResult:
    """FISTA on the working set *compacted* to a static ``width`` bucket.

    Where :func:`fista_masked` zeroes masked columns and still pays O(n·p)
    per iteration, this gathers the ≤ ``width`` unmasked columns into a
    device-resident (n, width) matrix — no host round-trip, no ``X * mask``
    materialization — solves at width W, and scatters the coefficients back
    to p-space.  Every FISTA iteration then costs O(n·W).

    Correctness leans on the same rank alignment as the host driver's
    gathered sub-problem: unmasked coefficients occupy the leading λ slots
    (λ[:W·m]) because masked coordinates are exactly 0 and sort to the λ
    tail.  Padding columns beyond ``mask.sum()`` are zeroed so they stay
    inert.  **The caller must guarantee** ``mask.sum() <= width`` (the path
    engine guards this with an overflow `lax.cond` falling back to
    :func:`fista_masked`) and that ``support(beta0) ⊆ mask``.

    ``width`` must be static (a Python int) — the path engine buckets it to
    powers of two so a whole path reuses a handful of compilations.  The
    two-tier compact engine (PR 5) composes this primitive at two static
    widths (W and 2W): each batch member's solve is served by the smallest
    tier that fits its screened set, and only demand beyond the top tier
    triggers the batch-wide masked fallback.
    """
    n, p = X.shape
    m = 1 if beta0.ndim == 1 else beta0.shape[1]
    dtype = X.dtype
    mask = mask.astype(bool)
    # stable sort: unmasked columns first, ascending index (matches the
    # host driver's np.nonzero gather order)
    idx = jnp.argsort(~mask)[:width]
    valid = (jnp.arange(width) < mask.sum()).astype(dtype)
    Xc = jnp.take(X, idx, axis=1) * valid[None, :]
    b0 = jnp.take(beta0, idx, axis=0)
    b0 = b0 * (valid if b0.ndim == 1 else valid[:, None])
    lam_c = lax.slice_in_dim(lam, 0, width * m)
    res = fista(Xc, y, lam_c, b0, family, **kw)
    bc = res.beta * (valid if res.beta.ndim == 1 else valid[:, None])
    beta = jnp.zeros(beta0.shape, dtype).at[idx].set(bc)
    return FistaResult(beta, res.iters, res.objective, res.converged, res.L)
