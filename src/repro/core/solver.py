"""FISTA solver for SLOPE (paper §3.1: accelerated proximal gradient).

One jit-compiled ``lax.while_loop`` per (n, p, m) shape; the path driver
buckets sub-problem widths to powers of two so the whole regularization
path reuses a handful of compilations.  Backtracking line search covers the
Poisson family (no global Lipschitz bound); adaptive restart (gradient
scheme) is a strict improvement over plain FISTA and is on by default.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .losses import Family
from .sorted_l1 import prox_sorted_l1_with_norm, sorted_l1_norm

__all__ = ["fista", "fista_masked", "default_L0", "FistaResult"]


def default_L0(X: jax.Array, family: Family) -> jax.Array:
    """Initial curvature guess: crude row-norm bound, corrected by
    backtracking.  Shared by :func:`fista` and the path engine's scan carry
    so warm-started device solves seed the same curvature as cold ones."""
    return jnp.maximum(
        jnp.sum(X * X) * (family.hess_bound or 1.0) / X.shape[1], 1e-3
    )


class FistaResult(NamedTuple):
    beta: jax.Array
    iters: jax.Array
    objective: jax.Array
    converged: jax.Array
    L: jax.Array  # final curvature estimate (warm-start for the next solve)


class _State(NamedTuple):
    x: jax.Array
    z: jax.Array
    t: jax.Array
    L: jax.Array
    obj: jax.Array
    it: jax.Array
    done: jax.Array


@functools.partial(
    jax.jit,
    static_argnames=(
        "family", "max_iter", "tol", "restart", "max_backtrack", "prox_method"
    ),
)
def fista(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    family: Family,
    *,
    max_iter: int = 1000,
    tol: float = 1e-8,
    restart: bool = True,
    max_backtrack: int = 30,
    prox_method: str = "stack",
    L0: jax.Array | None = None,
) -> FistaResult:
    """Minimise f(β) + J(β; λ) with FISTA + backtracking + adaptive restart.

    ``lam`` must have ``beta0.size`` entries (flattened coefficients for the
    multinomial family) and be non-increasing.  Zero-padded columns of X are
    self-consistent: their gradient is identically zero so they stay at 0.
    ``L0`` overrides the initial curvature guess — the device path engine
    passes the previous path step's learned L so warm solves skip the
    backtracking ramp-up.
    """
    dtype = X.dtype
    lam = lam.astype(dtype)

    def obj_fn(beta):
        return family.loss(X, y, beta) + sorted_l1_norm(beta, lam)

    if L0 is None:
        L0 = default_L0(X, family)

    def step(state: _State) -> _State:
        z = state.z
        fz = family.loss(X, y, z)
        gz = family.gradient(X, y, z)

        def bt_cond(carry):
            L, x_new, fx, J, ok, tries = carry
            return (~ok) & (tries < max_backtrack)

        def bt_body(carry):
            L, _, _, _, _, tries = carry
            # prox at λ/L; its by-product norm is ⟨x_sorted, λ/L⟩, so scale
            # by L to recover J(x_new; λ) — no extra sort for the objective
            x_new, J_scaled = prox_sorted_l1_with_norm(
                jnp.ravel(z - gz / L), lam / L, method=prox_method
            )
            x_new = x_new.reshape(z.shape)
            diff = x_new - z
            q = fz + jnp.vdot(gz, diff) + 0.5 * L * jnp.vdot(diff, diff)
            fx = family.loss(X, y, x_new)
            ok = fx <= q + 1e-12 * jnp.abs(q)
            L_next = jnp.where(ok, L, L * 2.0)
            return L_next, x_new, fx, J_scaled * L, ok, tries + 1

        L, x_new, fx, J_new, _, _ = lax.while_loop(
            bt_cond, bt_body,
            (state.L, z, fz, jnp.zeros_like(fz), jnp.bool_(False), jnp.int32(0)),
        )

        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t**2))
        momentum = (state.t - 1.0) / t_new
        z_new = x_new + momentum * (x_new - state.x)
        if restart:
            # Gradient-scheme restart (O'Donoghue & Candès): kill momentum
            # when the update opposes the trajectory.
            bad = jnp.vdot(z - x_new, x_new - state.x) > 0
            t_new = jnp.where(bad, 1.0, t_new)
            z_new = jnp.where(bad, x_new, z_new)

        obj_new = fx + J_new
        done = jnp.abs(state.obj - obj_new) <= tol * jnp.maximum(1.0, jnp.abs(obj_new))
        # mild decrease of L lets the step size recover after conservative phases
        return _State(x_new, z_new, t_new, L * 0.95, obj_new, state.it + 1, done)

    def cond(state: _State):
        return (~state.done) & (state.it < max_iter)

    init = _State(
        x=beta0.astype(dtype),
        z=beta0.astype(dtype),
        t=jnp.asarray(1.0, dtype),
        L=L0.astype(dtype),
        obj=obj_fn(beta0.astype(dtype)),
        it=jnp.int32(0),
        done=jnp.bool_(False),
    )
    final = lax.while_loop(cond, step, init)
    return FistaResult(final.x, final.it, final.obj, final.done, final.L)


def fista_masked(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    beta0: jax.Array,
    mask: jax.Array,
    family: Family,
    **kw,
) -> FistaResult:
    """FISTA restricted to the working set ``mask`` — no column gathers.

    The device-engine analogue of the host driver's bucketed sub-problem:
    masked columns of X are zeroed, so their gradient vanishes and their
    coefficients stay pinned at exactly 0; because those coefficients are 0
    they sort to the tail of |β|, which leaves the working set aligned with
    the *leading* entries of λ — the same rank alignment the host driver
    achieves by slicing ``λ[:|E|·m]`` for the gathered sub-problem.

    ``mask`` is a (p,) predictor mask; for multinomial families it applies
    to every class column of the (p, m) coefficient block.
    """
    mask_col = mask.astype(X.dtype)
    Xm = X * mask_col[None, :]
    beta0 = beta0 * (mask_col if beta0.ndim == 1 else mask_col[:, None])
    res = fista(Xm, y, lam, beta0, family, **kw)
    beta = res.beta * (mask_col if res.beta.ndim == 1 else mask_col[:, None])
    return FistaResult(beta, res.iters, res.objective, res.converged, res.L)
