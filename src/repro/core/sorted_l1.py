"""Sorted-ℓ1 norm, its proximal operator and dual gauge.

This is the mathematical heart of SLOPE (paper §1, eq. (1)):

    J(β; λ) = Σ_j λ_j |β|_(j),   λ_1 ≥ … ≥ λ_p ≥ 0.

The prox follows the FastProxSL1 construction (Bogdan et al. 2015, used by
the paper's reference implementation): sort |v| in decreasing order, subtract
λ, project onto the non-increasing cone (PAVA), clip at zero, undo the sort
and restore signs.  The PAVA pooling is implemented with a fixed-shape stack
driven by ``lax.fori_loop``/``lax.while_loop`` so it jits with static shapes;
``repro.kernels.prox_sorted_l1`` provides the blocked Pallas version of the
pooling loop and ``repro.kernels.ref`` the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "sorted_l1_norm",
    "prox_sorted_l1",
    "prox_sorted_l1_with_norm",
    "dual_sorted_l1_gauge",
    "isotonic_decreasing",
    "isotonic_decreasing_minimax",
    "isotonic_decreasing_parallel",
    "clusters",
]


def sorted_l1_norm(beta: jax.Array, lam: jax.Array) -> jax.Array:
    """J(β; λ) = Σ λ_j |β|_(j) with |β|_(1) ≥ |β|_(2) ≥ …"""
    beta = jnp.ravel(beta)
    mag = jnp.sort(jnp.abs(beta))[::-1]
    return jnp.dot(mag, lam.astype(mag.dtype))


def isotonic_decreasing(y: jax.Array) -> jax.Array:
    """Project ``y`` onto the non-increasing cone {w : w_1 ≥ … ≥ w_p}.

    Pool-adjacent-violators with an explicit block stack.  O(p): every
    element is pushed once and merged at most once.
    """
    p = y.shape[0]
    dtype = y.dtype

    def push(i, state):
        sums, counts, top = state
        sums = sums.at[top].set(y[i])
        counts = counts.at[top].set(1)

        def violated(s):
            sm, ct, t = s
            # mean(block t) >= mean(block t-1): pool them.
            return (t > 0) & (sm[t] * ct[t - 1] >= sm[t - 1] * ct[t])

        def pool(s):
            sm, ct, t = s
            sm = sm.at[t - 1].add(sm[t])
            ct = ct.at[t - 1].add(ct[t])
            return sm, ct, t - 1

        sums, counts, top = lax.while_loop(violated, pool, (sums, counts, top))
        return sums, counts, top + 1

    sums0 = jnp.zeros((p,), dtype)
    counts0 = jnp.zeros((p,), jnp.int32)
    sums, counts, top = lax.fori_loop(0, p, push, (sums0, counts0, 0))

    # Expand block means back to element positions.  Block j covers
    # positions [cumsum(counts)[j-1], cumsum(counts)[j]).
    ends = jnp.cumsum(counts)
    idx = jnp.searchsorted(ends, jnp.arange(p, dtype=ends.dtype), side="right")
    safe_counts = jnp.maximum(counts, 1)
    means = sums / safe_counts.astype(dtype)
    return means[idx]


def isotonic_decreasing_parallel(y: jax.Array) -> jax.Array:
    """Project onto the non-increasing cone by parallel block merging.

    Each sweep merges EVERY violating adjacent block pair at once — safe
    because a violating adjacent pair must share a block in the optimum, so
    simultaneous merging keeps the partition a refinement of the optimal
    one (the classic PAVA invariant).  A sweep is ~10 dense vectorized ops
    (segment sums + a cumsum), with no data-dependent inner loop: unlike
    the sequential stack PAVA this form vmaps with near-perfect batch
    efficiency, which is why the batched device engine uses it.  Typical
    sweep counts are O(log p); worst case O(p) sweeps (still exact).
    """
    p = y.shape[0]
    dtype = y.dtype
    idx = jnp.arange(p)
    S = jnp.concatenate([jnp.zeros((1,), dtype), jnp.cumsum(y)])

    def block_means(start):
        # scatter-free segment means: a block is [begin, end) where begin is
        # the last start flag at-or-before i (cummax) and end the first one
        # after i (reverse cummin) — scatters are pathological under vmap on
        # CPU, cumulative scans are not
        begin = lax.cummax(jnp.where(start, idx, 0))
        nxt = lax.cummin(jnp.where(start, idx, p), reverse=True)
        end = jnp.concatenate([nxt[1:], jnp.full((1,), p, idx.dtype)])
        return (S[end] - S[begin]) / (end - begin).astype(dtype)

    def violations(start):
        mean = block_means(start)
        prev = jnp.roll(mean, 1)
        # pool when mean(block) ≥ mean(previous block) — ties merge, which
        # leaves the projected values unchanged (equal means pool to equal)
        return start & (mean >= prev) & (idx > 0)

    def cond(state):
        start, viol = state
        return viol.any()

    def body(state):
        start, viol = state
        start = start & ~viol
        return start, violations(start)

    start0 = jnp.ones((p,), bool)
    start, _ = lax.while_loop(cond, body, (start0, violations(start0)))
    return block_means(start)


def isotonic_decreasing_minimax(y: jax.Array) -> jax.Array:
    """Project onto the non-increasing cone via the minimax formula
    (Robertson et al.):  x_i = min_{a ≤ i} max_{b ≥ i} mean(y[a..b]).

    O(p²) work but O(log p) depth with NO sequential data dependence.
    Reference/benchmark alternative: the batched engine uses the
    sweep-merging form above (cheaper on CPU); this closed form is kept as
    an independently-derived oracle and for accelerator experiments, where
    its depth-parallelism may win despite the p × p intermediates.
    """
    p = y.shape[0]
    dtype = y.dtype
    S = jnp.concatenate([jnp.zeros((1,), dtype), jnp.cumsum(y)])
    a = jnp.arange(p)[:, None]
    b = jnp.arange(p)[None, :]
    valid = b >= a
    means = (S[b + 1] - S[a]) / jnp.where(valid, b - a + 1, 1).astype(dtype)
    means = jnp.where(valid, means, -jnp.inf)
    # R[a, i] = max_{b ≥ i} mean(y[a..b]);  x_i = min_{a ≤ i} R[a, i]
    R = lax.cummax(means, axis=1, reverse=True)
    R = jnp.where(valid, R, jnp.inf)
    return jnp.diagonal(lax.cummin(R, axis=0))


@functools.partial(jax.jit, static_argnames=("method",))
def prox_sorted_l1_with_norm(v: jax.Array, lam: jax.Array, *,
                             method: str = "stack"):
    """(prox_{J(·;λ)}(v), J(prox; λ)) in one pass.

    The prox works on |v| sorted decreasing, and its sorted output IS the
    sorted magnitude vector of the result — so J(x; λ) = ⟨x_sorted, λ⟩ falls
    out for free, saving the solver a per-iteration sort.
    """
    shape = v.shape
    v = jnp.ravel(v)
    lam = jnp.ravel(lam).astype(v.dtype)
    sign = jnp.sign(v)
    mag = jnp.abs(v)
    order = jnp.argsort(-mag)  # decreasing |v|
    w = mag[order] - lam
    iso = {
        "stack": isotonic_decreasing,
        "parallel": isotonic_decreasing_parallel,
        "minimax": isotonic_decreasing_minimax,
    }[method](w)
    x_sorted = jnp.maximum(iso, 0)
    x = jnp.zeros_like(v).at[order].set(x_sorted)
    return (sign * x).reshape(shape), jnp.dot(x_sorted, lam)


@functools.partial(jax.jit, static_argnames=("method",))
def prox_sorted_l1(v: jax.Array, lam: jax.Array, *, method: str = "stack") -> jax.Array:
    """prox_{J(·;λ)}(v) = argmin_x ½‖x − v‖² + J(x; λ).

    ``method='stack'`` is the lax.while_loop PAVA here; ``method='parallel'``
    the sweep-merging form (:func:`isotonic_decreasing_parallel`) the
    batched device engine uses; ``method='minimax'`` the O(p²)-work
    depth-parallel form; the Pallas kernel path lives in
    :mod:`repro.kernels.ops` and is validated against this.
    """
    return prox_sorted_l1_with_norm(v, lam, method=method)[0]


def dual_sorted_l1_gauge(g: jax.Array, lam: jax.Array) -> jax.Array:
    """Gauge of the dual ball of J: max_i cumsum(|g|↓)_i / cumsum(λ)_i.

    ``gauge ≤ 1``  ⇔  g ∈ ∂J(0; λ)  (Theorem 1, case β = 0).  The path
    start σ(1) (paper §3.1.2) is exactly this gauge evaluated at ∇f(0).
    """
    g = jnp.ravel(g)
    mag = jnp.sort(jnp.abs(g))[::-1]
    num = jnp.cumsum(mag)
    den = jnp.cumsum(lam.astype(mag.dtype))
    den = jnp.where(den <= 0, jnp.inf, den)
    return jnp.max(num / den)


def clusters(beta: jax.Array, *, atol: float = 0.0):
    """Cluster indices A_i of equal-magnitude coefficients (paper eq. (2)).

    Host-side helper (NumPy semantics) used by tests and the KKT check;
    returns a list of index arrays, magnitudes strictly decreasing.
    """
    import numpy as np

    beta = np.asarray(beta).ravel()
    mag = np.abs(beta)
    out = []
    for m in np.unique(mag)[::-1]:
        members = np.nonzero(np.abs(mag - m) <= atol)[0]
        out.append(members)
    return out
