"""KKT / subdifferential checks for SLOPE (paper Theorem 1 + §2.2.4).

Two flavours:

* :func:`in_subdifferential` — exact Theorem-1 membership test for
  ``g ∈ ∂J(β; λ)`` (cluster-wise cumsum + equality conditions).  Used by
  tests to certify prox correctness and solver optimality.
* :func:`kkt_violations` — the operational check both path algorithms use:
  run Proposition 1 (Algorithm 1 with the current full gradient); any
  predictor the rule keeps that is outside the working set E is a violation
  and must be added to E (Algorithms 3 and 4).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .screening import screen_k, screen_masked

__all__ = [
    "in_subdifferential",
    "kkt_violations",
    "kkt_violations_masked",
    "kkt_optimal",
]


def in_subdifferential(g, beta, lam, *, rtol: float = 1e-6, atol: float = 1e-6) -> bool:
    """Exact Theorem-1 test: is g ∈ ∂J(β; λ)?  Host-side (NumPy).

    Clusters A_i of equal |β| are checked independently (the subdifferential
    factorises over clusters); within a cluster the conditions are
      cumsum(|g_A|↓ − λ_A) ≤ 0, and, if the cluster is non-zero,
      Σ_{j∈A}(|g_j| − λ_j) = 0 together with sign(g_j) = sign(β_j).
    λ slots are allocated to clusters by the global magnitude order of β
    (inactive cluster gets the tail), matching Theorem 1's R(s) indexing.
    """
    g = np.asarray(g, dtype=np.float64).ravel()
    beta = np.asarray(beta, dtype=np.float64).ravel()
    lam = np.asarray(lam, dtype=np.float64).ravel()
    scale = max(1.0, float(np.max(lam, initial=0.0)))
    tol = atol + rtol * scale

    mag = np.abs(beta)
    order = np.argsort(-mag, kind="stable")
    # walk clusters in decreasing |β|; slot λ entries in order.  Clusters
    # are EXACT equality classes (paper eq. (2)) — prox/FISTA pool ties and
    # zeros exactly, and any absolute merge tolerance would misclassify
    # tiny-but-nonzero coefficients into the zero cluster.
    pos = 0
    i = 0
    while i < len(order):
        j = i
        while j < len(order) and mag[order[j]] == mag[order[i]]:
            j += 1
        members = order[i:j]
        lam_slot = lam[pos: pos + len(members)]
        gs = g[members]
        active = mag[members[0]] > 0
        if active and np.any((np.sign(gs) != np.sign(beta[members])) & (gs != 0)):
            # sign condition binds where β ≠ 0 AND g ≠ 0 (g_j = 0 is always
            # admissible — e.g. λ ≡ 0 gives ∂J = {0} regardless of signs)
            return False
        c = np.sort(np.abs(gs))[::-1]
        if np.any(np.cumsum(c - lam_slot) > tol):
            return False
        if active and abs(np.sum(np.abs(gs) - lam_slot)) > tol * max(1, len(members)):
            return False
        pos = j
        i = j
    return True


def kkt_optimal(grad, beta, lam, **kw) -> bool:
    """Stationarity (7): 0 ∈ ∇f(β) + ∂J(β;λ)  ⇔  −∇f(β) ∈ ∂J(β;λ)."""
    return in_subdifferential(-np.asarray(grad), beta, lam, **kw)


@functools.partial(jax.jit, static_argnames=("tol",))
def kkt_violations_masked(grad, lam, ever_mask, subset_mask, *, tol: float = 1e-6):
    """Device-resident form of :func:`kkt_violations` (no dynamic shapes).

    Same semantics — Proposition 1 over ``subset_mask | ever_mask``, minus
    the working set — but expressed through :func:`screen_masked` so the
    whole check stays inside one jit scope (the path engine's ``lax.scan``
    step).  ``grad`` is the *flattened* coefficient gradient; both masks are
    coordinate-space booleans of the same length.
    """
    grad = jnp.ravel(grad)
    ever_mask = jnp.ravel(ever_mask).astype(bool)
    consider = jnp.ravel(subset_mask).astype(bool) | ever_mask
    mag = jnp.abs(grad)
    shift = jnp.full(grad.shape, -tol, mag.dtype)
    keep, _ = screen_masked(mag, jnp.ravel(lam), consider, shift)
    return keep & ~ever_mask


def kkt_violations(grad, lam, ever_mask, *, subset_mask=None, tol: float = 1e-6):
    """Operational violation check used by Algorithms 3 and 4.

    Runs Proposition 1 on |grad| restricted to ``subset_mask`` (default: the
    full predictor set) and returns the boolean mask of predictors that the
    rule keeps but which are *not* in the working set ``ever_mask``.

    Host-side orchestration (the path drivers are NumPy-driven); the scan
    itself is the jit'd :func:`repro.core.screening.screen_k`.
    """
    grad = np.asarray(grad)
    p = grad.size
    ever_mask = np.asarray(ever_mask, dtype=bool).ravel()
    if subset_mask is None:
        subset_mask = np.ones(p, dtype=bool)
    else:
        subset_mask = np.asarray(subset_mask, dtype=bool).ravel()
    consider = subset_mask | ever_mask
    idx = np.nonzero(consider)[0]
    mag = np.abs(grad.ravel())[idx]
    order = np.argsort(-mag, kind="stable")
    # pad to the full length so screen_k sees ONE shape per problem (the
    # padded tail c−λ = −1e12 can never host the rightmost argmax) — keeps
    # the KKT check recompile-free along the whole path
    c_pad = np.full(p, -1e12)
    c_pad[: len(idx)] = mag[order] - tol
    lam_pad = np.zeros(p)
    lam_pad[: len(idx)] = np.asarray(lam)[: len(idx)]
    k = int(screen_k(jnp.asarray(c_pad), jnp.asarray(lam_pad)))
    k = min(k, len(idx))
    kept = idx[order[:k]]
    viol = np.zeros(p, dtype=bool)
    viol[kept] = True
    viol &= ~ever_mask
    return viol
