"""Feature-parallel (column-sharded) SLOPE screening with ``shard_map``.

At cluster scale the design matrix X (n × p, p ≫ n) is column-sharded over
the mesh: shard d owns X[:, d·p/D : (d+1)·p/D].  Per path step the strong
rule needs

  1. the full gradient  ∇f = Xᵀ r         — embarrassingly parallel over
     columns once the residual r (length n) is replicated;
  2. the *sorted* surrogate and the cumsum scan — global order matters.

Gathering all p magnitudes defeats the point, so we exploit the paper's own
observation (Table 2: the screened set is a small multiple of the active
set): the screened set S is always a prefix of the global magnitude order,
so S ⊆ top-`cap` as long as card(S) ≤ cap.  Each shard contributes its local
top-`cap ÷ D` … actually its local top-`cap` (safe: global top-cap ⊆ union
of local top-caps), candidates are all-gathered (O(D·cap) ≪ p), sorted, and
screened with the closed-form cumsum-argmax rule.  If the returned k hits
the cap the caller doubles it and retries — exactness is preserved.

The residual r = ∂ℓ/∂z needs z = Xβ = Σ_d X_d β_d: one ``psum`` of an
n-vector per gradient evaluation — the only communication that scales with
data rather than candidates.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .screening import screen_k

__all__ = ["sharded_linear_predictor", "sharded_gradient", "distributed_strong_rule",
           "DistributedScreenResult"]


class DistributedScreenResult(NamedTuple):
    k: jax.Array            # predicted support size (global)
    threshold: jax.Array    # |surrogate| of the k-th kept candidate
    keep_mask: jax.Array    # bool (p,), column-sharded like X
    hit_cap: jax.Array      # True → retry with a larger cap


def sharded_linear_predictor(mesh: Mesh, axis: str):
    """z = Xβ with X and β column/feature-sharded: local matvec + psum."""

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis)),
        out_specs=P(),
        check_rep=False,
    )
    def z_fn(X_local, beta_local):
        return jax.lax.psum(X_local @ beta_local, axis)

    return z_fn


def sharded_gradient(mesh: Mesh, axis: str):
    """∇f shard: Xᵀr needs no communication when X is column-sharded."""

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    def g_fn(X_local, r):
        return X_local.T @ r

    return g_fn


def distributed_strong_rule(mesh: Mesh, axis: str, *, cap: int, p_total: int):
    """Strong rule for SLOPE over a column-sharded gradient.

    Inputs (to the returned callable):
      grad         — (p,) gradient at the previous solution, sharded over ``axis``
      gap_cap      — (cap·D,) first cap·D entries of λ^(m) − λ^(m+1)
      lam_cap      — (cap·D,) first cap·D entries of λ^(m+1)
      lam_min      — scalar λ^(m+1)_p (smallest penalty)
      gap_tail_max — scalar max_{j>cap·D} (λ^(m)_j − λ^(m+1)_j)

    Only the top-``cap`` magnitudes per shard enter the global screen
    (all-gather payload and sort bounded at cap·D ≪ p).  Truncation is a
    *prefix* of Algorithm 2's input, so the result is certified exact only
    when the un-gathered tail provably cannot raise the running cumsum
    above its current maximum: every un-gathered surrogate value is ≤
    c_bound = max over shards of the shard's cap-th magnitude (+ the λ-gap
    bound), and each tail term contributes ≤ c_bound − λ_min.  When the
    certificate fails the callable reports uncertain=True and the caller
    retries with a doubled cap — exactness is never silently lost.
    """

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(), P(), P(), P()),
        out_specs=(P(), P(), P(axis), P()),
        check_rep=False,
    )
    def screen_fn(grad_local, gap_cap, lam_cap, lam_min, gap_tail_max):
        mag_local = jnp.abs(grad_local)
        top_local, _ = jax.lax.top_k(mag_local, cap)
        cand = jax.lax.all_gather(top_local, axis, tiled=True)  # (cap·D,)
        cand = -jnp.sort(-cand)
        c = cand + gap_cap
        s = jnp.cumsum(c - lam_cap)
        k = screen_k(c, lam_cap)
        # threshold: magnitude of the weakest kept candidate (∞ if none kept)
        thr = jnp.where(k > 0, cand[jnp.maximum(k - 1, 0)], jnp.inf)
        keep_local = mag_local >= thr

        capD = cand.shape[0]
        c_bound = jax.lax.pmax(top_local[-1], axis) + gap_tail_max
        tail = (p_total - capD) * jnp.maximum(c_bound - lam_min, 0.0)
        best = jnp.max(s)
        uncertain = (k >= capD) | (s[-1] + tail >= jnp.maximum(best, 0.0))
        return k, thr, keep_local, uncertain

    return screen_fn
