"""Regularization-path front-end: the strong-set and previous-set algorithms
(paper Algorithms 3 and 4) plus a no-screening baseline, over two backends.

``engine="host"`` is the classic driver: host-side NumPy orchestration
around three jit'd primitives (gradient, FISTA sub-solve, screen).  Column
gathers shrink every sub-problem to the screened set — the right trade for
a single huge p ≫ n problem, where the gathered matvec is the whole win —
and sub-problem widths are padded to power-of-four buckets so one path
reuses a handful of XLA compilations.

``engine="device"`` routes to :mod:`repro.core.engine`: the whole per-step
loop (screen → masked FISTA → KKT repair) runs inside one compiled
``lax.scan``, eliminating the per-step host↔device round-trips.  That is
the backend the batched/CV entry points build on.  ``engine="auto"``
currently selects "host" for this single-problem API (gathered sub-problems
beat masked full-width solves once p is large); batched workloads should
call :func:`repro.core.engine.fit_path_batched` directly, and *streams* of
heterogeneous single fits belong on :class:`repro.serve.PathService`, which
micro-batches them into the device engine.  ``pad="bucket"`` (device
backend) pads a single problem to the serve layer's canonical power-of-two
execution shape so heterogeneous one-off fits share compiled programs —
and return bit-identical results to the same request routed through the
service.

Both backends honour the same ``fit_path`` signature and return the same
:class:`PathResult` contract, and agree within solver tolerance (see
``tests/test_engine.py``).

Since PR 4 ``fit_path`` is a thin shim over :func:`repro.api.slope_path`:
the kwargs become a ``(Problem, PathSpec, SolverPolicy)`` spec triple, the
backend choice is made (or validated) by :func:`repro.api.plan_execution`,
and the private ``_fit_path_host`` / ``_fit_path_device`` implementations
below are invoked by the api layer — so legacy calls stay bit-identical
while new code gets one declarative front door.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np

import jax.numpy as jnp

from .engine import EnginePath, null_gradient, null_sigma_grid, path_engine
from .kkt import kkt_violations
from .losses import Family
from .screening import strong_rule
from .solver import (
    DEFAULT_KKT_TOL,
    DEFAULT_MAX_REFITS,
    DEFAULT_PATH_MAX_ITER,
    DEFAULT_PATH_TOL,
    fista,
)

__all__ = ["fit_path", "PathResult", "PathStep", "engine_to_path_result"]

# "kwarg not passed" sentinel (legacy defaults must not warn); local for the
# same import-cycle reason as repro.core.engine's — see the note there
_UNSET = object()


@dataclasses.dataclass
class PathStep:
    sigma: float
    active: np.ndarray          # bool (p,) — predictors with any nonzero coef
    n_active: int
    n_screened: int             # card of screened set fed to the solver
    n_violations: int           # KKT failures while solving this step
    refits: int
    deviance: float
    solver_iters: int
    wall_time: float


@dataclasses.dataclass
class PathResult:
    betas: np.ndarray           # (l, p) or (l, p, m)
    sigmas: np.ndarray
    steps: list[PathStep]
    lam: np.ndarray
    total_time: float
    total_violations: int
    plan: object | None = None  # repro.api ExecutionPlan (slope_path only)

    @property
    def screen_efficiency(self) -> np.ndarray:
        """card(screened)/card(active) per step (paper's 'efficiency')."""
        return np.array(
            [s.n_screened / max(1, s.n_active) for s in self.steps]
        )


def _bucket(width: int, p: int) -> int:
    """Sub-problem width bucket: ×4 growth from 64, capped at p.

    Coarse buckets bound the number of distinct jit shapes a path can see
    at log₄(p) — the screening rule must not pay recompilation overhead in
    the n ≫ p regime (paper Fig. 5).
    """
    b = 64
    while b < width:
        b *= 4
    return min(b, p)


def _stop_triggered(beta: np.ndarray, dev: float, prev_dev: float,
                    null_dev: float, n: int) -> bool:
    """The paper's stopping rules 1–3: unique-magnitude saturation,
    deviance plateau, deviance explained.  The ONE predicate shared by the
    host loop (inline break) and the device backend (post-hoc truncation)."""
    mags = np.unique(np.abs(beta[np.abs(beta) > 0]))
    frac_change = abs(prev_dev - dev) / max(abs(null_dev), 1e-12)
    dev_explained = 1.0 - dev / null_dev if null_dev > 0 else 1.0
    return len(mags) > n or frac_change < 1e-5 or dev_explained > 0.995


def _early_stop_len(betas_pm: np.ndarray, devs: np.ndarray, null_dev: float,
                    n: int) -> int:
    """First path length at which :func:`_stop_triggered` fires."""
    prev_dev = null_dev
    for i in range(1, len(devs)):
        dev = float(devs[i])
        if _stop_triggered(betas_pm[i], dev, prev_dev, null_dev, n):
            return i + 1
        prev_dev = dev
    return len(devs)


def engine_to_path_result(ep: EnginePath, sigmas, lam, wall_time: float, *,
                          early_stop: bool = True, n: int | None = None
                          ) -> PathResult:
    """Convert a device :class:`~repro.core.engine.EnginePath` (full σ grid)
    into the host :class:`PathResult` contract, applying the early-stopping
    rules post-hoc (the device scan cannot truncate)."""
    betas_pm = np.asarray(ep.betas)          # (L, p, m)
    devs = np.asarray(ep.deviance)
    sigmas = np.asarray(sigmas)
    L = betas_pm.shape[0]
    if early_stop:
        if n is None:
            raise ValueError("early_stop requires the sample count n")
        L = _early_stop_len(betas_pm, devs, float(devs[0]), n)
    per_step = wall_time / max(L, 1)
    steps = [
        PathStep(
            sigma=float(sigmas[i]),
            active=(np.abs(betas_pm[i]) > 0).any(axis=1),
            n_active=int(ep.n_active[i]),
            n_screened=int(ep.n_screened[i]),
            n_violations=int(ep.n_violations[i]),
            refits=int(ep.refits[i]),
            deviance=float(devs[i]),
            solver_iters=int(ep.solver_iters[i]),
            wall_time=per_step,
        )
        for i in range(L)
    ]
    betas = betas_pm[:L]
    if betas.shape[2] == 1:
        betas = betas[:, :, 0]
    return PathResult(
        betas=betas,
        sigmas=sigmas[:L],
        steps=steps,
        lam=np.asarray(lam),
        total_time=wall_time,
        total_violations=int(np.asarray(ep.n_violations)[:L].sum()),
    )


def fit_path(
    X,
    y,
    lam,
    family: Family,
    *,
    screening: Literal["strong", "previous", "none"] = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    early_stop: bool = True,
    verbose: bool = False,
    engine: Literal["auto", "host", "device"] = _UNSET,
    max_refits: int = DEFAULT_MAX_REFITS,
    pad: str | None = _UNSET,
) -> PathResult:
    """Fit a full SLOPE path.

    ``screening='strong'``  → Algorithm 3 (E = strong ∪ previously-active),
    ``screening='previous'``→ Algorithm 4 (E = previously-active; check the
    strong set first, then the full set),
    ``screening='none'``    → always solve on all p predictors (baseline).

    Legacy entry point, now a thin shim over :func:`repro.api.slope_path`:
    the kwargs become a ``(Problem, PathSpec, SolverPolicy)`` triple and
    results are bit-identical to the PR-1..3 behaviour.  ``engine`` picks
    the backend ("auto" keeps the gathered host driver for this
    single-problem API); it and ``pad`` have spec replacements
    (``SolverPolicy(backend=..., pad=...)``) and warn once per process —
    see ``docs/MIGRATION.md``.  ``max_refits`` caps the device engine's
    bounded KKT repair loop; ``verbose`` is host-only.
    """
    from ..api import LambdaSpec, PathSpec, Problem, SolverPolicy, slope_path
    from ..api.compat import warn_legacy

    if engine is _UNSET:
        engine = "auto"
    else:
        warn_legacy("fit_path", "engine", "SolverPolicy(backend=...)")
    if pad is _UNSET:
        pad = None
    else:
        warn_legacy("fit_path", "pad", "SolverPolicy(pad=...)")
    if engine not in ("auto", "host", "device"):
        raise ValueError(f"engine must be 'auto', 'host' or 'device', got {engine!r}")
    if screening not in ("strong", "previous", "none"):
        raise ValueError(f"unknown screening mode {screening!r}")
    if engine == "auto":
        engine = "host"
    if pad is not None and engine != "device":
        raise ValueError("pad='bucket' requires engine='device' (the host "
                         "driver gathers sub-problems; it has no use for "
                         "canonical padded shapes)")
    return slope_path(
        Problem(X, y, family=family),
        PathSpec(lam=LambdaSpec.explicit(lam), path_length=path_length,
                 sigma_ratio=sigma_ratio, sigmas=sigmas,
                 early_stop=early_stop),
        SolverPolicy(backend="host" if engine == "host" else "masked",
                     pad=pad, screening=screening, solver_tol=solver_tol,
                     max_iter=max_iter, kkt_tol=kkt_tol,
                     max_refits=max_refits, verbose=verbose),
    )


def _fit_path_device(X, y, lam, family, *, screening, path_length,
                     sigma_ratio, sigmas, solver_tol, max_iter, kkt_tol,
                     early_stop, max_refits, pad=None):
    from .engine import _fit_path_batched, _warn_unrepaired

    t0 = time.perf_counter()
    X = np.asarray(X)
    y = np.asarray(y)
    n, p = X.shape
    m = family.n_classes
    lam = np.asarray(lam, dtype=X.dtype)
    assert lam.shape[0] == p * m, "λ must have one entry per coefficient"
    if sigmas is None:
        sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                                 sigma_ratio=sigma_ratio)
    sigmas = np.asarray(sigmas)
    if pad == "bucket":
        # route through the batched entry point's canonical bucket padding
        # (B padded to ≥ 2 inert slots): shares compiled programs across
        # nearby shapes, bit-identical to the PathService serving this
        # request (same policy, same execution shape)
        res = _fit_path_batched(
            X[None], y[None], lam, family, screening=screening,
            sigmas=sigmas, solver_tol=solver_tol, max_iter=max_iter,
            kkt_tol=kkt_tol, max_refits=max_refits, pad="bucket")
        return res.path_results(early_stop=early_stop)[0]
    ep = path_engine(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam), jnp.asarray(sigmas),
        family, screening=screening, max_iter=max_iter, tol=solver_tol,
        kkt_tol=kkt_tol, max_refits=max_refits,
    )
    ep = EnginePath(*(np.asarray(a) for a in ep))
    _warn_unrepaired(ep.kkt_unrepaired, max_refits)
    return engine_to_path_result(ep, sigmas, lam,
                                 time.perf_counter() - t0,
                                 early_stop=early_stop, n=n)


def _fit_path_host(
    X, y, lam, family, *, screening, path_length, sigma_ratio, sigmas,
    solver_tol, max_iter, kkt_tol, early_stop, verbose,
) -> PathResult:
    t_start = time.perf_counter()
    X = np.asarray(X)
    y = np.asarray(y)
    n, p = X.shape
    m = family.n_classes
    lam = np.asarray(lam, dtype=X.dtype)
    assert lam.shape[0] == p * m, "λ must have one entry per coefficient"

    def _b(b):
        # family code works with (p,) for scalar families, (p, m) otherwise
        return b[:, 0] if m == 1 else b

    beta = np.zeros((p, m), dtype=X.dtype)
    grad_full = null_gradient(X, y, family)
    null_dev = float(family.loss(jnp.asarray(X), jnp.asarray(y),
                                 jnp.asarray(_b(beta))))

    if sigmas is None:
        sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                                 sigma_ratio=sigma_ratio, grad0=grad_full)
    sigmas = np.asarray(sigmas)

    betas = [beta.copy()]
    steps: list[PathStep] = [
        PathStep(float(sigmas[0]), np.zeros(p, bool), 0, 0, 0, 0, null_dev, 0, 0.0)
    ]
    prev_active = np.zeros(p, dtype=bool)
    prev_dev = null_dev
    total_viol = 0

    for step_idx in range(1, len(sigmas)):
        t0 = time.perf_counter()
        sig_prev, sig = float(sigmas[step_idx - 1]), float(sigmas[step_idx])
        lam_next = sig * lam
        n_screened = p
        strong_mask = np.ones(p, dtype=bool)

        if screening != "none":
            k, order = strong_rule(
                jnp.asarray(grad_full), jnp.asarray(sig_prev * lam), jnp.asarray(lam_next)
            )
            kept_flat = np.asarray(order)[: int(k)]
            strong_mask = np.zeros(p, dtype=bool)
            strong_mask[np.unique(kept_flat // m)] = True
            n_screened = int(strong_mask.sum())

        if screening == "strong":
            E = strong_mask | prev_active
        elif screening == "previous":
            E = prev_active.copy()
            if not E.any():
                E = strong_mask.copy()
        else:
            E = np.ones(p, dtype=bool)
        if E.sum() >= 0.5 * p:
            # screening keeps most predictors (n ≳ p regime): solve the full
            # problem — shares one compiled shape with the unscreened path
            E = np.ones(p, dtype=bool)

        viol_count = 0
        refits = 0
        iters_total = 0
        checked_full = False
        while True:
            E_idx = np.nonzero(E)[0]
            width = max(len(E_idx), 1)
            bucket = _bucket(width, p)
            Xs = np.zeros((n, bucket), dtype=X.dtype)
            Xs[:, :width] = X[:, E_idx] if len(E_idx) else 0.0
            lam_sub = np.zeros(bucket * m, dtype=lam.dtype)
            lam_sub[: len(E_idx) * m] = lam_next[: len(E_idx) * m]
            warm = np.zeros((bucket, m), dtype=X.dtype)
            if len(E_idx):
                warm[:width] = beta[E_idx]

            res = fista(
                jnp.asarray(Xs),
                jnp.asarray(y),
                jnp.asarray(lam_sub),
                jnp.asarray(warm if m > 1 else warm[:, 0]),
                family,
                max_iter=max_iter,
                tol=solver_tol,
            )
            iters_total += int(res.iters)
            beta_sub = np.asarray(res.beta).reshape(bucket, m)
            beta = np.zeros((p, m), dtype=X.dtype)
            if len(E_idx):
                beta[E_idx] = beta_sub[:width]

            grad_full = np.asarray(
                family.gradient(jnp.asarray(X), jnp.asarray(y),
                                jnp.asarray(_b(beta)))
            ).reshape(p, m)

            if screening == "none":
                break

            ever_flat = np.repeat(E, m)
            if screening == "previous" and not checked_full:
                subset_flat = np.repeat(strong_mask, m)
                viol = kkt_violations(
                    grad_full.ravel(), lam_next, ever_flat, subset_mask=subset_flat, tol=kkt_tol
                )
                if not viol.any():
                    checked_full = True
                    viol = kkt_violations(grad_full.ravel(), lam_next, ever_flat, tol=kkt_tol)
            else:
                viol = kkt_violations(grad_full.ravel(), lam_next, ever_flat, tol=kkt_tol)

            if not viol.any():
                break
            viol_rows = np.unique(np.nonzero(viol)[0] // m)
            # Violations against the *strong* set are the rule's failures
            # (paper §2.2.3); previous-set warm misses are algorithmic.
            viol_count += int((~strong_mask[viol_rows]).sum()) if screening == "strong" else int(
                (~strong_mask[viol_rows] & ~prev_active[viol_rows]).sum()
            )
            E[viol_rows] = True
            refits += 1

        active = np.abs(beta).max(axis=1) > 0
        dev = float(family.loss(jnp.asarray(X), jnp.asarray(y),
                                jnp.asarray(_b(beta))))
        total_viol += viol_count
        betas.append(beta.copy())
        steps.append(
            PathStep(
                sigma=sig,
                active=active,
                n_active=int(active.sum()),
                n_screened=n_screened,
                n_violations=viol_count,
                refits=refits,
                deviance=dev,
                solver_iters=iters_total,
                wall_time=time.perf_counter() - t0,
            )
        )
        prev_active = active
        if verbose:
            print(
                f"[path {step_idx:3d}] σ={sig:.4g} active={int(active.sum()):5d} "
                f"screened={n_screened:5d} viol={viol_count} iters={iters_total}"
            )

        if early_stop and _stop_triggered(beta, dev, prev_dev, null_dev, n):
            prev_dev = dev
            break
        prev_dev = dev

    arr = np.stack(betas)
    if m == 1:
        arr = arr[:, :, 0]
    return PathResult(
        betas=arr,
        sigmas=sigmas[: len(betas)],
        steps=steps,
        lam=lam,
        total_time=time.perf_counter() - t_start,
        total_violations=total_viol,
    )
