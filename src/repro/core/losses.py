"""GLM objectives used in the paper's experiments (§3.2.3): OLS, logistic,
Poisson and multinomial regression.

Every family exposes the loss through its linear predictor z = Xβ:

    f(β) = Σ_i ℓ(z_i, y_i),       ∇f(β) = Xᵀ r(z, y),   r = ∂ℓ/∂z

so the solver and the screening rule only ever need ``value``/``residual``
plus the two matvecs (which are what the Pallas kernels accelerate).
Conventions follow the R SLOPE package: unnormalised sums, centred y for
OLS, y ∈ {0,1} for logistic, y ∈ ℕ for Poisson, integer classes for
multinomial (β ∈ R^{p×m}, penalty on the flattened coefficients).

Per-row sample weights generalize every family without touching X:

    f_w(β) = Σ_i w_i ℓ(z_i, y_i),   ∇f_w(β) = Xᵀ (w ⊙ r(z, y))

which is exactly the loss of the row-duplicated problem when w is an
integer count vector — the representation the resampling engine uses to
solve B bootstrap replicates against ONE shared X.  ``weights=None``
keeps the original (unweighted) code path byte-for-byte.  Zero-weight
rows are guarded with ``jnp.where`` so a w=0 row can never leak a
non-finite z into the sums (0·inf would otherwise NaN the member).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Family", "ols", "logistic", "poisson", "multinomial", "get_family"]


def _row_broadcast(w, a):
    """Broadcast per-row weights (n,) against a row-shaped array: (n,) for
    single-class families, (n, 1) against the (n, m) multinomial block."""
    return w if a.ndim == 1 else w[:, None]


@dataclasses.dataclass(frozen=True)
class Family:
    name: str
    value: Callable  # (z, y) -> scalar loss
    residual: Callable  # (z, y) -> dloss/dz, same shape as z
    hess_bound: float | None  # sup of d²ℓ/dz² (None: use backtracking)
    n_classes: int = 1  # >1 → β is (p, m)
    row_value: Callable | None = None  # (z, y) -> (n,) per-row losses

    def weighted_value(self, z, y, weights):
        """Σ wᵢ ℓ(zᵢ, yᵢ) with zero-weight rows exactly inert (a w=0 row
        contributes an exact 0 even when its z is non-finite)."""
        rv = self.row_value(z, y)
        return jnp.sum(jnp.where(weights == 0, jnp.zeros((), rv.dtype),
                                 weights * rv))

    def weighted_residual(self, z, y, weights):
        """w ⊙ r(z, y), zero-weight rows guarded to exact 0."""
        r = self.residual(z, y)
        wb = _row_broadcast(weights, r)
        return jnp.where(wb == 0, jnp.zeros((), r.dtype), wb * r)

    def loss(self, X, y, beta, weights=None):
        if weights is None:
            return self.value(X @ beta, y)
        return self.weighted_value(X @ beta, y, weights)

    def gradient(self, X, y, beta, weights=None):
        """∇f(β) = Xᵀ r(Xβ, y); shape = beta.shape."""
        if weights is None:
            return X.T @ self.residual(X @ beta, y)
        return X.T @ self.weighted_residual(X @ beta, y, weights)

    def loss_and_gradient(self, X, y, beta, weights=None):
        """(f(β), ∇f(β)) sharing ONE linear predictor z = Xβ.

        Separate ``loss``/``gradient`` calls each build their own Xβ and
        only merge if XLA's CSE happens to fire; this fuses the pair by
        construction, so a FISTA step streams X for z once plus once for
        the Xᵀr matvec.  The Pallas analogue is
        :func:`repro.kernels.slope_loss_residual`.
        """
        z = X @ beta
        if weights is None:
            return self.value(z, y), X.T @ self.residual(z, y)
        return (self.weighted_value(z, y, weights),
                X.T @ self.weighted_residual(z, y, weights))

    def lipschitz(self, X) -> jax.Array:
        """Upper bound on the gradient Lipschitz constant: c·‖X‖₂²."""
        s = _spectral_norm(X)
        c = self.hess_bound if self.hess_bound is not None else 1.0
        return c * s * s


def _spectral_norm(X, iters: int = 30):
    """Power iteration for ‖X‖₂ (deterministic start)."""
    v = jnp.ones((X.shape[1],), X.dtype) / jnp.sqrt(X.shape[1])

    def body(_, v):
        u = X @ v
        u = u / (jnp.linalg.norm(u) + 1e-12)
        w = X.T @ u
        return w / (jnp.linalg.norm(w) + 1e-12)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(X @ v)


# -- OLS --------------------------------------------------------------------

def _ols_value(z, y):
    return 0.5 * jnp.sum(jnp.square(z - y))


def _ols_residual(z, y):
    return z - y


def _ols_row_value(z, y):
    return 0.5 * jnp.square(z - y)


ols = Family("ols", _ols_value, _ols_residual, hess_bound=1.0,
             row_value=_ols_row_value)


# -- logistic (y ∈ {0,1}) ----------------------------------------------------

def _logit_value(z, y):
    # Σ log(1 + e^z) − y z, numerically stable
    return jnp.sum(jnp.logaddexp(0.0, z) - y * z)


def _logit_residual(z, y):
    return jax.nn.sigmoid(z) - y


def _logit_row_value(z, y):
    return jnp.logaddexp(0.0, z) - y * z


logistic = Family("logistic", _logit_value, _logit_residual, hess_bound=0.25,
                  row_value=_logit_row_value)


# -- Poisson -----------------------------------------------------------------

def _pois_value(z, y):
    return jnp.sum(jnp.exp(z) - y * z)


def _pois_residual(z, y):
    return jnp.exp(z) - y


def _pois_row_value(z, y):
    return jnp.exp(z) - y * z


poisson = Family("poisson", _pois_value, _pois_residual, hess_bound=None,
                 row_value=_pois_row_value)


# -- multinomial (y integer classes, β ∈ R^{p×m}) ----------------------------

def _multi_value(Z, y):
    return jnp.sum(jax.nn.logsumexp(Z, axis=-1) - jnp.take_along_axis(Z, y[:, None], axis=-1)[:, 0])


def _multi_residual(Z, y):
    m = Z.shape[-1]
    return jax.nn.softmax(Z, axis=-1) - jax.nn.one_hot(y, m, dtype=Z.dtype)


def _multi_row_value(Z, y):
    return (jax.nn.logsumexp(Z, axis=-1)
            - jnp.take_along_axis(Z, y[:, None], axis=-1)[:, 0])


def multinomial(m: int) -> Family:
    return Family("multinomial", _multi_value, _multi_residual, hess_bound=0.5,
                  n_classes=m, row_value=_multi_row_value)


def get_family(name: str, n_classes: int = 3) -> Family:
    if name == "multinomial":
        return multinomial(n_classes)
    fam = {"ols": ols, "logistic": logistic, "poisson": poisson}.get(name)
    if fam is None:
        raise ValueError(f"unknown family {name!r}")
    return fam
