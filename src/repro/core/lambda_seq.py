"""Penalty sequences and path parameterization (paper §3.1.1–§3.1.2).

All sequences are returned *unscaled*; the path multiplies them by σ, with
σ(1) chosen so the first path point gives the all-zero solution:

    σ(1) = max( cumsum(|∇f(0)|↓) ⊘ cumsum(λ) )

which is exactly the dual gauge of ∇f(0) (see sorted_l1.dual_sorted_l1_gauge).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

from .sorted_l1 import dual_sorted_l1_gauge

__all__ = [
    "bh_sequence",
    "gaussian_sequence",
    "oscar_sequence",
    "lasso_sequence",
    "path_start_sigma",
    "sigma_grid",
]


def bh_sequence(p: int, q: float = 0.1, dtype=jnp.float64) -> jax.Array:
    """Benjamini–Hochberg sequence: λ_i = Φ⁻¹(1 − q·i/(2p))."""
    i = jnp.arange(1, p + 1, dtype=dtype)
    lam = ndtri(1 - q * i / (2 * p))
    return jnp.maximum(lam, 0)


def gaussian_sequence(p: int, n: int, q: float = 0.1, dtype=np.float64):
    """Gaussian-adjusted BH sequence (paper §3.1.1).

    λG_1 = λBH_1;  λG_i = λBH_i · sqrt(1 + Σ_{j<i}(λG_j)² / (n − i)),
    truncated to the previous value once the sequence starts increasing
    (and undefined at i = n, handled by the same truncation).
    Host-side NumPy: the recursion is inherently sequential and tiny.
    """
    bh = np.asarray(bh_sequence(p, q, dtype=jnp.float64))
    lam = np.empty(p, dtype=dtype)
    lam[0] = bh[0]
    acc = 0.0
    for i in range(1, p):
        acc += lam[i - 1] ** 2
        denom = n - (i + 1)  # 1-based i in the paper
        if denom <= 0:
            lam[i:] = lam[i - 1]
            break
        cand = bh[i] * np.sqrt(1 + acc / denom)
        if cand > lam[i - 1]:
            lam[i:] = lam[i - 1]
            break
        lam[i] = cand
    return jnp.asarray(lam)


def oscar_sequence(p: int, q: float = 0.1, dtype=jnp.float64) -> jax.Array:
    """OSCAR linear sequence λ_i = q(p − i) + 1 (paper §3.1.1, single-param)."""
    i = jnp.arange(1, p + 1, dtype=dtype)
    return q * (p - i) + 1


def lasso_sequence(p: int, dtype=jnp.float64) -> jax.Array:
    """Constant sequence — SLOPE degenerates to the lasso (Proposition 3)."""
    return jnp.ones((p,), dtype=dtype)


def path_start_sigma(grad0: jax.Array, lam: jax.Array) -> jax.Array:
    """σ(1): smallest σ with β̂ = 0, i.e. max(cumsum(|∇f(0)|↓) ⊘ cumsum(σλ)) = 1."""
    return dual_sorted_l1_gauge(grad0, lam)


def sigma_grid(sigma_max: float, *, length: int = 100, ratio: float | None = None,
               n: int | None = None, p: int | None = None) -> np.ndarray:
    """Geometric grid σ(1) … σ(l).  Paper: σ(l) = t·σ(1), t = 1e-2 if n < p
    else 1e-4 (§3.1.2)."""
    if ratio is None:
        if n is None or p is None:
            ratio = 1e-2
        else:
            ratio = 1e-2 if n < p else 1e-4
    return sigma_max * np.logspace(0, np.log10(ratio), num=length)
