"""Device-resident batched path engine (Algorithms 3/4 under one jit scope).

The host driver in :mod:`repro.core.path` orchestrates one path step at a
time from NumPy: gather the screened columns, pad to a bucket, dispatch a
FISTA solve, pull the gradient back, check KKT, repeat.  That is the right
trade for a *single* huge p ≫ n problem — the gathers shrink every matvec —
but it round-trips host↔device at every step, and it can only fit one
(X, y) problem at a time.

This module moves the whole per-step loop onto the device:

* the path is a ``lax.scan`` over σ-grid points;
* working sets are *masks*, not gathers — :func:`repro.core.solver.fista_masked`
  zeroes masked columns so the sub-problem keeps one static shape, and
  :func:`repro.core.screening.screen_masked` /
  :func:`repro.core.kkt.kkt_violations_masked` run the strong rule and the
  KKT guard on the same masked representation;
* KKT repair is a bounded ``lax.while_loop`` inside each scan step;
* a ``vmap`` batching layer fits B independent problems — CV folds,
  bootstrap replicates, a batch of user requests — in ONE compiled program.

Shape policy: one compilation per static (B, n, p, m, L, config) bucket.
The batching wrappers stack problems of identical shape; callers with mixed
shapes bucket on the host (pad n with zero rows / p with zero columns) —
zero columns are inert in every family, zero rows are inert for OLS.

Everything here returns the *full* σ grid (a scan cannot truncate); the
host front-end applies the paper's early-stopping rules post-hoc when a
:class:`repro.core.path.PathResult` is requested.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .kkt import kkt_violations_masked
from .lambda_seq import path_start_sigma, sigma_grid
from .losses import Family
from .screening import screen_masked
from .solver import default_L0, fista_compact, fista_masked

__all__ = [
    "EnginePath",
    "CompactStats",
    "path_engine",
    "batched_path_engine",
    "compact_path_engine",
    "fit_path_batched",
    "cv_path",
    "null_gradient",
    "null_sigma_grid",
    "BatchedPathResult",
    "CvPathResult",
]


class EnginePath(NamedTuple):
    """Raw device arrays for one fitted path (leading axis = path point)."""

    betas: jax.Array          # (L, p, m)
    n_active: jax.Array       # (L,) int32
    n_screened: jax.Array     # (L,) int32
    n_violations: jax.Array   # (L,) int32
    refits: jax.Array         # (L,) int32
    solver_iters: jax.Array   # (L,) int32
    deviance: jax.Array       # (L,)
    kkt_unrepaired: jax.Array  # (L,) bool — repair loop hit max_refits
    #   with violations outstanding; the step's betas are NOT KKT-clean


class CompactStats(NamedTuple):
    """Per-step compact-engine telemetry (leading axes = problem, path point)."""

    ws_size: jax.Array    # (B, L) int32 — peak working-set demand |E| per step
    fell_back: jax.Array  # (B, L) bool — step ran the masked full-width
    #   fallback because some batch member's |E| exceeded the W bucket


# ---------------------------------------------------------------------------
# Per-problem step primitives, shared by the masked and compact engines
# ---------------------------------------------------------------------------

def _screen_sets(grad, prev_active, sig_prev, sig, lam, *, p, m, screening):
    """Strong set + initial working set E₀ for one path step (one problem)."""
    pm = p * m
    gap = (sig_prev - sig) * lam  # rank-space surrogate shift
    keep_flat, _ = screen_masked(jnp.abs(grad.reshape(pm)), sig * lam,
                                 jnp.ones((pm,), bool), gap)
    strong_p = keep_flat.reshape(p, m).any(axis=1)
    n_screened = strong_p.sum().astype(jnp.int32)
    if screening == "strong":
        E0 = strong_p | prev_active
    else:  # "previous" (Algorithm 4)
        E0 = jnp.where(prev_active.any(), prev_active, strong_p)
    # mirror the host driver: once screening keeps most predictors
    # (n ≳ p regime) just solve the full problem — keeps violation
    # accounting identical between backends
    E0 = jnp.where(E0.sum() >= 0.5 * p, jnp.ones((p,), bool), E0)
    return strong_p, E0, n_screened


def _kkt_step(grad, lam_next, E, strong_p, checked_full, *, p, m, kkt_tol,
              screening):
    """KKT violation mask for one problem; see Algorithms 3/4."""
    pm = p * m
    gflat = grad.reshape(pm)
    ever = jnp.repeat(E, m)
    ones_pm = jnp.ones((pm,), bool)
    viol_full = kkt_violations_masked(gflat, lam_next, ever, ones_pm,
                                      tol=kkt_tol)
    if screening != "previous":
        return viol_full, checked_full
    # Algorithm 4: check the strong set first; only once it is clean,
    # graduate (permanently) to full-set checks.
    subset = jnp.repeat(strong_p, m)
    viol_sub = kkt_violations_masked(gflat, lam_next, ever, subset,
                                     tol=kkt_tol)
    pre = ~checked_full
    sub_has = viol_sub.any()
    viol = jnp.where(pre & sub_has, viol_sub, viol_full)
    return viol, checked_full | (pre & ~sub_has)


def _new_violations(viol_flat, strong_p, prev_active, *, p, m, screening):
    """Count the rule's failures: violations against the *strong* set
    (paper §2.2.3); previous-set warm misses are algorithmic."""
    rows = viol_flat.reshape(p, m).any(axis=1)
    miss = rows & ~strong_p
    if screening == "previous":
        miss = miss & ~prev_active
    return miss.sum().astype(jnp.int32)


def _engine(X, y, lam, sigmas, family: Family, screening, max_iter, tol,
            kkt_tol, max_refits) -> EnginePath:
    """Traced body shared by :func:`path_engine` and the vmapped batch form."""
    n, p = X.shape
    m = family.n_classes
    dtype = X.dtype
    lam = lam.astype(dtype)

    def fam_shape(b):  # (p, m) -> the shape the family callbacks expect
        return b[:, 0] if m == 1 else b

    def lift(b):  # family shape -> (p, m)
        return b[:, None] if m == 1 else b

    zeros = jnp.zeros((p, m), dtype)
    grad0 = lift(family.gradient(X, y, fam_shape(zeros)))
    null_dev = family.loss(X, y, fam_shape(zeros))

    def solve(E, lam_next, beta, L):
        # The stack PAVA prox is a p·m-length sequential loop — under vmap
        # every batch member pays the slowest member's pooling in lockstep.
        # The sweep-merging prox is a handful of dense ops per sweep, so it
        # batches with near-perfect efficiency.  L is the curvature estimate
        # carried from the previous solve — device-resident state the host
        # driver cannot keep, which skips the backtracking ramp-up.
        res = fista_masked(X, y, lam_next, fam_shape(beta), E, family,
                           max_iter=max_iter, tol=tol,
                           prox_method="parallel", L0=L)
        beta_new = lift(res.beta)
        grad = lift(family.gradient(X, y, fam_shape(beta_new)))
        return beta_new, grad, res.iters.astype(jnp.int32), res.L

    kkt_check = functools.partial(_kkt_step, p=p, m=m, kkt_tol=kkt_tol,
                                  screening=screening)
    count_viol = functools.partial(_new_violations, p=p, m=m,
                                   screening=screening)

    def step(carry, sigs):
        beta, grad, prev_active, L_carry = carry
        sig_prev, sig = sigs
        lam_next = sig * lam

        if screening == "none":
            strong_p = jnp.ones((p,), bool)
            E0 = strong_p
            n_screened = jnp.int32(p)
        else:
            strong_p, E0, n_screened = _screen_sets(
                grad, prev_active, sig_prev, sig, lam, p=p, m=m,
                screening=screening)

        beta1, grad1, it1, L1 = solve(E0, lam_next, beta, L_carry)

        if screening == "none":
            beta_f, grad_f, L_f = beta1, grad1, L1
            viol_count = jnp.int32(0)
            refits = jnp.int32(0)
            iters = it1
            unrepaired = jnp.bool_(False)
        else:
            viol1, checked1 = kkt_check(grad1, lam_next, E0, strong_p,
                                        jnp.bool_(False))
            state = dict(
                beta=beta1, grad=grad1, L=L1,
                E=E0 | viol1.reshape(p, m).any(axis=1),
                checked=checked1, has_viol=viol1.any(),
                viol_count=count_viol(viol1, strong_p, prev_active),
                refits=jnp.int32(0), iters=it1,
            )

            def cond(s):
                return s["has_viol"] & (s["refits"] < max_refits)

            def body(s):
                beta2, grad2, it2, L2 = solve(s["E"], lam_next, s["beta"],
                                              s["L"])
                viol2, checked2 = kkt_check(grad2, lam_next, s["E"],
                                            strong_p, s["checked"])
                return dict(
                    beta=beta2, grad=grad2, L=L2,
                    E=s["E"] | viol2.reshape(p, m).any(axis=1),
                    checked=checked2, has_viol=viol2.any(),
                    viol_count=s["viol_count"]
                    + count_viol(viol2, strong_p, prev_active),
                    refits=s["refits"] + 1, iters=s["iters"] + it2,
                )

            state = lax.while_loop(cond, body, state)
            beta_f, grad_f, L_f = state["beta"], state["grad"], state["L"]
            viol_count = state["viol_count"]
            refits = state["refits"]
            iters = state["iters"]
            unrepaired = state["has_viol"]  # loop exited on the refit cap

        active = (jnp.abs(beta_f) > 0).any(axis=1)
        dev = family.loss(X, y, fam_shape(beta_f))
        out = (beta_f, active.sum().astype(jnp.int32), n_screened, viol_count,
               refits, iters, dev, unrepaired)
        return (beta_f, grad_f, active, L_f), out

    L_init = default_L0(X, family).astype(dtype)
    carry0 = (zeros, grad0, jnp.zeros((p,), bool), L_init)
    _, outs = lax.scan(step, carry0, (sigmas[:-1], sigmas[1:]))
    betas, n_act, n_scr, viol, refits, iters, devs, unrep = outs

    def pre(a, v):
        return jnp.concatenate([jnp.asarray(v, a.dtype)[None], a])

    return EnginePath(
        betas=jnp.concatenate([zeros[None], betas]),
        n_active=pre(n_act, 0),
        n_screened=pre(n_scr, 0),
        n_violations=pre(viol, 0),
        refits=pre(refits, 0),
        solver_iters=pre(iters, 0),
        deviance=pre(devs, null_dev),
        kkt_unrepaired=pre(unrep, False),
    )


_ENGINE_STATICS = ("family", "screening", "max_iter", "tol", "kkt_tol",
                   "max_refits")


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def path_engine(X, y, lam, sigmas, family: Family, *, screening: str = "strong",
                max_iter: int = 5000, tol: float = 1e-8,
                kkt_tol: float = 1e-4, max_refits: int = 32) -> EnginePath:
    """Fit one full SLOPE path entirely on device (fixed σ grid, no early
    stop).  One compilation per (n, p, m, len(sigmas), config)."""
    return _engine(X, y, lam, sigmas, family, screening, max_iter, tol,
                   kkt_tol, max_refits)


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def batched_path_engine(X, y, lam, sigmas, family: Family, *,
                        screening: str = "strong", max_iter: int = 5000,
                        tol: float = 1e-8, kkt_tol: float = 1e-4,
                        max_refits: int = 32) -> EnginePath:
    """vmap of :func:`path_engine` over the leading problem axis.

    ``X``: (B, n, p); ``y``: (B, n[, ...]); ``sigmas``: (B, L); ``lam`` is
    shared (SLOPE's λ is a rank sequence, not per-problem data).  Returns an
    :class:`EnginePath` whose arrays carry a leading batch axis.
    """

    def one(Xi, yi, si):
        return _engine(Xi, yi, lam, si, family, screening, max_iter, tol,
                       kkt_tol, max_refits)

    return jax.vmap(one)(X, y, sigmas)


def _compact_engine(X, y, lam, sigmas, family: Family, screening, max_iter,
                    tol, kkt_tol, max_refits, width):
    """Natively-batched compact-working-set engine.

    Identical per-step semantics to ``vmap(_engine)`` with one structural
    difference: the batch axis is threaded through the *data* while control
    flow stays **scalar**.  That lets the overflow check reduce over the
    batch (``any(|E| > W)``) before the ``lax.cond`` that picks between the
    compact O(n·W) solve and the masked O(n·p) fallback — a per-member cond
    under ``vmap`` would lower to ``lax.select`` and execute BOTH branches,
    erasing the compact win.  The price: if any one batch member overflows
    the W bucket, the whole batch pays the masked solve for that repair
    round (conservative, correct, and rare once W is bucketed right).
    """
    B, n, p = X.shape
    m = family.n_classes
    dtype = X.dtype
    lam = lam.astype(dtype)
    W = width

    def fam_shape(b):  # (p, m) -> the shape the family callbacks expect
        return b[:, 0] if m == 1 else b

    def lift(b):  # family shape -> (p, m)
        return b[:, None] if m == 1 else b

    zeros1 = jnp.zeros((p, m), dtype)

    def grad_one(Xi, yi, beta):
        return lift(family.gradient(Xi, yi, fam_shape(beta)))

    def dev_one(Xi, yi, beta):
        return family.loss(Xi, yi, fam_shape(beta))

    grad0 = jax.vmap(lambda Xi, yi: grad_one(Xi, yi, zeros1))(X, y)
    null_dev = jax.vmap(lambda Xi, yi: dev_one(Xi, yi, zeros1))(X, y)

    solver_kw = dict(max_iter=max_iter, tol=tol, prox_method="parallel")

    def solve_masked_one(Xi, yi, lam_next, beta, E, L):
        res = fista_masked(Xi, yi, lam_next, fam_shape(beta), E, family,
                           L0=L, **solver_kw)
        return lift(res.beta), res.iters.astype(jnp.int32), res.L

    def solve_compact_one(Xi, yi, lam_next, beta, E, L):
        res = fista_compact(Xi, yi, lam_next, fam_shape(beta), E, family,
                            width=W, L0=L, **solver_kw)
        return lift(res.beta), res.iters.astype(jnp.int32), res.L

    def solve_all(E, lam_next, beta, L):
        need = E.sum(axis=1).astype(jnp.int32)
        fell_back = jnp.any(need > W)  # scalar — keeps the cond a real branch
        beta1, it1, L1 = lax.cond(
            fell_back,
            lambda args: jax.vmap(solve_masked_one)(X, y, *args),
            lambda args: jax.vmap(solve_compact_one)(X, y, *args),
            (lam_next, beta, E, L),
        )
        grad1 = jax.vmap(grad_one)(X, y, beta1)
        return beta1, grad1, it1, L1, fell_back, need

    kkt_one = functools.partial(_kkt_step, p=p, m=m, kkt_tol=kkt_tol,
                                screening=screening)
    nv_one = functools.partial(_new_violations, p=p, m=m, screening=screening)
    screen_one = functools.partial(_screen_sets, p=p, m=m, screening=screening)

    def step(carry, sigs):
        beta, grad, prev_active, L_carry = carry
        sig_prev, sig = sigs                      # (B,), (B,)
        lam_next = sig[:, None] * lam[None, :]    # (B, p·m)

        if screening == "none":
            strong_p = jnp.ones((B, p), bool)
            E0 = strong_p
            n_screened = jnp.full((B,), p, jnp.int32)
        else:
            strong_p, E0, n_screened = jax.vmap(
                screen_one, in_axes=(0, 0, 0, 0, None)
            )(grad, prev_active, sig_prev, sig, lam)

        beta1, grad1, it1, L1, fb1, need1 = solve_all(E0, lam_next, beta,
                                                      L_carry)

        if screening == "none":
            beta_f, grad_f, L_f = beta1, grad1, L1
            viol_count = jnp.zeros((B,), jnp.int32)
            refits = jnp.zeros((B,), jnp.int32)
            iters = it1
            unrepaired = jnp.zeros((B,), bool)
            fell_back = fb1
            ws_max = need1
        else:
            viol1, checked1 = jax.vmap(kkt_one)(grad1, lam_next, E0, strong_p,
                                                jnp.zeros((B,), bool))
            state = dict(
                beta=beta1, grad=grad1, L=L1,
                E=E0 | viol1.reshape(B, p, m).any(axis=2),
                checked=checked1,
                has_viol=viol1.reshape(B, -1).any(axis=1),
                viol_count=jax.vmap(nv_one)(viol1, strong_p, prev_active),
                refits=jnp.zeros((B,), jnp.int32), iters=it1,
                fell_back=fb1, ws_max=need1,
            )

            def cond(s):
                return jnp.any(s["has_viol"] & (s["refits"] < max_refits))

            def body(s):
                # members already KKT-clean keep their state (mirrors the
                # per-member select vmap applies to a batched while_loop).
                # Their E is blanked for this round so only members still
                # repairing count toward the overflow predicate — their
                # (discarded) solve must not force the masked fallback.
                active = s["has_viol"] & (s["refits"] < max_refits)
                beta2, grad2, it2, L2, fb2, need2 = solve_all(
                    s["E"] & active[:, None], lam_next, s["beta"], s["L"])
                viol2, checked2 = jax.vmap(kkt_one)(grad2, lam_next, s["E"],
                                                    strong_p, s["checked"])

                def sel(new, old):
                    a = active.reshape((B,) + (1,) * (new.ndim - 1))
                    return jnp.where(a, new, old)

                viol_rows = viol2.reshape(B, p, m).any(axis=2)
                return dict(
                    beta=sel(beta2, s["beta"]),
                    grad=sel(grad2, s["grad"]),
                    L=sel(L2, s["L"]),
                    E=sel(s["E"] | viol_rows, s["E"]),
                    checked=sel(checked2, s["checked"]),
                    has_viol=sel(viol2.reshape(B, -1).any(axis=1),
                                 s["has_viol"]),
                    viol_count=s["viol_count"] + jnp.where(
                        active, jax.vmap(nv_one)(viol2, strong_p, prev_active),
                        0),
                    refits=s["refits"] + active.astype(jnp.int32),
                    iters=s["iters"] + jnp.where(active, it2, 0),
                    fell_back=s["fell_back"] | fb2,
                    ws_max=jnp.maximum(s["ws_max"], need2),
                )

            state = lax.while_loop(cond, body, state)
            beta_f, grad_f, L_f = state["beta"], state["grad"], state["L"]
            viol_count = state["viol_count"]
            refits = state["refits"]
            iters = state["iters"]
            unrepaired = state["has_viol"]  # loop exited on the refit cap
            fell_back = state["fell_back"]
            ws_max = state["ws_max"]

        active = (jnp.abs(beta_f) > 0).any(axis=2)
        dev = jax.vmap(dev_one)(X, y, beta_f)
        out = (beta_f, active.sum(axis=1).astype(jnp.int32), n_screened,
               viol_count, refits, iters, dev, unrepaired, ws_max,
               fell_back & jnp.ones((B,), bool))
        return (beta_f, grad_f, active, L_f), out

    L_init = jax.vmap(lambda Xi: default_L0(Xi, family))(X).astype(dtype)
    carry0 = (jnp.zeros((B, p, m), dtype), grad0, jnp.zeros((B, p), bool),
              L_init)
    xs = (sigmas[:, :-1].T, sigmas[:, 1:].T)  # scan over the path axis
    _, outs = lax.scan(step, carry0, xs)
    betas, n_act, n_scr, viol, refits, iters, devs, unrep, ws, fb = outs

    def pre(a, v):
        a = jnp.moveaxis(a, 0, 1)  # (L-1, B, ...) -> (B, L-1, ...)
        v = jnp.broadcast_to(jnp.asarray(v, a.dtype),
                             (a.shape[0],) + a.shape[2:])
        return jnp.concatenate([v[:, None], a], axis=1)

    ep = EnginePath(
        betas=pre(betas, jnp.zeros((p, m), dtype)),
        n_active=pre(n_act, 0),
        n_screened=pre(n_scr, 0),
        n_violations=pre(viol, 0),
        refits=pre(refits, 0),
        solver_iters=pre(iters, 0),
        deviance=jnp.concatenate([null_dev[:, None],
                                  jnp.moveaxis(devs, 0, 1)], axis=1),
        kkt_unrepaired=pre(unrep, False),
    )
    stats = CompactStats(ws_size=pre(ws, 0), fell_back=pre(fb, False))
    return ep, stats


_COMPACT_STATICS = _ENGINE_STATICS + ("width",)


@functools.partial(jax.jit, static_argnames=_COMPACT_STATICS)
def compact_path_engine(X, y, lam, sigmas, family: Family, *, width: int,
                        screening: str = "strong", max_iter: int = 5000,
                        tol: float = 1e-8, kkt_tol: float = 1e-4,
                        max_refits: int = 32):
    """Batched path engine with working sets compacted to a static ``width``
    bucket: the inner solve costs O(n·W) instead of O(n·p), with a batch-wide
    ``lax.cond`` fallback to the masked full-width solve on overflow.

    ``X``: (B, n, p); ``y``: (B, n[, ...]); ``sigmas``: (B, L); ``lam``
    shared.  Returns ``(EnginePath, CompactStats)`` with leading batch axes.
    One compilation per (B, n, p, m, L, W, config).
    """
    return _compact_engine(X, y, lam, sigmas, family, screening, max_iter,
                           tol, kkt_tol, max_refits, width)


# ---------------------------------------------------------------------------
# Host-facing wrappers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPathResult:
    """B paths fitted by one compiled program (leading axis = problem)."""

    betas: np.ndarray         # (B, L, p) or (B, L, p, m)
    sigmas: np.ndarray        # (B, L)
    lam: np.ndarray
    n_active: np.ndarray      # (B, L)
    n_screened: np.ndarray
    n_violations: np.ndarray
    refits: np.ndarray
    solver_iters: np.ndarray
    deviance: np.ndarray
    kkt_unrepaired: np.ndarray  # (B, L) bool — see EnginePath.kkt_unrepaired
    total_time: float
    n_samples: int            # rows per problem (early-stop rules need it)
    working_set: int | None = None        # W bucket (None: masked engine)
    ws_size: np.ndarray | None = None     # (B, L) peak |E| per step
    compact_fallback: np.ndarray | None = None  # (B, L) masked-fallback steps

    @property
    def batch(self) -> int:
        return self.betas.shape[0]

    @property
    def total_violations(self) -> np.ndarray:
        return self.n_violations.sum(axis=1)

    def path_results(self, *, early_stop: bool = True):
        """Per-problem :class:`repro.core.path.PathResult` views (the same
        contract the unbatched driver returns, early stopping applied
        post-hoc)."""
        from .path import engine_to_path_result  # lazy: avoid import cycle

        per = self.total_time / self.batch
        return [
            engine_to_path_result(
                EnginePath(
                    betas=self.betas[b] if self.betas.ndim == 4
                    else self.betas[b][:, :, None],
                    n_active=self.n_active[b],
                    n_screened=self.n_screened[b],
                    n_violations=self.n_violations[b],
                    refits=self.refits[b],
                    solver_iters=self.solver_iters[b],
                    deviance=self.deviance[b],
                    kkt_unrepaired=self.kkt_unrepaired[b],
                ),
                self.sigmas[b], self.lam, per, early_stop=early_stop,
                n=self.n_samples,
            )
            for b in range(self.batch)
        ]


def null_gradient(X, y, family: Family) -> np.ndarray:
    """∇f(0) reshaped to (p, m) — the quantity both the σ-grid recipe and
    the first strong-rule step start from."""
    p = X.shape[1]
    m = family.n_classes
    beta0 = jnp.zeros((p,) if m == 1 else (p, m), X.dtype)
    return np.asarray(
        family.gradient(jnp.asarray(X), jnp.asarray(y), beta0)
    ).reshape(p, m)


def null_sigma_grid(X, y, lam, family: Family, *, path_length: int,
                    sigma_ratio: float | None,
                    grad0: np.ndarray | None = None) -> np.ndarray:
    """The paper's σ grid for one problem: σ(1) from the null gradient's
    dual gauge, geometric decay per §3.1.2.  The ONE recipe shared by
    fit_path (both backends), fit_path_batched and cv_path."""
    if grad0 is None:
        grad0 = null_gradient(X, y, family)
    s1 = float(path_start_sigma(jnp.asarray(grad0), jnp.asarray(lam)))
    n, p = X.shape
    return sigma_grid(s1, length=path_length, ratio=sigma_ratio, n=n, p=p)


def _null_sigma_grids(Xs, ys, lam, family: Family, path_length, sigma_ratio):
    """Per-problem σ grids (stacked :func:`null_sigma_grid`)."""
    return np.stack([
        null_sigma_grid(Xs[b], ys[b], lam, family, path_length=path_length,
                        sigma_ratio=sigma_ratio)
        for b in range(Xs.shape[0])
    ])


# Grow-on-overflow bucket memory: (n, p, m, family, screening) → last W that
# overflowed, promoted to the next power of two.  Correctness never depends
# on it (overflow steps fall back to the masked solve in-graph); it just
# stops the NEXT same-shape call from paying the fallback again.
_WS_BUCKETS: dict[tuple, int] = {}


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def _ws_bucket(working_set, n: int, p: int, key: tuple) -> int:
    """Resolve the static compact width W to a power-of-two bucket ≤ p."""
    if isinstance(working_set, int):
        if working_set < 1:
            raise ValueError(f"working_set must be ≥ 1, got {working_set}")
        return min(_next_pow2(working_set), p)
    if working_set != "auto":
        raise ValueError(
            f"working_set must be None, an int or 'auto', got {working_set!r}")
    if key in _WS_BUCKETS:
        return min(_WS_BUCKETS[key], p)
    # p ≫ n: the screened set tracks the active set, which cannot exceed n
    # useful coefficients by much — 2n is a comfortable first bucket
    return min(_next_pow2(max(2 * n, 64)), p)


def fit_path_batched(
    Xs, ys, lam, family: Family, *,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = 1e-8,
    max_iter: int = 5000,
    kkt_tol: float = 1e-4,
    max_refits: int = 32,
    working_set: int | str | None = None,
) -> BatchedPathResult:
    """Fit B independent SLOPE paths in one compiled device program.

    ``Xs`` is (B, n, p) and ``ys`` (B, n) — problems of identical shape share
    one compilation (the bucketing policy: pad mixed shapes on the host).
    Semantics match ``fit_path(..., engine="device")`` per problem.  Steps
    whose KKT repair hit ``max_refits`` are flagged in ``kkt_unrepaired``
    (and warned about) — raise the cap if that ever fires.

    ``working_set`` selects the compact engine: an int requests a static
    width bucket W (rounded up to a power of two, capped at p), ``"auto"``
    picks ``min(2^⌈log₂ max(2n, 64)⌉, p)`` with grow-on-overflow memory, and
    ``None`` keeps the masked full-width engine.  Compact solves cost
    O(n·W) per FISTA iteration; any step where a batch member's working set
    outgrows W falls back — correctly, in-graph — to the masked solve and
    is flagged in ``compact_fallback``.
    """
    Xs = np.asarray(Xs)
    ys = np.asarray(ys)
    if Xs.ndim != 3:
        raise ValueError(f"Xs must be (B, n, p), got {Xs.shape}")
    if ys.shape[:2] != Xs.shape[:2]:
        raise ValueError(
            f"ys must be (B, n[, ...]) matching Xs {Xs.shape[:2]}, got {ys.shape}")
    lam = np.asarray(lam)
    if sigmas is None:
        sigmas = _null_sigma_grids(Xs, ys, lam, family, path_length,
                                   sigma_ratio)
    sigmas = np.asarray(sigmas)
    B = Xs.shape[0]
    if sigmas.ndim == 1:  # one shared grid, like fit_path's 1-D sigmas
        sigmas = np.tile(sigmas, (B, 1))
    if sigmas.shape[0] != B or sigmas.ndim != 2:
        raise ValueError(
            f"sigmas must be (L,) shared or (B, L) per-problem; got "
            f"{sigmas.shape} for B={B}")

    n, p = Xs.shape[1], Xs.shape[2]
    engine_kw = dict(screening=screening, max_iter=max_iter, tol=solver_tol,
                     kkt_tol=kkt_tol, max_refits=max_refits)
    t0 = time.perf_counter()
    W = None
    stats = None
    if working_set is None:
        res = batched_path_engine(
            jnp.asarray(Xs), jnp.asarray(ys), jnp.asarray(lam),
            jnp.asarray(sigmas), family, **engine_kw)
    else:
        ws_key = (n, p, family.n_classes, family.name, screening)
        W = _ws_bucket(working_set, n, p, ws_key)
        res, stats = compact_path_engine(
            jnp.asarray(Xs), jnp.asarray(ys), jnp.asarray(lam),
            jnp.asarray(sigmas), family, width=W, **engine_kw)
    betas = np.asarray(res.betas)  # (B, L, p, m)
    wall = time.perf_counter() - t0
    if family.n_classes == 1:
        betas = betas[:, :, :, 0]
    unrepaired = np.asarray(res.kkt_unrepaired)
    _warn_unrepaired(unrepaired, max_refits)
    ws_size = fallback = None
    if stats is not None:
        ws_size = np.asarray(stats.ws_size)
        fallback = np.asarray(stats.fell_back)
        # grow the bucket for the next same-shape "auto" call; explicit-int
        # runs (e.g. a deliberately undersized overflow probe) must not
        # seed "auto" with a bucket below its documented default
        if working_set == "auto" and fallback.any() and W < p:
            _WS_BUCKETS[ws_key] = min(_next_pow2(int(ws_size.max())), p)
    return BatchedPathResult(
        betas=betas,
        sigmas=sigmas,
        lam=lam,
        n_active=np.asarray(res.n_active),
        n_screened=np.asarray(res.n_screened),
        n_violations=np.asarray(res.n_violations),
        refits=np.asarray(res.refits),
        solver_iters=np.asarray(res.solver_iters),
        deviance=np.asarray(res.deviance),
        kkt_unrepaired=unrepaired,
        total_time=wall,
        n_samples=n,
        working_set=W,
        ws_size=ws_size,
        compact_fallback=fallback,
    )


def _warn_unrepaired(unrepaired: np.ndarray, max_refits: int) -> None:
    if unrepaired.any():
        import warnings

        warnings.warn(
            f"{int(unrepaired.sum())} path step(s) hit the KKT repair cap "
            f"(max_refits={max_refits}) with violations outstanding; those "
            "betas are not KKT-clean — raise max_refits",
            RuntimeWarning,
            stacklevel=3,
        )


@dataclasses.dataclass
class CvPathResult:
    """K-fold cross-validation over one shared σ grid."""

    sigmas: np.ndarray            # (L,) shared grid
    lam: np.ndarray
    val_deviance: np.ndarray      # (K, L) held-out deviance per fold
    mean_val_deviance: np.ndarray  # (L,)
    best_index: int
    best_sigma: float
    fold_paths: BatchedPathResult
    total_time: float


def cv_path(
    X, y, lam, family: Family, *,
    n_folds: int = 5,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    solver_tol: float = 1e-8,
    max_iter: int = 5000,
    kkt_tol: float = 1e-4,
    max_refits: int = 32,
    working_set: int | str | None = None,
) -> CvPathResult:
    """K-fold CV: all fold paths fit as ONE batched device program.

    Folds are contiguous blocks of ⌊n/K⌋ rows (remainder rows are always in
    training) so every training design has the same shape and the folds
    batch into a single compilation.  The σ grid is computed once from the
    full data and shared, so every fold is evaluated at the same penalty.
    ``working_set`` selects the compact engine exactly as in
    :func:`fit_path_batched` — the natural fit for CV's p ≫ n folds.
    """
    t0 = time.perf_counter()
    X = np.asarray(X)
    y = np.asarray(y)
    n = X.shape[0]
    lam = np.asarray(lam)
    if not 2 <= n_folds <= n:
        raise ValueError(f"n_folds must be in [2, {n}], got {n_folds}")
    fold = n // n_folds

    sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                             sigma_ratio=sigma_ratio)

    Xs, ys_tr, vals = [], [], []
    for k in range(n_folds):
        val = np.arange(k * fold, (k + 1) * fold)
        train = np.setdiff1d(np.arange(n), val)
        Xs.append(X[train])
        ys_tr.append(y[train])
        vals.append(val)

    res = fit_path_batched(
        np.stack(Xs), np.stack(ys_tr), lam, family, screening=screening,
        sigmas=sigmas, solver_tol=solver_tol,  # 1-D grid: shared across folds
        max_iter=max_iter, kkt_tol=kkt_tol, max_refits=max_refits,
        working_set=working_set,
    )

    # one batched evaluation of all K × L held-out deviances (the fold and
    # path axes share shapes, so this is two nested vmaps, not K·L dispatches)
    Xv = jnp.asarray(np.stack([X[v] for v in vals]))
    yv = jnp.asarray(np.stack([y[v] for v in vals]))

    def fold_devs(Xvk, yvk, betas_k):
        return jax.vmap(lambda b: family.loss(Xvk, yvk, b))(betas_k)

    val_dev = np.asarray(jax.vmap(fold_devs)(Xv, yv, jnp.asarray(res.betas)))
    mean_dev = val_dev.mean(axis=0)
    best = int(np.argmin(mean_dev))
    return CvPathResult(
        sigmas=sigmas,
        lam=lam,
        val_deviance=val_dev,
        mean_val_deviance=mean_dev,
        best_index=best,
        best_sigma=float(sigmas[best]),
        fold_paths=res,
        total_time=time.perf_counter() - t0,
    )
