"""Device-resident batched path engine (Algorithms 3/4 under one jit scope).

The host driver in :mod:`repro.core.path` orchestrates one path step at a
time from NumPy: gather the screened columns, pad to a bucket, dispatch a
FISTA solve, pull the gradient back, check KKT, repeat.  That is the right
trade for a *single* huge p ≫ n problem — the gathers shrink every matvec —
but it round-trips host↔device at every step, and it can only fit one
(X, y) problem at a time.

This module moves the whole per-step loop onto the device:

* the path is a ``lax.scan`` over σ-grid points;
* working sets are *masks*, not gathers — :func:`repro.core.solver.fista_masked`
  zeroes masked columns so the sub-problem keeps one static shape, and
  :func:`repro.core.screening.screen_masked` /
  :func:`repro.core.kkt.kkt_violations_masked` run the strong rule and the
  KKT guard on the same masked representation;
* KKT repair is a bounded ``lax.while_loop`` inside each scan step;
* a ``vmap`` batching layer fits B independent problems — CV folds,
  bootstrap replicates, a batch of user requests — in ONE compiled program.

Shape policy: one compilation per static (B, n, p, m, L, config) bucket.
The batching wrappers stack problems of identical shape; callers with mixed
shapes bucket on the host (pad n with zero rows / p with zero columns) —
zero columns are inert in every family, zero rows are inert for OLS.

Everything here returns the *full* σ grid (a scan cannot truncate); the
host front-end applies the paper's early-stopping rules post-hoc when a
:class:`repro.core.path.PathResult` is requested.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .kkt import kkt_violations_masked
from .lambda_seq import path_start_sigma, sigma_grid
from .losses import Family
from .screening import screen_masked
from .solver import default_L0, fista_masked

__all__ = [
    "EnginePath",
    "path_engine",
    "batched_path_engine",
    "fit_path_batched",
    "cv_path",
    "null_gradient",
    "null_sigma_grid",
    "BatchedPathResult",
    "CvPathResult",
]


class EnginePath(NamedTuple):
    """Raw device arrays for one fitted path (leading axis = path point)."""

    betas: jax.Array          # (L, p, m)
    n_active: jax.Array       # (L,) int32
    n_screened: jax.Array     # (L,) int32
    n_violations: jax.Array   # (L,) int32
    refits: jax.Array         # (L,) int32
    solver_iters: jax.Array   # (L,) int32
    deviance: jax.Array       # (L,)
    kkt_unrepaired: jax.Array  # (L,) bool — repair loop hit max_refits
    #   with violations outstanding; the step's betas are NOT KKT-clean


def _engine(X, y, lam, sigmas, family: Family, screening, max_iter, tol,
            kkt_tol, max_refits) -> EnginePath:
    """Traced body shared by :func:`path_engine` and the vmapped batch form."""
    n, p = X.shape
    m = family.n_classes
    pm = p * m
    dtype = X.dtype
    lam = lam.astype(dtype)

    def fam_shape(b):  # (p, m) -> the shape the family callbacks expect
        return b[:, 0] if m == 1 else b

    def lift(b):  # family shape -> (p, m)
        return b[:, None] if m == 1 else b

    zeros = jnp.zeros((p, m), dtype)
    grad0 = lift(family.gradient(X, y, fam_shape(zeros)))
    null_dev = family.loss(X, y, fam_shape(zeros))
    ones_pm = jnp.ones((pm,), bool)

    def solve(E, lam_next, beta, L):
        # The stack PAVA prox is a p·m-length sequential loop — under vmap
        # every batch member pays the slowest member's pooling in lockstep.
        # The sweep-merging prox is a handful of dense ops per sweep, so it
        # batches with near-perfect efficiency.  L is the curvature estimate
        # carried from the previous solve — device-resident state the host
        # driver cannot keep, which skips the backtracking ramp-up.
        res = fista_masked(X, y, lam_next, fam_shape(beta), E, family,
                           max_iter=max_iter, tol=tol,
                           prox_method="parallel", L0=L)
        beta_new = lift(res.beta)
        grad = lift(family.gradient(X, y, fam_shape(beta_new)))
        return beta_new, grad, res.iters.astype(jnp.int32), res.L

    def kkt_check(grad, E, strong_p, checked_full, lam_next):
        gflat = grad.reshape(pm)
        ever = jnp.repeat(E, m)
        viol_full = kkt_violations_masked(gflat, lam_next, ever, ones_pm,
                                          tol=kkt_tol)
        if screening != "previous":
            return viol_full, checked_full
        # Algorithm 4: check the strong set first; only once it is clean,
        # graduate (permanently) to full-set checks.
        subset = jnp.repeat(strong_p, m)
        viol_sub = kkt_violations_masked(gflat, lam_next, ever, subset,
                                         tol=kkt_tol)
        pre = ~checked_full
        sub_has = viol_sub.any()
        viol = jnp.where(pre & sub_has, viol_sub, viol_full)
        return viol, checked_full | (pre & ~sub_has)

    def count_viol(viol_flat, strong_p, prev_active):
        # Violations against the *strong* set are the rule's failures
        # (paper §2.2.3); previous-set warm misses are algorithmic.
        rows = viol_flat.reshape(p, m).any(axis=1)
        miss = rows & ~strong_p
        if screening == "previous":
            miss = miss & ~prev_active
        return miss.sum().astype(jnp.int32)

    def step(carry, sigs):
        beta, grad, prev_active, L_carry = carry
        sig_prev, sig = sigs
        lam_next = sig * lam

        if screening == "none":
            strong_p = jnp.ones((p,), bool)
            E0 = strong_p
            n_screened = jnp.int32(p)
        else:
            gap = (sig_prev - sig) * lam  # rank-space surrogate shift
            keep_flat, _ = screen_masked(jnp.abs(grad.reshape(pm)), lam_next,
                                         ones_pm, gap)
            strong_p = keep_flat.reshape(p, m).any(axis=1)
            n_screened = strong_p.sum().astype(jnp.int32)
            if screening == "strong":
                E0 = strong_p | prev_active
            else:
                E0 = jnp.where(prev_active.any(), prev_active, strong_p)
            # mirror the host driver: once screening keeps most predictors
            # (n ≳ p regime) just solve the full problem — keeps violation
            # accounting identical between backends
            E0 = jnp.where(E0.sum() >= 0.5 * p, jnp.ones((p,), bool), E0)

        beta1, grad1, it1, L1 = solve(E0, lam_next, beta, L_carry)

        if screening == "none":
            beta_f, grad_f, L_f = beta1, grad1, L1
            viol_count = jnp.int32(0)
            refits = jnp.int32(0)
            iters = it1
            unrepaired = jnp.bool_(False)
        else:
            viol1, checked1 = kkt_check(grad1, E0, strong_p, jnp.bool_(False),
                                        lam_next)
            state = dict(
                beta=beta1, grad=grad1, L=L1,
                E=E0 | viol1.reshape(p, m).any(axis=1),
                checked=checked1, has_viol=viol1.any(),
                viol_count=count_viol(viol1, strong_p, prev_active),
                refits=jnp.int32(0), iters=it1,
            )

            def cond(s):
                return s["has_viol"] & (s["refits"] < max_refits)

            def body(s):
                beta2, grad2, it2, L2 = solve(s["E"], lam_next, s["beta"],
                                              s["L"])
                viol2, checked2 = kkt_check(grad2, s["E"], strong_p,
                                            s["checked"], lam_next)
                return dict(
                    beta=beta2, grad=grad2, L=L2,
                    E=s["E"] | viol2.reshape(p, m).any(axis=1),
                    checked=checked2, has_viol=viol2.any(),
                    viol_count=s["viol_count"]
                    + count_viol(viol2, strong_p, prev_active),
                    refits=s["refits"] + 1, iters=s["iters"] + it2,
                )

            state = lax.while_loop(cond, body, state)
            beta_f, grad_f, L_f = state["beta"], state["grad"], state["L"]
            viol_count = state["viol_count"]
            refits = state["refits"]
            iters = state["iters"]
            unrepaired = state["has_viol"]  # loop exited on the refit cap

        active = (jnp.abs(beta_f) > 0).any(axis=1)
        dev = family.loss(X, y, fam_shape(beta_f))
        out = (beta_f, active.sum().astype(jnp.int32), n_screened, viol_count,
               refits, iters, dev, unrepaired)
        return (beta_f, grad_f, active, L_f), out

    L_init = default_L0(X, family).astype(dtype)
    carry0 = (zeros, grad0, jnp.zeros((p,), bool), L_init)
    _, outs = lax.scan(step, carry0, (sigmas[:-1], sigmas[1:]))
    betas, n_act, n_scr, viol, refits, iters, devs, unrep = outs

    def pre(a, v):
        return jnp.concatenate([jnp.asarray(v, a.dtype)[None], a])

    return EnginePath(
        betas=jnp.concatenate([zeros[None], betas]),
        n_active=pre(n_act, 0),
        n_screened=pre(n_scr, 0),
        n_violations=pre(viol, 0),
        refits=pre(refits, 0),
        solver_iters=pre(iters, 0),
        deviance=pre(devs, null_dev),
        kkt_unrepaired=pre(unrep, False),
    )


_ENGINE_STATICS = ("family", "screening", "max_iter", "tol", "kkt_tol",
                   "max_refits")


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def path_engine(X, y, lam, sigmas, family: Family, *, screening: str = "strong",
                max_iter: int = 5000, tol: float = 1e-8,
                kkt_tol: float = 1e-4, max_refits: int = 32) -> EnginePath:
    """Fit one full SLOPE path entirely on device (fixed σ grid, no early
    stop).  One compilation per (n, p, m, len(sigmas), config)."""
    return _engine(X, y, lam, sigmas, family, screening, max_iter, tol,
                   kkt_tol, max_refits)


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def batched_path_engine(X, y, lam, sigmas, family: Family, *,
                        screening: str = "strong", max_iter: int = 5000,
                        tol: float = 1e-8, kkt_tol: float = 1e-4,
                        max_refits: int = 32) -> EnginePath:
    """vmap of :func:`path_engine` over the leading problem axis.

    ``X``: (B, n, p); ``y``: (B, n[, ...]); ``sigmas``: (B, L); ``lam`` is
    shared (SLOPE's λ is a rank sequence, not per-problem data).  Returns an
    :class:`EnginePath` whose arrays carry a leading batch axis.
    """

    def one(Xi, yi, si):
        return _engine(Xi, yi, lam, si, family, screening, max_iter, tol,
                       kkt_tol, max_refits)

    return jax.vmap(one)(X, y, sigmas)


# ---------------------------------------------------------------------------
# Host-facing wrappers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPathResult:
    """B paths fitted by one compiled program (leading axis = problem)."""

    betas: np.ndarray         # (B, L, p) or (B, L, p, m)
    sigmas: np.ndarray        # (B, L)
    lam: np.ndarray
    n_active: np.ndarray      # (B, L)
    n_screened: np.ndarray
    n_violations: np.ndarray
    refits: np.ndarray
    solver_iters: np.ndarray
    deviance: np.ndarray
    kkt_unrepaired: np.ndarray  # (B, L) bool — see EnginePath.kkt_unrepaired
    total_time: float
    n_samples: int            # rows per problem (early-stop rules need it)

    @property
    def batch(self) -> int:
        return self.betas.shape[0]

    @property
    def total_violations(self) -> np.ndarray:
        return self.n_violations.sum(axis=1)

    def path_results(self, *, early_stop: bool = True):
        """Per-problem :class:`repro.core.path.PathResult` views (the same
        contract the unbatched driver returns, early stopping applied
        post-hoc)."""
        from .path import engine_to_path_result  # lazy: avoid import cycle

        per = self.total_time / self.batch
        return [
            engine_to_path_result(
                EnginePath(
                    betas=self.betas[b] if self.betas.ndim == 4
                    else self.betas[b][:, :, None],
                    n_active=self.n_active[b],
                    n_screened=self.n_screened[b],
                    n_violations=self.n_violations[b],
                    refits=self.refits[b],
                    solver_iters=self.solver_iters[b],
                    deviance=self.deviance[b],
                    kkt_unrepaired=self.kkt_unrepaired[b],
                ),
                self.sigmas[b], self.lam, per, early_stop=early_stop,
                n=self.n_samples,
            )
            for b in range(self.batch)
        ]


def null_gradient(X, y, family: Family) -> np.ndarray:
    """∇f(0) reshaped to (p, m) — the quantity both the σ-grid recipe and
    the first strong-rule step start from."""
    p = X.shape[1]
    m = family.n_classes
    beta0 = jnp.zeros((p,) if m == 1 else (p, m), X.dtype)
    return np.asarray(
        family.gradient(jnp.asarray(X), jnp.asarray(y), beta0)
    ).reshape(p, m)


def null_sigma_grid(X, y, lam, family: Family, *, path_length: int,
                    sigma_ratio: float | None,
                    grad0: np.ndarray | None = None) -> np.ndarray:
    """The paper's σ grid for one problem: σ(1) from the null gradient's
    dual gauge, geometric decay per §3.1.2.  The ONE recipe shared by
    fit_path (both backends), fit_path_batched and cv_path."""
    if grad0 is None:
        grad0 = null_gradient(X, y, family)
    s1 = float(path_start_sigma(jnp.asarray(grad0), jnp.asarray(lam)))
    n, p = X.shape
    return sigma_grid(s1, length=path_length, ratio=sigma_ratio, n=n, p=p)


def _null_sigma_grids(Xs, ys, lam, family: Family, path_length, sigma_ratio):
    """Per-problem σ grids (stacked :func:`null_sigma_grid`)."""
    return np.stack([
        null_sigma_grid(Xs[b], ys[b], lam, family, path_length=path_length,
                        sigma_ratio=sigma_ratio)
        for b in range(Xs.shape[0])
    ])


def fit_path_batched(
    Xs, ys, lam, family: Family, *,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = 1e-8,
    max_iter: int = 5000,
    kkt_tol: float = 1e-4,
    max_refits: int = 32,
) -> BatchedPathResult:
    """Fit B independent SLOPE paths in one compiled device program.

    ``Xs`` is (B, n, p) and ``ys`` (B, n) — problems of identical shape share
    one compilation (the bucketing policy: pad mixed shapes on the host).
    Semantics match ``fit_path(..., engine="device")`` per problem.  Steps
    whose KKT repair hit ``max_refits`` are flagged in ``kkt_unrepaired``
    (and warned about) — raise the cap if that ever fires.
    """
    Xs = np.asarray(Xs)
    ys = np.asarray(ys)
    if Xs.ndim != 3:
        raise ValueError(f"Xs must be (B, n, p), got {Xs.shape}")
    if ys.shape[:2] != Xs.shape[:2]:
        raise ValueError(
            f"ys must be (B, n[, ...]) matching Xs {Xs.shape[:2]}, got {ys.shape}")
    lam = np.asarray(lam)
    if sigmas is None:
        sigmas = _null_sigma_grids(Xs, ys, lam, family, path_length,
                                   sigma_ratio)
    sigmas = np.asarray(sigmas)
    B = Xs.shape[0]
    if sigmas.ndim == 1:  # one shared grid, like fit_path's 1-D sigmas
        sigmas = np.tile(sigmas, (B, 1))
    if sigmas.shape[0] != B or sigmas.ndim != 2:
        raise ValueError(
            f"sigmas must be (L,) shared or (B, L) per-problem; got "
            f"{sigmas.shape} for B={B}")

    t0 = time.perf_counter()
    res = batched_path_engine(
        jnp.asarray(Xs), jnp.asarray(ys), jnp.asarray(lam),
        jnp.asarray(sigmas), family, screening=screening, max_iter=max_iter,
        tol=solver_tol, kkt_tol=kkt_tol, max_refits=max_refits,
    )
    betas = np.asarray(res.betas)  # (B, L, p, m)
    wall = time.perf_counter() - t0
    if family.n_classes == 1:
        betas = betas[:, :, :, 0]
    unrepaired = np.asarray(res.kkt_unrepaired)
    _warn_unrepaired(unrepaired, max_refits)
    return BatchedPathResult(
        betas=betas,
        sigmas=sigmas,
        lam=lam,
        n_active=np.asarray(res.n_active),
        n_screened=np.asarray(res.n_screened),
        n_violations=np.asarray(res.n_violations),
        refits=np.asarray(res.refits),
        solver_iters=np.asarray(res.solver_iters),
        deviance=np.asarray(res.deviance),
        kkt_unrepaired=unrepaired,
        total_time=wall,
        n_samples=Xs.shape[1],
    )


def _warn_unrepaired(unrepaired: np.ndarray, max_refits: int) -> None:
    if unrepaired.any():
        import warnings

        warnings.warn(
            f"{int(unrepaired.sum())} path step(s) hit the KKT repair cap "
            f"(max_refits={max_refits}) with violations outstanding; those "
            "betas are not KKT-clean — raise max_refits",
            RuntimeWarning,
            stacklevel=3,
        )


@dataclasses.dataclass
class CvPathResult:
    """K-fold cross-validation over one shared σ grid."""

    sigmas: np.ndarray            # (L,) shared grid
    lam: np.ndarray
    val_deviance: np.ndarray      # (K, L) held-out deviance per fold
    mean_val_deviance: np.ndarray  # (L,)
    best_index: int
    best_sigma: float
    fold_paths: BatchedPathResult
    total_time: float


def cv_path(
    X, y, lam, family: Family, *,
    n_folds: int = 5,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    solver_tol: float = 1e-8,
    max_iter: int = 5000,
    kkt_tol: float = 1e-4,
    max_refits: int = 32,
) -> CvPathResult:
    """K-fold CV: all fold paths fit as ONE batched device program.

    Folds are contiguous blocks of ⌊n/K⌋ rows (remainder rows are always in
    training) so every training design has the same shape and the folds
    batch into a single compilation.  The σ grid is computed once from the
    full data and shared, so every fold is evaluated at the same penalty.
    """
    t0 = time.perf_counter()
    X = np.asarray(X)
    y = np.asarray(y)
    n = X.shape[0]
    lam = np.asarray(lam)
    if not 2 <= n_folds <= n:
        raise ValueError(f"n_folds must be in [2, {n}], got {n_folds}")
    fold = n // n_folds

    sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                             sigma_ratio=sigma_ratio)

    Xs, ys_tr, vals = [], [], []
    for k in range(n_folds):
        val = np.arange(k * fold, (k + 1) * fold)
        train = np.setdiff1d(np.arange(n), val)
        Xs.append(X[train])
        ys_tr.append(y[train])
        vals.append(val)

    res = fit_path_batched(
        np.stack(Xs), np.stack(ys_tr), lam, family, screening=screening,
        sigmas=sigmas, solver_tol=solver_tol,  # 1-D grid: shared across folds
        max_iter=max_iter, kkt_tol=kkt_tol, max_refits=max_refits,
    )

    # one batched evaluation of all K × L held-out deviances (the fold and
    # path axes share shapes, so this is two nested vmaps, not K·L dispatches)
    Xv = jnp.asarray(np.stack([X[v] for v in vals]))
    yv = jnp.asarray(np.stack([y[v] for v in vals]))

    def fold_devs(Xvk, yvk, betas_k):
        return jax.vmap(lambda b: family.loss(Xvk, yvk, b))(betas_k)

    val_dev = np.asarray(jax.vmap(fold_devs)(Xv, yv, jnp.asarray(res.betas)))
    mean_dev = val_dev.mean(axis=0)
    best = int(np.argmin(mean_dev))
    return CvPathResult(
        sigmas=sigmas,
        lam=lam,
        val_deviance=val_dev,
        mean_val_deviance=mean_dev,
        best_index=best,
        best_sigma=float(sigmas[best]),
        fold_paths=res,
        total_time=time.perf_counter() - t0,
    )
