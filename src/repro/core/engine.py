"""Device-resident batched path engine (Algorithms 3/4 under one jit scope).

The host driver in :mod:`repro.core.path` orchestrates one path step at a
time from NumPy: gather the screened columns, pad to a bucket, dispatch a
FISTA solve, pull the gradient back, check KKT, repeat.  That is the right
trade for a *single* huge p ≫ n problem — the gathers shrink every matvec —
but it round-trips host↔device at every step, and it can only fit one
(X, y) problem at a time.

This module moves the whole per-step loop onto the device:

* the path is a ``lax.scan`` over σ-grid points;
* working sets are *masks*, not gathers — :func:`repro.core.solver.fista_masked`
  zeroes masked columns so the sub-problem keeps one static shape, and
  :func:`repro.core.screening.screen_masked` /
  :func:`repro.core.kkt.kkt_violations_masked` run the strong rule and the
  KKT guard on the same masked representation;
* KKT repair is a bounded ``lax.while_loop`` inside each scan step;
* a ``vmap`` batching layer fits B independent problems — CV folds,
  bootstrap replicates, a batch of user requests — in ONE compiled program.

Shape policy: one compilation per static (B, n, p, m, L, config) bucket.
The batching wrappers stack problems of identical shape; callers with mixed
shapes bucket on the host (pad n with zero rows / p with zero columns) —
zero columns are inert in every family, zero rows are inert for OLS.

Everything here returns the *full* σ grid (a scan cannot truncate); the
host front-end applies the paper's early-stopping rules post-hoc when a
:class:`repro.core.path.PathResult` is requested.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..serve.buckets import BucketRegistry, next_pow2
from .kkt import kkt_violations_masked
from .lambda_seq import path_start_sigma, sigma_grid
from .losses import Family
from .screening import screen_masked
from .solver import (
    DEFAULT_KKT_TOL,
    DEFAULT_MAX_REFITS,
    DEFAULT_PATH_MAX_ITER,
    DEFAULT_PATH_TOL,
    DEFAULT_WS_TIERS,
    default_L0,
    fista_compact,
    fista_masked,
    fista_shared_masked,
)

__all__ = [
    "EnginePath",
    "CompactStats",
    "PathHealth",
    "HEALTH_OK",
    "HEALTH_NONFINITE_INPUT",
    "HEALTH_NONFINITE_STATE",
    "HEALTH_DIVERGED",
    "health_causes",
    "path_engine",
    "batched_path_engine",
    "compact_path_engine",
    "chunk_path_engine",
    "path_init_engine",
    "replicate_path_engine",
    "replicate_compact_path_engine",
    "fit_path_batched",
    "grow_ws_bucket",
    "resolve_ws_tiers",
    "second_tier_width",
    "cv_path",
    "cv_fold_indices",
    "cv_val_deviance",
    "cv_select",
    "null_gradient",
    "null_sigma_grid",
    "BatchedPathResult",
    "CvPathResult",
]


class EnginePath(NamedTuple):
    """Raw device arrays for one fitted path (leading axis = path point)."""

    betas: jax.Array          # (L, p, m)
    n_active: jax.Array       # (L,) int32
    n_screened: jax.Array     # (L,) int32
    n_violations: jax.Array   # (L,) int32
    refits: jax.Array         # (L,) int32
    solver_iters: jax.Array   # (L,) int32
    deviance: jax.Array       # (L,)
    kkt_unrepaired: jax.Array  # (L,) bool — repair loop hit max_refits
    #   with violations outstanding; the step's betas are NOT KKT-clean
    health: jax.Array         # (L,) int32 — sticky per-step health word
    #   (HEALTH_* bitmask); nonzero from the first step a member turned
    #   sick — its betas are zeroed and it is quarantined out of
    #   screening/KKT from then on


# Per-member health word bits.  The word rides the scan carry, is sticky
# (monotone OR across steps), and quarantines the member in-graph: its data
# is zeroed, its working set blanked and its KKT repair gated off, so the
# quarantined no-op solve exits in one iteration instead of grinding
# ``max_iter`` on NaN stop criteria and stalling the lockstep batch.
HEALTH_OK = 0
HEALTH_NONFINITE_INPUT = 1   # non-finite X/y/λ/σ reached the engine
HEALTH_NONFINITE_STATE = 2   # solver state (beta/grad/L/deviance) went NaN/Inf
HEALTH_DIVERGED = 4          # objective blew past the divergence bound

# a step's deviance beyond FACTOR·(|null deviance| + 1) marks divergence:
# every family's loss at beta=0 is the natural scale of the objective, and
# a correct prox step can never increase it by six orders of magnitude
_DIVERGENCE_FACTOR = 1e6

_HEALTH_BITS = (
    (HEALTH_NONFINITE_INPUT, "nonfinite_input"),
    (HEALTH_NONFINITE_STATE, "nonfinite_state"),
    (HEALTH_DIVERGED, "diverged"),
)


def health_causes(word: int) -> tuple[str, ...]:
    """Human-readable causes encoded in a health word."""
    return tuple(name for bit, name in _HEALTH_BITS if int(word) & bit)


@dataclasses.dataclass(frozen=True)
class PathHealth:
    """Per-member quarantine verdicts for one batched fit.

    ``word`` is the (B, L) sticky per-step health bitmask an engine run
    emitted (``EnginePath.health`` with the batch axis leading).  Because
    the word is monotone along the path, the last step's word is each
    member's cumulative verdict.
    """

    word: np.ndarray  # (B, L) int32

    @property
    def quarantined(self) -> np.ndarray:
        """(B,) bool — members that turned sick anywhere on the path."""
        return np.asarray(self.word)[:, -1] != 0

    @property
    def first_bad_step(self) -> np.ndarray:
        """(B,) int — first sick path index per member, -1 when healthy."""
        w = np.asarray(self.word)
        sick = w != 0
        return np.where(sick.any(axis=1), sick.argmax(axis=1), -1)

    @property
    def ok(self) -> bool:
        return not bool(self.quarantined.any())

    def causes(self, b: int) -> tuple[str, ...]:
        return health_causes(int(np.asarray(self.word)[b, -1]))


class CompactStats(NamedTuple):
    """Per-step compact-engine telemetry (leading axes = problem, path point)."""

    ws_size: jax.Array    # (B, L) int32 — peak working-set demand |E| per step
    tier: jax.Array       # (B, L) int32 — which tier served the member's
    #   step: 1 = the W bucket, 2 = the 2W top tier, 0 = the step ran the
    #   batch-wide masked fallback (some member's demand exceeded the top
    #   tier; `ws_size` still records every member's own demand)
    fell_back: jax.Array  # (B, L) bool — step ran the masked full-width
    #   fallback because some batch member's |E| exceeded the top tier


# ---------------------------------------------------------------------------
# Per-problem step primitives, shared by the masked and compact engines
# ---------------------------------------------------------------------------

def _valid_masks(p, m, p_valid):
    """Predictor- and coordinate-space validity masks for a (possibly
    bucket-padded) problem.  ``p_valid=None`` means every column is real —
    the masks are all-True constants and fold away at trace time; a traced
    ``p_valid`` scalar marks columns ≥ p_valid as padding, excluded from
    screening, KKT checks and the full-problem widening heuristic (their
    coefficients are inert zeros either way — see repro.serve.buckets)."""
    if p_valid is None:
        return jnp.ones((p,), bool), jnp.ones((p * m,), bool)
    valid_p = jnp.arange(p) < p_valid
    return valid_p, jnp.repeat(valid_p, m)


def _screen_sets(grad, prev_active, sig_prev, sig, lam, *, p, m, screening,
                 p_valid=None):
    """Strong set + initial working set E₀ for one path step (one problem)."""
    pm = p * m
    valid_p, valid_flat = _valid_masks(p, m, p_valid)
    gap = (sig_prev - sig) * lam  # rank-space surrogate shift
    keep_flat, _ = screen_masked(jnp.abs(grad.reshape(pm)), sig * lam,
                                 valid_flat, gap)
    strong_p = keep_flat.reshape(p, m).any(axis=1)
    n_screened = strong_p.sum().astype(jnp.int32)
    if screening == "strong":
        E0 = strong_p | prev_active
    else:  # "previous" (Algorithm 4)
        E0 = jnp.where(prev_active.any(), prev_active, strong_p)
    # mirror the host driver: once screening keeps most predictors
    # (n ≳ p regime) just solve the full problem — keeps violation
    # accounting identical between backends.  "Full" means the valid
    # columns; the threshold counts them, not the padded width.
    p_eff = p if p_valid is None else p_valid
    E0 = jnp.where(E0.sum() >= 0.5 * p_eff, valid_p, E0)
    return strong_p, E0, n_screened


def _kkt_step(grad, lam_next, E, strong_p, checked_full, *, p, m, kkt_tol,
              screening, p_valid=None):
    """KKT violation mask for one problem; see Algorithms 3/4."""
    pm = p * m
    _, valid_flat = _valid_masks(p, m, p_valid)
    gflat = grad.reshape(pm)
    ever = jnp.repeat(E, m)
    viol_full = kkt_violations_masked(gflat, lam_next, ever, valid_flat,
                                      tol=kkt_tol)
    if screening != "previous":
        return viol_full, checked_full
    # Algorithm 4: check the strong set first; only once it is clean,
    # graduate (permanently) to full-set checks.
    subset = jnp.repeat(strong_p, m)
    viol_sub = kkt_violations_masked(gflat, lam_next, ever, subset,
                                     tol=kkt_tol)
    pre = ~checked_full
    sub_has = viol_sub.any()
    viol = jnp.where(pre & sub_has, viol_sub, viol_full)
    return viol, checked_full | (pre & ~sub_has)


def _new_violations(viol_flat, strong_p, prev_active, *, p, m, screening):
    """Count the rule's failures: violations against the *strong* set
    (paper §2.2.3); previous-set warm misses are algorithmic."""
    rows = viol_flat.reshape(p, m).any(axis=1)
    miss = rows & ~strong_p
    if screening == "previous":
        miss = miss & ~prev_active
    return miss.sum().astype(jnp.int32)


def _step_builder(X, y, lam, family: Family, screening, max_iter, tol,
                  kkt_tol, max_refits, rw=None, shared_x=False):
    """Build the per-σ-point path step for ONE problem.

    Returns ``step(carry, sigs, p_valid) -> (carry, out)`` with carry
    ``(beta, grad, prev_active, L, health)`` — the traced body shared by
    the monolithic scan (:func:`path_engine` / the vmapped batch form) and
    the chunked continuous-batching scan (:func:`chunk_path_engine`).  One
    body, one trace structure: a chunked run must produce bit-identical
    per-step results to the monolithic scan, so the step cannot fork.
    ``p_valid`` is per-call (not closed over) because the chunked engine
    feeds a *dynamic* value: a frozen slot passes 0, which empties the
    screened set and turns the step into a one-iteration no-op solve.

    ``health`` (int32 HEALTH_* bitmask, sticky) is the quarantine word: a
    sick member enters the step with its carry sanitized and its DATA
    zeroed (``jnp.where`` on X/y — value-identity for healthy members, so
    the healthy path stays bitwise what it was before health existed).
    Zeroing the data matters: NaN comparisons are always False, so a
    poisoned X would never trip FISTA's stop criteria and one member would
    grind ``max_iter`` iterations while the whole lockstep batch waits.
    With zeroed data and a blanked working set the quarantined solve exits
    in one iteration — the same blanked-solve trick the two-tier mixed arm
    and the chunked engine's dead steps use.

    ``rw`` (optional, (n,)) is a per-member row-weight vector: the solves
    minimise the reweighted loss Σ wᵢℓᵢ — the count-vector representation
    of a bootstrap replicate, and the row-weight form of OLS sample
    weights.  ``shared_x=True`` marks X as a batch-shared operand (the
    replicate engine vmaps this builder with ``in_axes=None`` on X): the
    quarantine gate then zeroes the member's WEIGHTS instead of the data —
    ``jnp.where`` on a shared X would materialize a per-member copy — and
    the masked solves route through :func:`fista_shared_masked` (gradient
    masking) for the same reason.  Zero weights make every row inert, so
    the blanked-solve quarantine trick carries over unchanged.
    """
    p = X.shape[1]
    m = family.n_classes
    dtype = X.dtype
    lam = lam.astype(dtype)
    if shared_x and rw is None:
        raise ValueError("shared_x=True requires row weights (rw)")
    # loop-invariant health inputs, hoisted by XLA out of the scan: the
    # divergence bound from the null deviance, and whether λ itself is sick
    null_dev_in = family.loss(X, y, jnp.zeros((p,) if m == 1 else (p, m),
                                              dtype), weights=rw)
    dev_bound = _DIVERGENCE_FACTOR * (jnp.abs(null_dev_in) + 1.0)
    lam_bad = ~jnp.all(jnp.isfinite(lam))

    def fam_shape(b):  # (p, m) -> the shape the family callbacks expect
        return b[:, 0] if m == 1 else b

    def lift(b):  # family shape -> (p, m)
        return b[:, None] if m == 1 else b

    def solve(Xs, ys, E, lam_next, beta, L, rws=None):
        # The stack PAVA prox is a p·m-length sequential loop — under vmap
        # every batch member pays the slowest member's pooling in lockstep.
        # The sweep-merging prox is a handful of dense ops per sweep, so it
        # batches with near-perfect efficiency.  L is the curvature estimate
        # carried from the previous solve — device-resident state the host
        # driver cannot keep, which skips the backtracking ramp-up.
        masked = fista_shared_masked if shared_x else fista_masked
        res = masked(Xs, ys, lam_next, fam_shape(beta), E, family,
                     max_iter=max_iter, tol=tol,
                     prox_method="parallel", L0=L, weights=rws)
        beta_new = lift(res.beta)
        grad = lift(family.gradient(Xs, ys, fam_shape(beta_new),
                                    weights=rws))
        return beta_new, grad, res.iters.astype(jnp.int32), res.L

    count_viol = functools.partial(_new_violations, p=p, m=m,
                                   screening=screening)

    def step(carry, sigs, p_valid):
        beta, grad, prev_active, L_carry, health = carry
        sig_prev, sig = sigs
        lam_next = sig * lam
        kkt_check = functools.partial(_kkt_step, p=p, m=m, kkt_tol=kkt_tol,
                                      screening=screening, p_valid=p_valid)

        # quarantine gate: a member already sick runs this step on zeroed
        # data, zeroed carry and an empty working set — a one-iteration
        # no-op solve.  All selects are value-identity when sick is False.
        # With a shared X the member's row WEIGHTS are zeroed instead of
        # the data (a where() on shared X would materialize a per-member
        # copy under vmap); zero weights make every row inert, so the
        # blanked solve still exits in one iteration.
        sick = health != 0
        Xq = X if shared_x else jnp.where(sick, jnp.zeros((), dtype), X)
        yq = jnp.where(sick, jnp.zeros((), y.dtype), y)
        rwq = (None if rw is None
               else jnp.where(sick, jnp.zeros((), rw.dtype), rw))
        beta = jnp.where(sick, 0, beta)
        grad = jnp.where(sick, 0, grad)
        prev_active = prev_active & ~sick
        L_carry = jnp.where(sick, jnp.ones((), L_carry.dtype), L_carry)

        if screening == "none":
            strong_p, _ = _valid_masks(p, m, p_valid)
            E0 = strong_p
            n_screened = (jnp.int32(p) if p_valid is None
                          else jnp.asarray(p_valid, jnp.int32))
        else:
            strong_p, E0, n_screened = _screen_sets(
                grad, prev_active, sig_prev, sig, lam, p=p, m=m,
                screening=screening, p_valid=p_valid)
        E0 = E0 & ~sick
        strong_p = strong_p & ~sick
        n_screened = jnp.where(sick, 0, n_screened)

        beta1, grad1, it1, L1 = solve(Xq, yq, E0, lam_next, beta, L_carry,
                                      rwq)

        if screening == "none":
            beta_f, grad_f, L_f = beta1, grad1, L1
            viol_count = jnp.int32(0)
            refits = jnp.int32(0)
            iters = it1
            unrepaired = jnp.bool_(False)
        else:
            viol1, checked1 = kkt_check(grad1, lam_next, E0, strong_p,
                                        jnp.bool_(False))
            state = dict(
                beta=beta1, grad=grad1, L=L1,
                E=E0 | viol1.reshape(p, m).any(axis=1),
                checked=checked1, has_viol=viol1.any() & ~sick,
                viol_count=count_viol(viol1, strong_p, prev_active),
                refits=jnp.int32(0), iters=it1,
            )

            def cond(s):
                return s["has_viol"] & (s["refits"] < max_refits)

            def body(s):
                beta2, grad2, it2, L2 = solve(Xq, yq, s["E"], lam_next,
                                              s["beta"], s["L"], rwq)
                viol2, checked2 = kkt_check(grad2, lam_next, s["E"],
                                            strong_p, s["checked"])
                return dict(
                    beta=beta2, grad=grad2, L=L2,
                    E=s["E"] | viol2.reshape(p, m).any(axis=1),
                    checked=checked2, has_viol=viol2.any(),
                    viol_count=s["viol_count"]
                    + count_viol(viol2, strong_p, prev_active),
                    refits=s["refits"] + 1, iters=s["iters"] + it2,
                )

            state = lax.while_loop(cond, body, state)
            beta_f, grad_f, L_f = state["beta"], state["grad"], state["L"]
            viol_count = state["viol_count"]
            refits = state["refits"]
            iters = state["iters"]
            unrepaired = state["has_viol"]  # loop exited on the refit cap

        dev = family.loss(Xq, yq, fam_shape(beta_f), weights=rwq)
        # health detection: non-finite σ/λ inputs, non-finite solver state,
        # objective divergence.  Sticky — once sick, always sick.
        bad_input = lam_bad | ~(jnp.isfinite(sig_prev) & jnp.isfinite(sig))
        bad_state = ~(jnp.all(jnp.isfinite(beta_f))
                      & jnp.all(jnp.isfinite(grad_f))
                      & jnp.isfinite(L_f))
        bad_dev = ~jnp.isfinite(dev) | (dev > dev_bound)
        zero32 = jnp.int32(0)
        health = (health
                  | jnp.where(bad_input, jnp.int32(HEALTH_NONFINITE_INPUT),
                              zero32)
                  | jnp.where(bad_state, jnp.int32(HEALTH_NONFINITE_STATE),
                              zero32)
                  | jnp.where(bad_dev, jnp.int32(HEALTH_DIVERGED), zero32))
        # quarantine newly-sick members' outputs so NaNs cannot escape into
        # the carried state (next step's screen/solve) or the emitted path
        sick_out = health != 0
        beta_f = jnp.where(sick_out, 0, beta_f)
        grad_f = jnp.where(sick_out, 0, grad_f)
        L_f = jnp.where(sick_out, jnp.ones((), L_f.dtype), L_f)

        active = (jnp.abs(beta_f) > 0).any(axis=1)
        out = (beta_f, active.sum().astype(jnp.int32), n_screened, viol_count,
               refits, iters, dev, unrepaired, health)
        return (beta_f, grad_f, active, L_f, health), out

    return step


def _init_state(X, y, family: Family, rw=None):
    """Null-model start state for one problem: ``(beta0, grad0, active0,
    L0, health0)`` plus the null deviance — exactly the pre-scan
    computation :func:`_engine` performs, factored out so the chunked
    engine's prefill is bitwise the same.  ``health0`` is nonzero when the
    inputs are already sick at the null model (non-finite X/y poison the
    null gradient, deviance or Lipschitz estimate) — the member is then
    quarantined from its very first step.  ``rw`` (optional, (n,)) seeds
    the state of the row-reweighted problem (replicates / OLS weights)."""
    p = X.shape[1]
    m = family.n_classes
    dtype = X.dtype
    zeros = jnp.zeros((p, m), dtype)
    fam0 = zeros[:, 0] if m == 1 else zeros
    grad0 = family.gradient(X, y, fam0, weights=rw)
    grad0 = grad0[:, None] if m == 1 else grad0
    null_dev = family.loss(X, y, fam0, weights=rw)
    L_init = default_L0(X, family, rw).astype(dtype)
    finite0 = (jnp.all(jnp.isfinite(grad0)) & jnp.isfinite(null_dev)
               & jnp.isfinite(L_init))
    health0 = jnp.where(finite0, jnp.int32(HEALTH_OK),
                        jnp.int32(HEALTH_NONFINITE_INPUT))
    return zeros, grad0, null_dev, L_init, health0


def _engine(X, y, lam, sigmas, family: Family, screening, max_iter, tol,
            kkt_tol, max_refits, p_valid=None, rw=None,
            shared_x=False) -> EnginePath:
    """Traced body shared by :func:`path_engine` and the vmapped batch form."""
    p = X.shape[1]
    zeros, grad0, null_dev, L_init, health0 = _init_state(X, y, family, rw)
    step = _step_builder(X, y, lam, family, screening, max_iter, tol,
                         kkt_tol, max_refits, rw=rw, shared_x=shared_x)
    carry0 = (zeros, grad0, jnp.zeros((p,), bool), L_init, health0)
    _, outs = lax.scan(lambda c, s: step(c, s, p_valid), carry0,
                       (sigmas[:-1], sigmas[1:]))
    betas, n_act, n_scr, viol, refits, iters, devs, unrep, hlth = outs

    def pre(a, v):
        return jnp.concatenate([jnp.asarray(v, a.dtype)[None], a])

    return EnginePath(
        betas=jnp.concatenate([zeros[None], betas]),
        n_active=pre(n_act, 0),
        n_screened=pre(n_scr, 0),
        n_violations=pre(viol, 0),
        refits=pre(refits, 0),
        solver_iters=pre(iters, 0),
        deviance=pre(devs, null_dev),
        kkt_unrepaired=pre(unrep, False),
        health=pre(hlth, health0),
    )


_ENGINE_STATICS = ("family", "screening", "max_iter", "tol", "kkt_tol",
                   "max_refits")


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def path_engine(X, y, lam, sigmas, family: Family, p_valid=None, *,
                screening: str = "strong",
                max_iter: int = 5000, tol: float = 1e-8,
                kkt_tol: float = 1e-4, max_refits: int = 32) -> EnginePath:
    """Fit one full SLOPE path entirely on device (fixed σ grid, no early
    stop).  One compilation per (n, p, m, len(sigmas), config).

    ``p_valid`` (optional scalar) marks columns ≥ p_valid as bucket padding:
    inert in the solve and excluded from screening/KKT accounting."""
    return _engine(X, y, lam, sigmas, family, screening, max_iter, tol,
                   kkt_tol, max_refits, p_valid)


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def batched_path_engine(X, y, lam, sigmas, family: Family, p_valid=None, *,
                        screening: str = "strong", max_iter: int = 5000,
                        tol: float = 1e-8, kkt_tol: float = 1e-4,
                        max_refits: int = 32) -> EnginePath:
    """vmap of :func:`path_engine` over the leading problem axis.

    ``X``: (B, n, p); ``y``: (B, n[, ...]); ``sigmas``: (B, L); ``lam`` is
    either one shared (p·m,) sequence (SLOPE's λ is a rank sequence, not
    per-problem data) or a per-problem (B, p·m) stack — the serve layer
    uses the latter so requests with different native widths can share one
    padded program.  ``p_valid`` (optional, (B,) int32) marks per-member
    bucket padding.  Returns an :class:`EnginePath` whose arrays carry a
    leading batch axis.
    """
    lam_axis = 0 if lam.ndim == 2 else None
    pv_axis = None if p_valid is None else 0

    def one(Xi, yi, si, lami, pvi):
        return _engine(Xi, yi, lami, si, family, screening, max_iter, tol,
                       kkt_tol, max_refits, pvi)

    return jax.vmap(one, in_axes=(0, 0, 0, lam_axis, pv_axis))(
        X, y, sigmas, lam, p_valid)


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def replicate_path_engine(X, y, lam, sigmas, weights, family: Family,
                          p_valid=None, *, screening: str = "strong",
                          max_iter: int = 5000, tol: float = 1e-8,
                          kkt_tol: float = 1e-4,
                          max_refits: int = 32) -> EnginePath:
    """B row-reweighted SLOPE paths against ONE shared (n, p) design.

    The materialize-free replicate engine (ROADMAP item 4): a bootstrap /
    permutation / subsample replicate is represented as ``(shared X,
    per-member row-weight vector)`` instead of a row-duplicated copy of X,
    so the resident operands are O(n·p + B·n) — the vmap closes over X
    with ``in_axes=None``, which turns every per-member GEMV inside FISTA
    into one shared (n, p) × (p, B) GEMM and never stacks a (B, n, p) X.

    ``X``: (n, p) shared; ``y``: (n,) shared or (B, n) per-member (the
    permutation-null workload permutes y, not X); ``weights``: (B, n)
    per-member row weights (bootstrap count vectors, subsample 0/1 masks,
    OLS sample weights); ``lam``: one shared (p·m,) sequence; ``sigmas``:
    (L,) — replicates share the master problem's σ grid, like CV folds
    share the full-data grid; ``p_valid`` (optional scalar) marks shared
    bucket padding.  An all-zero weight vector is a legal edge member: its
    loss surface is identically 0, every path step solves the blanked
    null problem in one iteration, and its coefficients come back exactly
    0.  Returns an :class:`EnginePath` with a leading (B,) replicate axis.
    """
    y_axis = 0 if y.ndim == 2 else None

    def one(yi, wi):
        return _engine(X, yi, lam, sigmas, family, screening, max_iter, tol,
                       kkt_tol, max_refits, p_valid, rw=wi, shared_x=True)

    return jax.vmap(one, in_axes=(y_axis, 0))(y, weights)


@functools.partial(jax.jit, static_argnames=("family",))
def path_init_engine(X, y, family: Family):
    """Batched prefill: the state a path scan starts from, per member.

    Returns ``(grad0, null_dev, L0, health0)`` with shapes ``(B, p, m)`` /
    ``(B,)`` / ``(B,)`` / ``(B,) int32`` — the same pre-scan computation
    :func:`batched_path_engine` performs internally (one
    :func:`_init_state` per member under vmap), as its own compiled
    program so the continuous-batching dispatcher can initialise a *newly
    inserted* slot mid-flight with bitwise the state a from-scratch run
    would have started with.  ``beta0``/``active0`` are zeros at known
    shapes; the host materialises those itself.  A nonzero ``health0``
    marks a member quarantined before its first step (non-finite inputs).
    """
    def one(Xi, yi):
        _, grad0, null_dev, L0, health0 = _init_state(Xi, yi, family)
        return grad0, null_dev, L0, health0

    return jax.vmap(one)(X, y)


@functools.partial(jax.jit, static_argnames=_ENGINE_STATICS)
def chunk_path_engine(X, y, lam, sig_prev, sig_next, live, beta, grad,
                      active, L, health, family: Family, p_valid, *,
                      screening: str = "strong", max_iter: int = 5000,
                      tol: float = 1e-8, kkt_tol: float = 1e-4,
                      max_refits: int = 32):
    """Advance B carried paths by C σ-grid steps each (continuous batching).

    The slot-swap seam for the async serving layer: instead of one
    monolithic scan over a member's whole grid, the path advances in
    chunks of C steps with the scan carry ``(beta, grad, active, L,
    health)`` round-tripped through the host between chunks — so a member
    that early-stops can free its batch slot and a queued request can join
    the *running* cohort at the next chunk boundary, each slot at its own
    step offset.

    ``sig_prev``/``sig_next``: (B, C) per-slot σ pairs (each slot's own
    grid, wherever its cursor stands); ``live``: (B, C) bool — steps beyond
    a slot's remaining grid (or an empty slot) are dead: the step sees an
    effective ``p_valid`` of 0 (empty screened set → one-iteration blanked
    solve, the same trick the two-tier mixed arm uses) and the carry is
    held, so a dead step costs lockstep time but cannot perturb state.
    ``p_valid``: (B,) int32; ``health``: (B,) int32 sticky quarantine words
    (0 for healthy slots; :func:`path_init_engine` seeds them).  Returns
    ``((beta, grad, active, L, health), EnginePath)`` with EnginePath
    arrays shaped (B, C, ...) — raw chunk steps, no null head (the
    dispatcher owns step 0 via :func:`path_init_engine`).

    Per-step traced body is :func:`_step_builder`'s — the SAME body the
    monolithic engines scan — so chunked execution is bit-identical to
    :func:`batched_path_engine` on the same inputs (pinned in
    ``tests/test_serve_async.py``).
    """
    lam_axis = 0 if lam.ndim == 2 else None

    def one(Xi, yi, lami, spi, sni, lvi, bi, gi, ai, Li, hi, pvi):
        step = _step_builder(Xi, yi, lami, family, screening, max_iter, tol,
                             kkt_tol, max_refits)

        def chunk_step(carry, xs):
            sp, sn, lv = xs
            pv = jnp.where(lv, pvi, 0)
            new_carry, out = step(carry, (sp, sn), pv)
            held = tuple(jnp.where(lv, nw, od)
                         for nw, od in zip(new_carry, carry))
            return held, out

        return lax.scan(chunk_step, (bi, gi, ai, Li, hi), (spi, sni, lvi))

    carry, outs = jax.vmap(one, in_axes=(0, 0, lam_axis, 0, 0, 0, 0, 0, 0,
                                         0, 0, 0))(
        X, y, lam, sig_prev, sig_next, live, beta, grad, active, L, health,
        p_valid)
    return carry, EnginePath(*outs)


def _compact_engine(X, y, lam, sigmas, family: Family, screening, max_iter,
                    tol, kkt_tol, max_refits, width, p_valid=None,
                    width2=None, rw=None, shared_x=False):
    """Natively-batched compact-working-set engine, now two-tier.

    Identical per-step semantics to ``vmap(_engine)`` with one structural
    difference: the batch axis is threaded through the *data* while control
    flow stays **scalar**.  That lets the overflow check reduce over the
    batch (``any(|E| > W_top)``) before the ``lax.cond`` that picks between
    the compact solve and the masked O(n·p) fallback — a per-member cond
    under ``vmap`` would lower to ``lax.select`` and execute BOTH branches,
    erasing the compact win.

    ``width2`` (optional, > ``width``) adds a second tier: inside the
    compact arm a nested scalar gate checks ``any(|E| > W)``; only when it
    fires does the mixed arm run, solving every member at BOTH tiers and
    per-member-selecting each member's own tier's result.  The per-member
    cond is a select by construction — that is exactly what a vmapped cond
    would lower to — but both branches are compact (O(n·W) + O(n·2W) ≈
    3·n·W), so a member whose screened set creeps just past W costs three
    W-solves instead of one O(n·p) masked solve for the whole batch.  The
    batch-wide masked fallback now fires only for demand beyond ``width2``.

    ``rw`` (optional, (B, n)) row-reweights each member's loss; with
    ``shared_x=True`` X is one shared (n, p) design (y then (B, n)), the
    replicate representation: each member's compact gather reads the SAME
    X, so resident memory is O(n·p + B·n·W) — the quarantine gate zeroes a
    sick member's weights instead of the shared data, and the masked
    fallback masks gradients (:func:`fista_shared_masked`) instead of
    columns of X.
    """
    if shared_x:
        if rw is None:
            raise ValueError("shared_x=True requires row weights (rw)")
        n, p = X.shape
        B = rw.shape[0]
    else:
        B, n, p = X.shape
    x_ax = None if shared_x else 0       # vmap axis for the design matrix
    w_ax = None if rw is None else 0     # vmap axis for the row weights
    m = family.n_classes
    dtype = X.dtype
    lam = lam.astype(dtype)
    if lam.ndim == 1:  # shared rank sequence -> per-member view
        lam = jnp.broadcast_to(lam, (B,) + lam.shape)
    pv_axis = None if p_valid is None else 0
    W = width
    W2 = width2
    if W2 is not None and W2 <= W:
        raise ValueError(f"width2 must exceed width, got {W2} <= {W}")
    W_top = W if W2 is None else W2

    def fam_shape(b):  # (p, m) -> the shape the family callbacks expect
        return b[:, 0] if m == 1 else b

    def lift(b):  # family shape -> (p, m)
        return b[:, None] if m == 1 else b

    zeros1 = jnp.zeros((p, m), dtype)

    def grad_one(Xi, yi, beta, wi=None):
        return lift(family.gradient(Xi, yi, fam_shape(beta), weights=wi))

    def dev_one(Xi, yi, beta, wi=None):
        return family.loss(Xi, yi, fam_shape(beta), weights=wi)

    grad0 = jax.vmap(lambda Xi, yi, wi: grad_one(Xi, yi, zeros1, wi),
                     in_axes=(x_ax, 0, w_ax))(X, y, rw)
    null_dev = jax.vmap(lambda Xi, yi, wi: dev_one(Xi, yi, zeros1, wi),
                        in_axes=(x_ax, 0, w_ax))(X, y, rw)
    # health inputs, mirroring _step_builder/_init_state member-for-member
    L_init0 = jax.vmap(lambda Xi, wi: default_L0(Xi, family, wi),
                       in_axes=(x_ax, w_ax))(X, rw).astype(dtype)
    finite0 = (jnp.isfinite(grad0).reshape(B, -1).all(axis=1)
               & jnp.isfinite(null_dev) & jnp.isfinite(L_init0))
    health0 = jnp.where(finite0, jnp.int32(HEALTH_OK),
                        jnp.int32(HEALTH_NONFINITE_INPUT))
    dev_bound = _DIVERGENCE_FACTOR * (jnp.abs(null_dev) + 1.0)  # (B,)
    lam_bad = ~jnp.isfinite(lam).all(axis=1)                    # (B,)

    solver_kw = dict(max_iter=max_iter, tol=tol, prox_method="parallel")

    def solve_masked_one(Xi, yi, wi, lam_next, beta, E, L):
        masked = fista_shared_masked if shared_x else fista_masked
        res = masked(Xi, yi, lam_next, fam_shape(beta), E, family,
                     L0=L, weights=wi, **solver_kw)
        return lift(res.beta), res.iters.astype(jnp.int32), res.L

    def solve_compact_one(width_t):
        def one(Xi, yi, wi, lam_next, beta, E, L):
            res = fista_compact(Xi, yi, lam_next, fam_shape(beta), E, family,
                                width=width_t, L0=L, weights=wi, **solver_kw)
            return lift(res.beta), res.iters.astype(jnp.int32), res.L
        return one

    solve_tier1 = solve_compact_one(W)
    solve_tier2 = None if W2 is None else solve_compact_one(W2)

    # per-member solve axes: the shared-X replicate form broadcasts X
    # (in_axes=None) and batches the weights; the plain form is unchanged
    solve_axes = (x_ax, 0, w_ax, 0, 0, 0, 0)

    def solve_all(Xq, yq, wq, E, lam_next, beta, L):
        need = E.sum(axis=1).astype(jnp.int32)
        # scalar reduction — keeps the fallback cond a real branch
        fell_back = jnp.any(need > W_top)
        args = (lam_next, beta, E, L)

        def tier1_all(a):
            return jax.vmap(solve_tier1, in_axes=solve_axes)(Xq, yq, wq, *a)

        if W2 is None:
            compact_arm = tier1_all
        else:
            over1 = need > W  # (B,) members whose demand needs the top tier

            def mixed(a):
                # both tiers run (a per-member cond would lower to exactly
                # this select); each member keeps its OWN tier's result, so
                # tier-1 members' coefficients come from the same W-width
                # solve a homogeneous batch would have run.  Each member's
                # *other*-tier slot is blanked (empty E, zero warm start):
                # its discarded solve then converges in one iteration
                # instead of grinding a truncated or redundant sub-problem
                # to tolerance — under vmap the solves run in lockstep, so
                # one slow discarded member would stall the whole batch
                lam_next, beta, E, L = a
                # (the solvers already zero each member's warm start through
                # its mask, so blanking E alone blanks the whole problem)
                r1 = jax.vmap(solve_tier1, in_axes=solve_axes)(
                    Xq, yq, wq, lam_next, beta, E & ~over1[:, None], L)
                r2 = jax.vmap(solve_tier2, in_axes=solve_axes)(
                    Xq, yq, wq, lam_next, beta, E & over1[:, None], L)

                def sel(two, one):
                    o = over1.reshape((B,) + (1,) * (two.ndim - 1))
                    return jnp.where(o, two, one)

                return tuple(sel(t2, t1) for t2, t1 in zip(r2, r1))

            def compact_arm(a):
                # nested scalar gate: the all-tier-1 fast path stays a real
                # branch, so homogeneous steps never pay the second gather
                return lax.cond(jnp.any(over1), mixed, tier1_all, a)

        beta1, it1, L1 = lax.cond(
            fell_back,
            lambda a: jax.vmap(solve_masked_one, in_axes=solve_axes)(
                Xq, yq, wq, *a),
            compact_arm,
            args,
        )
        grad1 = jax.vmap(grad_one, in_axes=(x_ax, 0, 0, w_ax))(
            Xq, yq, beta1, wq)
        return beta1, grad1, it1, L1, fell_back, need

    nv_one = functools.partial(_new_violations, p=p, m=m, screening=screening)

    def screen_one(grad_i, prev_i, sp_i, s_i, lam_i, pv_i):
        return _screen_sets(grad_i, prev_i, sp_i, s_i, lam_i, p=p, m=m,
                            screening=screening, p_valid=pv_i)

    def kkt_one(grad_i, lam_i, E_i, strong_i, checked_i, pv_i):
        return _kkt_step(grad_i, lam_i, E_i, strong_i, checked_i, p=p, m=m,
                         kkt_tol=kkt_tol, screening=screening, p_valid=pv_i)

    kkt_all = jax.vmap(kkt_one, in_axes=(0, 0, 0, 0, 0, pv_axis))

    def step(carry, sigs):
        beta, grad, prev_active, L_carry, health = carry
        sig_prev, sig = sigs                      # (B,), (B,)
        lam_next = sig[:, None] * lam             # (B, p·m)

        # quarantine gate, member-for-member what _step_builder applies:
        # sick members run on zeroed data/carry and a blanked working set
        # (shared X stays untouched — the member's weights are zeroed)
        sick = health != 0                        # (B,)
        Xq = (X if shared_x
              else jnp.where(sick[:, None, None], jnp.zeros((), dtype), X))
        yq = jnp.where(sick.reshape((B,) + (1,) * (y.ndim - 1)),
                       jnp.zeros((), y.dtype), y)
        wq = (None if rw is None
              else jnp.where(sick[:, None], jnp.zeros((), rw.dtype), rw))
        beta = jnp.where(sick[:, None, None], 0, beta)
        grad = jnp.where(sick[:, None, None], 0, grad)
        prev_active = prev_active & ~sick[:, None]
        L_carry = jnp.where(sick, jnp.ones((), L_carry.dtype), L_carry)

        if screening == "none":
            if p_valid is None:
                strong_p = jnp.ones((B, p), bool)
                n_screened = jnp.full((B,), p, jnp.int32)
            else:
                strong_p = jnp.arange(p)[None, :] < p_valid[:, None]
                n_screened = jnp.asarray(p_valid, jnp.int32)
            E0 = strong_p
        else:
            strong_p, E0, n_screened = jax.vmap(
                screen_one, in_axes=(0, 0, 0, 0, 0, pv_axis)
            )(grad, prev_active, sig_prev, sig, lam, p_valid)
        E0 = E0 & ~sick[:, None]
        strong_p = strong_p & ~sick[:, None]
        n_screened = jnp.where(sick, 0, n_screened)

        beta1, grad1, it1, L1, fb1, need1 = solve_all(Xq, yq, wq, E0,
                                                      lam_next, beta, L_carry)

        if screening == "none":
            beta_f, grad_f, L_f = beta1, grad1, L1
            viol_count = jnp.zeros((B,), jnp.int32)
            refits = jnp.zeros((B,), jnp.int32)
            iters = it1
            unrepaired = jnp.zeros((B,), bool)
            fell_back = fb1
            ws_max = need1
        else:
            viol1, checked1 = kkt_all(grad1, lam_next, E0, strong_p,
                                      jnp.zeros((B,), bool), p_valid)
            state = dict(
                beta=beta1, grad=grad1, L=L1,
                E=E0 | viol1.reshape(B, p, m).any(axis=2),
                checked=checked1,
                has_viol=viol1.reshape(B, -1).any(axis=1) & ~sick,
                viol_count=jax.vmap(nv_one)(viol1, strong_p, prev_active),
                refits=jnp.zeros((B,), jnp.int32), iters=it1,
                fell_back=fb1, ws_max=need1,
            )

            def cond(s):
                return jnp.any(s["has_viol"] & (s["refits"] < max_refits))

            def body(s):
                # members already KKT-clean keep their state (mirrors the
                # per-member select vmap applies to a batched while_loop).
                # Their E is blanked for this round so only members still
                # repairing count toward the overflow predicate — their
                # (discarded) solve must not force the masked fallback.
                active = s["has_viol"] & (s["refits"] < max_refits)
                beta2, grad2, it2, L2, fb2, need2 = solve_all(
                    Xq, yq, wq, s["E"] & active[:, None], lam_next,
                    s["beta"], s["L"])
                viol2, checked2 = kkt_all(grad2, lam_next, s["E"],
                                          strong_p, s["checked"], p_valid)

                def sel(new, old):
                    a = active.reshape((B,) + (1,) * (new.ndim - 1))
                    return jnp.where(a, new, old)

                viol_rows = viol2.reshape(B, p, m).any(axis=2)
                return dict(
                    beta=sel(beta2, s["beta"]),
                    grad=sel(grad2, s["grad"]),
                    L=sel(L2, s["L"]),
                    E=sel(s["E"] | viol_rows, s["E"]),
                    checked=sel(checked2, s["checked"]),
                    has_viol=sel(viol2.reshape(B, -1).any(axis=1),
                                 s["has_viol"]),
                    viol_count=s["viol_count"] + jnp.where(
                        active, jax.vmap(nv_one)(viol2, strong_p, prev_active),
                        0),
                    refits=s["refits"] + active.astype(jnp.int32),
                    iters=s["iters"] + jnp.where(active, it2, 0),
                    fell_back=s["fell_back"] | fb2,
                    ws_max=jnp.maximum(s["ws_max"], need2),
                )

            state = lax.while_loop(cond, body, state)
            beta_f, grad_f, L_f = state["beta"], state["grad"], state["L"]
            viol_count = state["viol_count"]
            refits = state["refits"]
            iters = state["iters"]
            unrepaired = state["has_viol"]  # loop exited on the refit cap
            fell_back = state["fell_back"]
            ws_max = state["ws_max"]

        dev = jax.vmap(dev_one, in_axes=(x_ax, 0, 0, w_ax))(Xq, yq, beta_f,
                                                            wq)
        # health detection + output quarantine, member-for-member what
        # _step_builder applies (sticky word, NaNs never escape the carry)
        bad_input = lam_bad | ~(jnp.isfinite(sig_prev) & jnp.isfinite(sig))
        bad_state = ~(jnp.isfinite(beta_f).reshape(B, -1).all(axis=1)
                      & jnp.isfinite(grad_f).reshape(B, -1).all(axis=1)
                      & jnp.isfinite(L_f))
        bad_dev = ~jnp.isfinite(dev) | (dev > dev_bound)
        zero32 = jnp.zeros((B,), jnp.int32)
        health = (health
                  | jnp.where(bad_input, jnp.int32(HEALTH_NONFINITE_INPUT),
                              zero32)
                  | jnp.where(bad_state, jnp.int32(HEALTH_NONFINITE_STATE),
                              zero32)
                  | jnp.where(bad_dev, jnp.int32(HEALTH_DIVERGED), zero32))
        sick_out = health != 0
        beta_f = jnp.where(sick_out[:, None, None], 0, beta_f)
        grad_f = jnp.where(sick_out[:, None, None], 0, grad_f)
        L_f = jnp.where(sick_out, jnp.ones((), L_f.dtype), L_f)

        active = (jnp.abs(beta_f) > 0).any(axis=2)
        # which tier served each member this step: 0 on fallback steps (the
        # whole batch ran masked), else the smallest tier covering the
        # member's peak demand across repair rounds
        tier = jnp.where(fell_back, jnp.int32(0),
                         jnp.where(ws_max > W, jnp.int32(2), jnp.int32(1)))
        out = (beta_f, active.sum(axis=1).astype(jnp.int32), n_screened,
               viol_count, refits, iters, dev, unrepaired, health, ws_max,
               tier, fell_back & jnp.ones((B,), bool))
        return (beta_f, grad_f, active, L_f, health), out

    carry0 = (jnp.zeros((B, p, m), dtype), grad0, jnp.zeros((B, p), bool),
              L_init0, health0)
    xs = (sigmas[:, :-1].T, sigmas[:, 1:].T)  # scan over the path axis
    _, outs = lax.scan(step, carry0, xs)
    (betas, n_act, n_scr, viol, refits, iters, devs, unrep, hlth, ws, tiers,
     fb) = outs

    def pre(a, v):
        a = jnp.moveaxis(a, 0, 1)  # (L-1, B, ...) -> (B, L-1, ...)
        v = jnp.broadcast_to(jnp.asarray(v, a.dtype),
                             (a.shape[0],) + a.shape[2:])
        return jnp.concatenate([v[:, None], a], axis=1)

    ep = EnginePath(
        betas=pre(betas, jnp.zeros((p, m), dtype)),
        n_active=pre(n_act, 0),
        n_screened=pre(n_scr, 0),
        n_violations=pre(viol, 0),
        refits=pre(refits, 0),
        solver_iters=pre(iters, 0),
        deviance=jnp.concatenate([null_dev[:, None],
                                  jnp.moveaxis(devs, 0, 1)], axis=1),
        kkt_unrepaired=pre(unrep, False),
        health=jnp.concatenate([health0[:, None],
                                jnp.moveaxis(hlth, 0, 1)], axis=1),
    )
    stats = CompactStats(ws_size=pre(ws, 0), tier=pre(tiers, 1),
                         fell_back=pre(fb, False))
    return ep, stats


_COMPACT_STATICS = _ENGINE_STATICS + ("width", "width2")


@functools.partial(jax.jit, static_argnames=_COMPACT_STATICS)
def compact_path_engine(X, y, lam, sigmas, family: Family, p_valid=None, *,
                        width: int, width2: int | None = None,
                        screening: str = "strong", max_iter: int = 5000,
                        tol: float = 1e-8, kkt_tol: float = 1e-4,
                        max_refits: int = 32):
    """Batched path engine with working sets compacted to a static ``width``
    bucket: the inner solve costs O(n·W) instead of O(n·p), with a batch-wide
    ``lax.cond`` fallback to the masked full-width solve on overflow.

    ``width2`` (optional) adds a second compact tier: members whose screened
    set exceeds ``width`` but fits ``width2`` are served by a wider gather
    instead of dragging the whole batch into the masked fallback (which then
    fires only for demand beyond ``width2``).

    ``X``: (B, n, p); ``y``: (B, n[, ...]); ``sigmas``: (B, L); ``lam``
    shared (p·m,) or per-member (B, p·m); ``p_valid`` (optional, (B,)
    int32) marks bucket padding per member.  Returns ``(EnginePath,
    CompactStats)`` with leading batch axes.  One compilation per
    (B, n, p, m, L, W, W2, config).
    """
    return _compact_engine(X, y, lam, sigmas, family, screening, max_iter,
                           tol, kkt_tol, max_refits, width, p_valid, width2)


@functools.partial(jax.jit, static_argnames=_COMPACT_STATICS)
def replicate_compact_path_engine(X, y, lam, sigmas, weights,
                                  family: Family, p_valid=None, *,
                                  width: int, width2: int | None = None,
                                  screening: str = "strong",
                                  max_iter: int = 5000, tol: float = 1e-8,
                                  kkt_tol: float = 1e-4,
                                  max_refits: int = 32):
    """Compact-working-set replicate engine: B row-reweighted paths against
    ONE shared (n, p) X with per-member W-bucket gathers.

    The compact counterpart of :func:`replicate_path_engine`: each member
    gathers its ≤ W screened columns from the SAME shared design, so the
    resident footprint is O(n·p + B·n·W) — the only per-member matrix ever
    built is the (n, W) compact gather the inner solves run on.  ``X``:
    (n, p); ``y``: (n,) shared or (B, n) per-member; ``weights``: (B, n);
    ``sigmas``: (L,) shared grid; ``lam`` one (p·m,) sequence.  Returns
    ``(EnginePath, CompactStats)`` with leading (B,) replicate axes.
    """
    B = weights.shape[0]
    if y.ndim == 1:
        y = jnp.broadcast_to(y, (B,) + y.shape)
    sig = jnp.broadcast_to(sigmas, (B,) + sigmas.shape)
    if p_valid is not None:  # shared scalar -> the engine's per-member form
        p_valid = jnp.broadcast_to(jnp.asarray(p_valid, jnp.int32), (B,))
    return _compact_engine(X, y, lam, sig, family, screening, max_iter,
                           tol, kkt_tol, max_refits, width, p_valid, width2,
                           rw=weights, shared_x=True)


# ---------------------------------------------------------------------------
# Host-facing wrappers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPathResult:
    """B paths fitted by one compiled program (leading axis = problem)."""

    betas: np.ndarray         # (B, L, p) or (B, L, p, m)
    sigmas: np.ndarray        # (B, L)
    lam: np.ndarray
    n_active: np.ndarray      # (B, L)
    n_screened: np.ndarray
    n_violations: np.ndarray
    refits: np.ndarray
    solver_iters: np.ndarray
    deviance: np.ndarray
    kkt_unrepaired: np.ndarray  # (B, L) bool — see EnginePath.kkt_unrepaired
    total_time: float
    n_samples: int            # rows per problem (early-stop rules need it)
    health: np.ndarray | None = None      # (B, L) int32 HEALTH_* words
    working_set: int | None = None        # W bucket (None: masked engine)
    working_set_top: int | None = None    # second-tier bucket (None: one tier)
    ws_size: np.ndarray | None = None     # (B, L) peak |E| per step
    ws_tier: np.ndarray | None = None     # (B, L) serving tier per step
    #   (1 = W, 2 = the top tier, 0 = the step ran the masked fallback)
    compact_fallback: np.ndarray | None = None  # (B, L) masked-fallback steps
    pad_shape: tuple | None = None        # (slots, N, P) executed shape when
    #   pad="bucket" routed the batch through the serve layer's buckets
    plan: object | None = None            # repro.api ExecutionPlan when the
    #   fit was dispatched through slope_path (None for direct impl calls)
    path_trace: object | None = None      # repro.obs.PathTrace when the fit
    #   ran with telemetry="summary"|"steps" (None when "off")

    @property
    def batch(self) -> int:
        return self.betas.shape[0]

    @property
    def total_violations(self) -> np.ndarray:
        return self.n_violations.sum(axis=1)

    @property
    def path_health(self) -> PathHealth | None:
        """Per-member quarantine verdicts (None for pre-health pickles)."""
        return None if self.health is None else PathHealth(word=self.health)

    def path_results(self, *, early_stop: bool = True):
        """Per-problem :class:`repro.core.path.PathResult` views (the same
        contract the unbatched driver returns, early stopping applied
        post-hoc)."""
        from .path import engine_to_path_result  # lazy: avoid import cycle

        per = self.total_time / self.batch
        return [
            engine_to_path_result(
                EnginePath(
                    betas=self.betas[b] if self.betas.ndim == 4
                    else self.betas[b][:, :, None],
                    n_active=self.n_active[b],
                    n_screened=self.n_screened[b],
                    n_violations=self.n_violations[b],
                    refits=self.refits[b],
                    solver_iters=self.solver_iters[b],
                    deviance=self.deviance[b],
                    kkt_unrepaired=self.kkt_unrepaired[b],
                    health=(np.zeros(self.deviance[b].shape, np.int32)
                            if self.health is None else self.health[b]),
                ),
                self.sigmas[b], self.lam, per, early_stop=early_stop,
                n=self.n_samples,
            )
            for b in range(self.batch)
        ]


def null_gradient(X, y, family: Family) -> np.ndarray:
    """∇f(0) reshaped to (p, m) — the quantity both the σ-grid recipe and
    the first strong-rule step start from."""
    p = X.shape[1]
    m = family.n_classes
    beta0 = jnp.zeros((p,) if m == 1 else (p, m), X.dtype)
    return np.asarray(
        family.gradient(jnp.asarray(X), jnp.asarray(y), beta0)
    ).reshape(p, m)


def null_sigma_grid(X, y, lam, family: Family, *, path_length: int,
                    sigma_ratio: float | None,
                    grad0: np.ndarray | None = None) -> np.ndarray:
    """The paper's σ grid for one problem: σ(1) from the null gradient's
    dual gauge, geometric decay per §3.1.2.  The ONE recipe shared by
    fit_path (both backends), fit_path_batched and cv_path."""
    if grad0 is None:
        grad0 = null_gradient(X, y, family)
    s1 = float(path_start_sigma(jnp.asarray(grad0), jnp.asarray(lam)))
    n, p = X.shape
    return sigma_grid(s1, length=path_length, ratio=sigma_ratio, n=n, p=p)


def _null_sigma_grids(Xs, ys, lam, family: Family, path_length, sigma_ratio):
    """Per-problem σ grids (stacked :func:`null_sigma_grid`)."""
    lam = np.asarray(lam)
    return np.stack([
        null_sigma_grid(Xs[b], ys[b], lam[b] if lam.ndim == 2 else lam,
                        family, path_length=path_length,
                        sigma_ratio=sigma_ratio)
        for b in range(Xs.shape[0])
    ])


# Grow-on-overflow bucket memory: (n, p, m, family, screening) → last W that
# overflowed, promoted to the next power of two.  Correctness never depends
# on it (overflow steps fall back to the masked solve in-graph); it just
# stops the NEXT same-shape call from paying the fallback again.  A proper
# thread-safe bounded registry (PR 3) shared with repro.serve: the path
# service resolves compact widths through this same instance, so a service
# batch that overflows grows the bucket the next direct call sees.
_WS_BUCKETS = BucketRegistry(name="working_set", capacity=256)

_next_pow2 = next_pow2  # promoted to repro.serve.buckets; alias kept local


def _ws_bucket(working_set, n: int, p: int, key: tuple) -> int:
    """Resolve the static compact width W to a power-of-two bucket ≤ p."""
    if isinstance(working_set, int):
        if working_set < 1:
            raise ValueError(f"working_set must be ≥ 1, got {working_set}")
        return min(_next_pow2(working_set), p)
    if working_set != "auto":
        raise ValueError(
            f"working_set must be None, an int or 'auto', got {working_set!r}")
    grown = _WS_BUCKETS.get(key)
    if grown is not None:
        return min(grown, p)
    # p ≫ n: the screened set tracks the active set, which cannot exceed n
    # useful coefficients by much — 2n is a comfortable first bucket
    return min(_next_pow2(max(2 * n, 64)), p)


def second_tier_width(W: int, ws_tiers, p: int) -> int | None:
    """The second tier for a resolved W bucket: ``2·W`` for ``ws_tiers``
    "auto"/2 whenever ``2·W < p`` (a top tier spanning p would be the
    masked solve with gather overhead on top, so it degenerates to
    single-tier), None otherwise.  Factored out so the planner can derive
    the tier pair from its already-previewed W — one registry read, no
    window for the pair to desynchronize."""
    if ws_tiers not in ("auto", 1, 2):
        raise ValueError(
            f"ws_tiers must be 'auto', 1 or 2, got {ws_tiers!r}")
    if ws_tiers == 1 or 2 * W >= p:
        return None
    return 2 * W


def resolve_ws_tiers(working_set, ws_tiers, n: int, p: int,
                     key: tuple) -> tuple[int, int | None]:
    """Resolve the compact tier widths ``(W, W2)`` for one run.

    ``W`` comes from :func:`_ws_bucket` (explicit int / registry / auto
    recipe); ``W2`` from :func:`second_tier_width`.  The ONE tier recipe,
    shared by the engine, the planner preview and the serve layer so the
    three can never disagree on what shape actually compiles.
    """
    W = _ws_bucket(working_set, n, p, key)
    return W, second_tier_width(W, ws_tiers, p)


def grow_ws_bucket(ws_key: tuple, ws_size, fell_back, W: int,
                   p_cap: int, *, two_tier: bool = False) -> bool:
    """Grow the shared working-set registry after an overflowing "auto" run.

    ``ws_size``/``fell_back`` are the run's CompactStats arrays (real
    members only); ``p_cap`` bounds the promoted bucket (a bucket wider
    than the column count is wasted compaction).  ``two_tier`` marks a run
    whose next same-shape call will carry a 2W second tier: the registry
    then only needs the HALF-peak bucket — tier 2 covers (W, 2W], so
    ``W = 2^⌈log₂ peak⌉ / 2`` already makes the whole observed demand
    compact-servable at half the gather width the single-tier rule would
    store.  The ONE growth rule, shared by :func:`fit_path_batched` and
    the path service so the two front-ends can never desynchronize the
    registry they share.  Growth is monotonic and idempotent
    (:meth:`BucketRegistry.grow`): concurrent overflowing runs can only
    raise the stored bucket, never shrink it.  Returns True if the bucket
    grew.
    """
    if W >= p_cap or not np.asarray(fell_back).any():
        return False
    target = _next_pow2(int(np.asarray(ws_size).max()))
    if two_tier and target < p_cap:
        # the next run's second tier will sit at 2·(target/2) = target and
        # cover the observed peak; fell_back implies peak > 2W, so the
        # half-peak bucket (≥ 2W) still strictly exceeds the current W.
        # (target ≥ p_cap keeps the full width: a halved bucket would get
        # no 2× tier under the cap and just overflow again.)
        target = max(target // 2, 1)
    return _WS_BUCKETS.grow(ws_key, target, cap=p_cap)


def _fit_path_batched(
    Xs, ys, lam, family: Family, *,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
    working_set: int | str | None = None,
    ws_tiers: int | str = DEFAULT_WS_TIERS,
    pad: str | None = None,
    telemetry: str = "off",
) -> BatchedPathResult:
    """Fit B independent SLOPE paths in one compiled device program.

    ``Xs`` is (B, n, p) and ``ys`` (B, n) — problems of identical shape share
    one compilation (the bucketing policy: pad mixed shapes on the host).
    Semantics match ``fit_path(..., engine="device")`` per problem.  Steps
    whose KKT repair hit ``max_refits`` are flagged in ``kkt_unrepaired``
    (and warned about) — raise the cap if that ever fires.

    ``lam`` is one shared (p·m,) rank sequence or a per-problem (B, p·m)
    stack (what the serve layer uses to co-batch requests of different
    native widths inside one padded program).

    ``working_set`` selects the compact engine: an int requests a static
    width bucket W (rounded up to a power of two, capped at p), ``"auto"``
    picks ``min(2^⌈log₂ max(2n, 64)⌉, p)`` with grow-on-overflow memory, and
    ``None`` keeps the masked full-width engine.  Compact solves cost
    O(n·W) per FISTA iteration.  ``ws_tiers`` ("auto"/1/2, see
    :func:`resolve_ws_tiers`) controls the second tier at 2·W: a member
    whose working set outgrows W but fits 2·W is served by the wider
    gather; only demand beyond the top tier falls back — correctly,
    in-graph — to the masked solve for the whole batch and is flagged in
    ``compact_fallback`` (per-member serving tiers in ``ws_tier``).

    ``pad="bucket"`` routes the batch through the serve layer's canonical
    execution shapes (:mod:`repro.serve.buckets`): rows/columns/batch slots
    are padded to power-of-two buckets with inert zeros, screening and KKT
    checks are restricted to the valid prefix (``p_valid``), and results
    come back unpadded.  Problems then share compiled programs across
    nearby shapes — and, because the :class:`~repro.serve.service.PathService`
    resolves shapes through the same policy, a padded direct call is
    bit-identical to the same request served through the service.
    """
    Xs = np.asarray(Xs)
    ys = np.asarray(ys)
    if Xs.ndim != 3:
        raise ValueError(f"Xs must be (B, n, p), got {Xs.shape}")
    if ys.shape[:2] != Xs.shape[:2]:
        raise ValueError(
            f"ys must be (B, n[, ...]) matching Xs {Xs.shape[:2]}, got {ys.shape}")
    if pad not in (None, "bucket"):
        raise ValueError(f"pad must be None or 'bucket', got {pad!r}")
    if telemetry not in ("off", "summary", "steps"):
        raise ValueError(
            f"telemetry must be 'off', 'summary' or 'steps', got "
            f"{telemetry!r}")
    lam = np.asarray(lam)
    B, n, p = Xs.shape
    m = family.n_classes
    if lam.ndim == 2 and lam.shape != (B, p * m):
        raise ValueError(
            f"per-problem lam must be (B, p·m) = {(B, p * m)}, got {lam.shape}")
    if lam.ndim not in (1, 2):
        raise ValueError(f"lam must be (p·m,) or (B, p·m), got {lam.shape}")
    if sigmas is None:
        sigmas = _null_sigma_grids(Xs, ys, lam, family, path_length,
                                   sigma_ratio)
    sigmas = np.asarray(sigmas)
    if sigmas.ndim == 1:  # one shared grid, like fit_path's 1-D sigmas
        sigmas = np.tile(sigmas, (B, 1))
    if sigmas.shape[0] != B or sigmas.ndim != 2:
        raise ValueError(
            f"sigmas must be (L,) shared or (B, L) per-problem; got "
            f"{sigmas.shape} for B={B}")

    p_valid = None
    pad_shape = None
    Xs_run, ys_run, lam_run, sig_run = Xs, ys, lam, sigmas
    n_run, p_run = n, p
    if pad == "bucket":
        from ..serve.buckets import default_policy, pad_batch

        policy = default_policy()
        n_run, p_run = policy.shape_bucket(n, p, family.name)
        slots = policy.batch_bucket(B)
        lam2 = lam if lam.ndim == 2 else np.broadcast_to(lam, (B, p * m))
        pb = pad_batch(
            [(Xs[b], ys[b], lam2[b], sigmas[b]) for b in range(B)],
            n_rows=n_run, n_cols=p_run, n_slots=slots, n_classes=m)
        Xs_run, ys_run, lam_run, sig_run = pb.Xs, pb.ys, pb.lam, pb.sigmas
        p_valid = jnp.asarray(pb.p_valid)
        pad_shape = (slots, n_run, p_run)

    engine_kw = dict(screening=screening, max_iter=max_iter, tol=solver_tol,
                     kkt_tol=kkt_tol, max_refits=max_refits)
    t0 = time.perf_counter()
    W = W2 = None
    stats = None
    if working_set is None:
        res = batched_path_engine(
            jnp.asarray(Xs_run), jnp.asarray(ys_run), jnp.asarray(lam_run),
            jnp.asarray(sig_run), family, p_valid, **engine_kw)
    else:
        ws_key = (n_run, p_run, m, family.name, screening)
        W, W2 = resolve_ws_tiers(working_set, ws_tiers, n_run, p_run, ws_key)
        res, stats = compact_path_engine(
            jnp.asarray(Xs_run), jnp.asarray(ys_run), jnp.asarray(lam_run),
            jnp.asarray(sig_run), family, p_valid, width=W, width2=W2,
            **engine_kw)
    res = EnginePath(*(np.asarray(a) for a in res))
    wall = time.perf_counter() - t0
    if stats is not None:
        stats = CompactStats(*(np.asarray(a) for a in stats))
    if pad_shape is not None:  # drop dummy slots + padded columns
        res = EnginePath(
            betas=res.betas[:B, :, :p, :],
            n_active=res.n_active[:B], n_screened=res.n_screened[:B],
            n_violations=res.n_violations[:B], refits=res.refits[:B],
            solver_iters=res.solver_iters[:B], deviance=res.deviance[:B],
            kkt_unrepaired=res.kkt_unrepaired[:B], health=res.health[:B])
        if stats is not None:
            stats = CompactStats(ws_size=stats.ws_size[:B],
                                 tier=stats.tier[:B],
                                 fell_back=stats.fell_back[:B])
    betas = res.betas  # (B, L, p, m)
    if m == 1:
        betas = betas[:, :, :, 0]
    unrepaired = res.kkt_unrepaired
    _warn_unrepaired(unrepaired, max_refits)
    _warn_quarantined(res.health)
    ws_size = ws_tier = fallback = None
    if stats is not None:
        ws_size = stats.ws_size
        ws_tier = stats.tier
        fallback = stats.fell_back
        # grow the bucket for the next same-shape "auto" call; explicit-int
        # runs (e.g. a deliberately undersized overflow probe) must not
        # seed "auto" with a bucket below its documented default
        if working_set == "auto":
            grow_ws_bucket(ws_key, ws_size, fallback, W, p_run,
                           two_tier=ws_tiers != 1)
    path_trace = None
    if telemetry != "off":
        # built host-side from arrays the transfer above already landed —
        # one per fit, off the compiled program's path entirely
        from ..obs import PathTrace

        path_trace = PathTrace.from_arrays(
            mode=telemetry, p=p, sigmas=sigmas,
            n_screened=res.n_screened, n_active=res.n_active,
            n_violations=res.n_violations, refits=res.refits,
            solver_iters=res.solver_iters, health=res.health,
            working_set=W, working_set_top=W2, ws_size=ws_size,
            ws_tier=ws_tier, compact_fallback=fallback)
    return BatchedPathResult(
        betas=betas,
        sigmas=sigmas,
        lam=lam,
        n_active=res.n_active,
        n_screened=res.n_screened,
        n_violations=res.n_violations,
        refits=res.refits,
        solver_iters=res.solver_iters,
        deviance=res.deviance,
        kkt_unrepaired=unrepaired,
        total_time=wall,
        n_samples=n,
        health=res.health,
        working_set=W,
        working_set_top=W2,
        ws_size=ws_size,
        ws_tier=ws_tier,
        compact_fallback=fallback,
        pad_shape=pad_shape,
        path_trace=path_trace,
    )


def _fit_replicate_batched(
    X, y, lam, family: Family, weights, *,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
    working_set: int | str | None = None,
    ws_tiers: int | str = DEFAULT_WS_TIERS,
    telemetry: str = "off",
) -> BatchedPathResult:
    """Fit B row-reweighted paths against ONE shared (n, p) design.

    The replicate counterpart of :func:`_fit_path_batched`: ``X`` is a
    single (n, p) design shared by every member, ``weights`` a (B, n)
    per-member row-weight matrix (bootstrap counts / subsample masks /
    direct sample weights), ``y`` the shared (n,) response or a (B, n)
    per-member stack (permutation replicates).  Memory stays
    O(n·p + B·n) — no (B, n, p) batch is ever materialized.

    The σ grid is shared across members (computed from the *unweighted*
    problem when not given), so per-grid-point statistics compare like
    with like; a (B, n) ``y`` needs an explicit ``sigmas``.
    """
    X = np.asarray(X)
    lam = np.asarray(lam)
    weights_np = np.asarray(weights)
    if X.ndim != 2:
        raise ValueError(f"X must be one shared (n, p) design, got {X.shape}")
    n, p = X.shape
    if weights_np.ndim != 2 or weights_np.shape[1] != n:
        raise ValueError(
            f"weights must be (B, n) = (B, {n}), got {weights_np.shape}")
    B = weights_np.shape[0]
    m = family.n_classes
    y_np = np.asarray(y)
    if sigmas is None:
        if y_np.ndim != 1:  # per-member (B, n) stack: no canonical grid
            raise ValueError(
                "per-member (B, n) responses need an explicit shared σ "
                "grid (compute it from the original problem first)")
        sigmas = null_sigma_grid(X, y_np, lam, family,
                                 path_length=path_length,
                                 sigma_ratio=sigma_ratio)
    sigmas = np.asarray(sigmas)
    if sigmas.ndim != 1:
        raise ValueError(
            f"replicates share one (L,) σ grid, got {sigmas.shape}")

    engine_kw = dict(screening=screening, max_iter=max_iter, tol=solver_tol,
                     kkt_tol=kkt_tol, max_refits=max_refits)
    t0 = time.perf_counter()
    W = W2 = None
    stats = None
    if working_set is None:
        res = replicate_path_engine(
            jnp.asarray(X), jnp.asarray(y_np), jnp.asarray(lam),
            jnp.asarray(sigmas), jnp.asarray(weights_np), family, **engine_kw)
    else:
        ws_key = (n, p, m, family.name, screening)
        W, W2 = resolve_ws_tiers(working_set, ws_tiers, n, p, ws_key)
        res, stats = replicate_compact_path_engine(
            jnp.asarray(X), jnp.asarray(y_np), jnp.asarray(lam),
            jnp.asarray(sigmas), jnp.asarray(weights_np), family,
            width=W, width2=W2, **engine_kw)
    res = EnginePath(*(np.asarray(a) for a in res))
    wall = time.perf_counter() - t0
    if stats is not None:
        stats = CompactStats(*(np.asarray(a) for a in stats))
    betas = res.betas  # (B, L, p, m)
    if m == 1:
        betas = betas[:, :, :, 0]
    unrepaired = res.kkt_unrepaired
    _warn_unrepaired(unrepaired, max_refits)
    _warn_quarantined(res.health)
    ws_size = ws_tier = fallback = None
    if stats is not None:
        ws_size = stats.ws_size
        ws_tier = stats.tier
        fallback = stats.fell_back
        if working_set == "auto":
            grow_ws_bucket(ws_key, ws_size, fallback, W, p,
                           two_tier=ws_tiers != 1)
    path_trace = None
    if telemetry != "off":
        from ..obs import PathTrace

        path_trace = PathTrace.from_arrays(
            mode=telemetry, p=p, sigmas=np.tile(sigmas, (B, 1)),
            n_screened=res.n_screened, n_active=res.n_active,
            n_violations=res.n_violations, refits=res.refits,
            solver_iters=res.solver_iters, health=res.health,
            working_set=W, working_set_top=W2, ws_size=ws_size,
            ws_tier=ws_tier, compact_fallback=fallback)
    return BatchedPathResult(
        betas=betas,
        sigmas=np.tile(sigmas, (B, 1)),
        lam=lam,
        n_active=res.n_active,
        n_screened=res.n_screened,
        n_violations=res.n_violations,
        refits=res.refits,
        solver_iters=res.solver_iters,
        deviance=res.deviance,
        kkt_unrepaired=unrepaired,
        total_time=wall,
        n_samples=n,
        health=res.health,
        working_set=W,
        working_set_top=W2,
        ws_size=ws_size,
        ws_tier=ws_tier,
        compact_fallback=fallback,
        path_trace=path_trace,
    )


def _warn_unrepaired(unrepaired: np.ndarray, max_refits: int) -> None:
    if unrepaired.any():
        import warnings

        warnings.warn(
            f"{int(unrepaired.sum())} path step(s) hit the KKT repair cap "
            f"(max_refits={max_refits}) with violations outstanding; those "
            "betas are not KKT-clean — raise max_refits",
            RuntimeWarning,
            stacklevel=3,
        )


def _warn_quarantined(health: np.ndarray) -> None:
    word = np.asarray(health)[:, -1]
    if word.any():
        import warnings

        bad = np.nonzero(word)[0]
        causes = sorted({c for w in word[bad] for c in health_causes(int(w))})
        warnings.warn(
            f"{bad.size} batch member(s) were quarantined in-graph "
            f"(members {bad.tolist()}, causes: {', '.join(causes)}); their "
            "betas are zeroed from the first sick step — inspect "
            "result.path_health",
            RuntimeWarning,
            stacklevel=3,
        )


@dataclasses.dataclass
class CvPathResult:
    """K-fold cross-validation over one shared σ grid."""

    sigmas: np.ndarray            # (L,) shared grid
    lam: np.ndarray
    val_deviance: np.ndarray      # (K, L) held-out deviance per fold
    mean_val_deviance: np.ndarray  # (L,)
    best_index: int               # per the requested selection rule
    best_sigma: float
    fold_paths: BatchedPathResult
    total_time: float
    se_val_deviance: np.ndarray | None = None  # (L,) SE over folds
    best_index_min: int = 0       # argmin of the mean deviance
    best_index_1se: int = 0       # sparsest σ within 1 SE of the minimum
    selection: str = "min"
    plan: object | None = None    # repro.api ExecutionPlan (slope_path only)


def cv_fold_indices(y, n_folds: int, *, family: Family | None = None,
                    stratify="auto"):
    """Equal-size fold assignment shared by :func:`cv_path` and the serve
    layer's CV requests.

    Every validation fold has exactly ⌊n/K⌋ rows (remainder rows are always
    in training) so all K training designs share ONE shape and batch into a
    single compiled program.  ``stratify=True`` deals class-sorted rows
    round-robin across folds so each fold sees the full-data class mix —
    essential for binomial/multinomial families, where a contiguous fold
    can end up single-class (its held-out deviance is then degenerate).
    ``"auto"`` stratifies exactly for those families.  Returns
    ``(trains, vals)``: two lists of K index arrays.
    """
    y = np.asarray(y)
    n = y.shape[0]
    if not 2 <= n_folds <= n:
        raise ValueError(f"n_folds must be in [2, {n}], got {n_folds}")
    if stratify == "auto":
        stratify = family is not None and family.name in ("logistic",
                                                          "multinomial")
    fold = n // n_folds
    if not stratify:
        vals = [np.arange(k * fold, (k + 1) * fold) for k in range(n_folds)]
    else:
        classes = np.asarray(np.rint(y), np.int64)
        order = np.argsort(classes, kind="stable")  # group rows by class
        assign = np.empty(n, np.int64)
        assign[order] = np.arange(n) % n_folds      # deal round-robin
        # trim each fold to exactly ⌊n/K⌋ rows; trimmed rows join the
        # always-in-training remainder, same as the contiguous scheme
        vals = [np.nonzero(assign == k)[0][:fold] for k in range(n_folds)]
    trains = [np.setdiff1d(np.arange(n), v) for v in vals]
    return trains, vals


def cv_val_deviance(X, y, val_indices, fold_betas, family: Family):
    """Held-out deviance (K, L) for stacked per-fold path coefficients.

    One batched evaluation of all K × L deviances (the fold and path axes
    share shapes, so this is two nested vmaps, not K·L dispatches).  Shared
    by :func:`cv_path` and the serve layer's CV aggregation so both compute
    bit-identical selection criteria from the same fold fits.
    """
    X = np.asarray(X)
    y = np.asarray(y)
    Xv = jnp.asarray(np.stack([X[v] for v in val_indices]))
    yv = jnp.asarray(np.stack([y[v] for v in val_indices]))

    def fold_devs(Xvk, yvk, betas_k):
        return jax.vmap(lambda b: family.loss(Xvk, yvk, b))(betas_k)

    return np.asarray(jax.vmap(fold_devs)(Xv, yv, jnp.asarray(fold_betas)))


def cv_select(val_dev: np.ndarray):
    """Deviance-based λ selection from a (K, L) held-out deviance table.

    Returns ``(mean, se, best_min, best_1se)``: the fold mean and its
    standard error per path point, the argmin index, and the 1-SE index —
    the *sparsest* grid point (largest σ, smallest index) whose mean
    deviance is within one standard error of the minimum.  The 1-SE rule
    trades a statistically-insignificant deviance increase for a sparser,
    more stable model (the ROADMAP's deviance-based 1-SE rule).
    """
    val_dev = np.asarray(val_dev)
    K = val_dev.shape[0]
    mean = val_dev.mean(axis=0)
    se = val_dev.std(axis=0, ddof=1) / np.sqrt(K)
    best_min = int(np.argmin(mean))
    thresh = mean[best_min] + se[best_min]
    best_1se = int(np.argmax(mean <= thresh))  # first index ⇔ largest σ
    return mean, se, best_min, best_1se


def _cv_path(
    X, y, lam, family: Family, *,
    n_folds: int = 5,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
    working_set: int | str | None = None,
    ws_tiers: int | str = DEFAULT_WS_TIERS,
    stratify="auto",
    selection: str = "min",
    pad: str | None = None,
) -> CvPathResult:
    """K-fold CV: all fold paths fit as ONE batched device program.

    Every validation fold holds exactly ⌊n/K⌋ rows (remainder rows always
    in training) so the K training designs share one shape and batch into a
    single compilation; ``stratify`` controls class-balanced fold
    assignment (``"auto"``: on for binomial/multinomial — see
    :func:`cv_fold_indices`).  The σ grid is computed once from the full
    data and shared, so every fold is evaluated at the same penalty.

    ``selection`` picks the reported ``best_index``: ``"min"`` (lowest mean
    held-out deviance) or ``"1se"`` (sparsest σ within one standard error
    of it); both candidates are always reported.  ``working_set`` selects
    the compact engine exactly as in :func:`fit_path_batched` — the natural
    fit for CV's p ≫ n folds — and ``pad="bucket"`` routes the fold batch
    through the serve layer's canonical execution shapes.
    """
    if selection not in ("min", "1se"):
        raise ValueError(f"selection must be 'min' or '1se', got {selection!r}")
    t0 = time.perf_counter()
    X = np.asarray(X)
    y = np.asarray(y)
    lam = np.asarray(lam)

    sigmas = null_sigma_grid(X, y, lam, family, path_length=path_length,
                             sigma_ratio=sigma_ratio)

    trains, vals = cv_fold_indices(y, n_folds, family=family,
                                   stratify=stratify)
    res = _fit_path_batched(
        np.stack([X[tr] for tr in trains]),
        np.stack([y[tr] for tr in trains]),
        lam, family, screening=screening,
        sigmas=sigmas, solver_tol=solver_tol,  # 1-D grid: shared across folds
        max_iter=max_iter, kkt_tol=kkt_tol, max_refits=max_refits,
        working_set=working_set, ws_tiers=ws_tiers, pad=pad,
    )

    val_dev = cv_val_deviance(X, y, vals, res.betas, family)
    mean_dev, se_dev, best_min, best_1se = cv_select(val_dev)
    best = best_1se if selection == "1se" else best_min
    return CvPathResult(
        sigmas=sigmas,
        lam=lam,
        val_deviance=val_dev,
        mean_val_deviance=mean_dev,
        best_index=best,
        best_sigma=float(sigmas[best]),
        fold_paths=res,
        total_time=time.perf_counter() - t0,
        se_val_deviance=se_dev,
        best_index_min=best_min,
        best_index_1se=best_1se,
        selection=selection,
    )


# ---------------------------------------------------------------------------
# Legacy entry points — thin shims over the declarative repro.api layer
# ---------------------------------------------------------------------------

# "kwarg not passed" sentinel (legacy defaults must not warn).  Local on
# purpose: importing repro.api.compat.UNSET at module level would run
# repro.api/__init__ while repro.core is still initialising (api.plan pulls
# engine attributes) — each shim module only ever compares its own sentinel.
_UNSET = object()


def _legacy_backend(working_set):
    """Map the legacy ``working_set`` knob onto a SolverPolicy backend."""
    if working_set is None:
        return "masked", "auto"
    if working_set == "auto" or (isinstance(working_set, int)
                                 and not isinstance(working_set, bool)):
        return "compact", working_set
    raise ValueError(
        f"working_set must be None, an int or 'auto', got {working_set!r}")


def fit_path_batched(
    Xs, ys, lam, family: Family, *,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    sigmas: np.ndarray | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
    working_set: int | str | None = _UNSET,
    pad: str | None = _UNSET,
) -> BatchedPathResult:
    """Fit B independent SLOPE paths in one compiled device program.

    Legacy entry point, now a thin shim over :func:`repro.api.slope_path`:
    the kwargs are translated into a ``(Problem, PathSpec, SolverPolicy)``
    triple and dispatch through the same planned layer (results are
    bit-identical to PR-1..3 behaviour).  ``working_set=`` and ``pad=``
    have spec-field replacements and warn once per process — see
    ``docs/MIGRATION.md``.
    """
    from ..api import LambdaSpec, PathSpec, Problem, SolverPolicy, slope_path
    from ..api.compat import warn_legacy

    if working_set is _UNSET:
        working_set = None
    else:
        warn_legacy("fit_path_batched", "working_set",
                    "SolverPolicy(backend='compact', working_set=...)")
    if pad is _UNSET:
        pad = None
    else:
        warn_legacy("fit_path_batched", "pad", "SolverPolicy(pad=...)")
    Xs = np.asarray(Xs)
    ys = np.asarray(ys)
    if Xs.ndim != 3:
        raise ValueError(f"Xs must be (B, n, p), got {Xs.shape}")
    if ys.shape[:2] != Xs.shape[:2]:
        raise ValueError(
            f"ys must be (B, n[, ...]) matching Xs {Xs.shape[:2]}, got {ys.shape}")
    backend, ws = _legacy_backend(working_set)
    return slope_path(
        Problem(Xs, ys, family=family),
        PathSpec(lam=LambdaSpec.explicit(lam), path_length=path_length,
                 sigma_ratio=sigma_ratio, sigmas=sigmas),
        SolverPolicy(backend=backend, working_set=ws, pad=pad,
                     screening=screening, solver_tol=solver_tol,
                     max_iter=max_iter, kkt_tol=kkt_tol,
                     max_refits=max_refits),
    )


def cv_path(
    X, y, lam, family: Family, *,
    n_folds: int = 5,
    screening: str = "strong",
    path_length: int = 100,
    sigma_ratio: float | None = None,
    solver_tol: float = DEFAULT_PATH_TOL,
    max_iter: int = DEFAULT_PATH_MAX_ITER,
    kkt_tol: float = DEFAULT_KKT_TOL,
    max_refits: int = DEFAULT_MAX_REFITS,
    working_set: int | str | None = _UNSET,
    stratify=_UNSET,
    selection: str = _UNSET,
    pad: str | None = _UNSET,
) -> CvPathResult:
    """K-fold CV: all fold paths fit as ONE batched device program.

    Legacy entry point, now a thin shim over :func:`repro.api.slope_path`
    with ``PathSpec(cv_folds=...)`` — results are bit-identical to the
    PR-1..3 implementation.  ``working_set=``, ``stratify=``,
    ``selection=`` and ``pad=`` have spec-field replacements and warn once
    per process — see ``docs/MIGRATION.md``.
    """
    from ..api import LambdaSpec, PathSpec, Problem, SolverPolicy, slope_path
    from ..api.compat import warn_legacy

    if working_set is _UNSET:
        working_set = None
    else:
        warn_legacy("cv_path", "working_set",
                    "SolverPolicy(backend='compact', working_set=...)")
    if stratify is _UNSET:
        stratify = "auto"
    else:
        warn_legacy("cv_path", "stratify", "PathSpec(stratify=...)")
    if selection is _UNSET:
        selection = "min"
    else:
        warn_legacy("cv_path", "selection", "PathSpec(selection=...)")
    if pad is _UNSET:
        pad = None
    else:
        warn_legacy("cv_path", "pad", "SolverPolicy(pad=...)")
    backend, ws = _legacy_backend(working_set)
    return slope_path(
        Problem(X, y, family=family),
        PathSpec(lam=LambdaSpec.explicit(lam), path_length=path_length,
                 sigma_ratio=sigma_ratio, cv_folds=n_folds,
                 stratify=stratify, selection=selection),
        SolverPolicy(backend=backend, working_set=ws, pad=pad,
                     screening=screening, solver_tol=solver_tol,
                     max_iter=max_iter, kkt_tol=kkt_tol,
                     max_refits=max_refits),
    )
