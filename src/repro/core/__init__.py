"""repro.core — faithful implementation of "The Strong Screening Rule for
SLOPE" (Larsson, Bogdan, Wallin; NeurIPS 2020)."""

from .sorted_l1 import (
    sorted_l1_norm,
    prox_sorted_l1,
    dual_sorted_l1_gauge,
    isotonic_decreasing,
    clusters,
)
from .screening import (
    algorithm_1_oracle,
    algorithm_2_oracle,
    screen_k,
    support_superset_k,
    strong_rule,
)
from .kkt import in_subdifferential, kkt_optimal, kkt_violations
from .lambda_seq import (
    bh_sequence,
    gaussian_sequence,
    oscar_sequence,
    lasso_sequence,
    path_start_sigma,
    sigma_grid,
)
from .losses import Family, ols, logistic, poisson, multinomial, get_family
from .solver import fista, FistaResult
from .path import fit_path, PathResult

__all__ = [
    "sorted_l1_norm", "prox_sorted_l1", "dual_sorted_l1_gauge",
    "isotonic_decreasing", "clusters",
    "algorithm_1_oracle", "algorithm_2_oracle", "screen_k",
    "support_superset_k", "strong_rule",
    "in_subdifferential", "kkt_optimal", "kkt_violations",
    "bh_sequence", "gaussian_sequence", "oscar_sequence", "lasso_sequence",
    "path_start_sigma", "sigma_grid",
    "Family", "ols", "logistic", "poisson", "multinomial", "get_family",
    "fista", "FistaResult",
    "fit_path", "PathResult",
]
