"""repro.core — faithful implementation of "The Strong Screening Rule for
SLOPE" (Larsson, Bogdan, Wallin; NeurIPS 2020)."""

from .sorted_l1 import (
    sorted_l1_norm,
    prox_sorted_l1,
    prox_sorted_l1_with_norm,
    dual_sorted_l1_gauge,
    isotonic_decreasing,
    isotonic_decreasing_parallel,
    clusters,
)
from .screening import (
    algorithm_1_oracle,
    algorithm_2_oracle,
    screen_k,
    screen_masked,
    support_superset_k,
    strong_rule,
)
from .kkt import in_subdifferential, kkt_optimal, kkt_violations, kkt_violations_masked
from .lambda_seq import (
    bh_sequence,
    gaussian_sequence,
    oscar_sequence,
    lasso_sequence,
    path_start_sigma,
    sigma_grid,
)
from .losses import Family, ols, logistic, poisson, multinomial, get_family
from .solver import fista, fista_masked, fista_compact, FistaResult
from .engine import (
    path_engine,
    batched_path_engine,
    compact_path_engine,
    fit_path_batched,
    cv_path,
    cv_fold_indices,
    cv_val_deviance,
    cv_select,
    EnginePath,
    CompactStats,
    BatchedPathResult,
    CvPathResult,
)
from .path import fit_path, PathResult, PathStep

__all__ = [
    "sorted_l1_norm", "prox_sorted_l1", "prox_sorted_l1_with_norm",
    "dual_sorted_l1_gauge",
    "isotonic_decreasing", "isotonic_decreasing_parallel", "clusters",
    "algorithm_1_oracle", "algorithm_2_oracle", "screen_k", "screen_masked",
    "support_superset_k", "strong_rule",
    "in_subdifferential", "kkt_optimal", "kkt_violations",
    "kkt_violations_masked",
    "bh_sequence", "gaussian_sequence", "oscar_sequence", "lasso_sequence",
    "path_start_sigma", "sigma_grid",
    "Family", "ols", "logistic", "poisson", "multinomial", "get_family",
    "fista", "fista_masked", "fista_compact", "FistaResult",
    "path_engine", "batched_path_engine", "compact_path_engine",
    "fit_path_batched", "cv_path",
    "cv_fold_indices", "cv_val_deviance", "cv_select",
    "EnginePath", "CompactStats", "BatchedPathResult", "CvPathResult",
    "fit_path", "PathResult", "PathStep",
]
