"""The strong screening rule for SLOPE (paper §2.2).

Three layers:

* :func:`algorithm_1_oracle` / :func:`algorithm_2_oracle` — verbatim Python
  transcriptions of the paper's Algorithm 1 and Algorithm 2.  Used as test
  oracles and for documentation; not jit-compiled.
* :func:`screen_k` — the closed-form parallel equivalent (DESIGN.md §1):
  Algorithm 2's result equals the *rightmost argmax of cumsum(c − λ)* when
  that maximum is ≥ 0, else 0.  One prefix sum + one reduction; this is the
  form that shards and the form the Pallas kernel implements.
* :func:`strong_rule` — the paper's strong rule for SLOPE: surrogate
  c = |∇f(β̂(λ^(m)))|↓ + (λ^(m) − λ^(m+1)), screened with λ^(m+1)
  (Proposition 2's unit-slope bound), returning the screened index set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "algorithm_1_oracle",
    "algorithm_2_oracle",
    "screen_k",
    "screen_masked",
    "support_superset_k",
    "strong_rule",
]

# Sentinel magnitude for masked-out coefficients.  Any entry this negative
# makes cumsum(c − λ) strictly decreasing over the tail, so the rightmost
# argmax (and hence k) can never land past the valid prefix — masking is
# exactly equivalent to truncating the problem to the unmasked entries.
MASKED_NEG = -1e12


# ---------------------------------------------------------------------------
# Verbatim oracles (host-side, for tests and reference)
# ---------------------------------------------------------------------------

def algorithm_1_oracle(c, lam):
    """Paper Algorithm 1.  ``c`` must be |gradient| sorted decreasing.

    Returns the set S of *sorted positions* (0-based) kept by the rule.
    """
    import numpy as np

    c = np.asarray(c, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    S: list[int] = []
    B: list[int] = []
    for i in range(len(c)):
        B.append(i)
        if sum(c[j] - lam[j] for j in B) >= 0:
            S.extend(B)
            B = []
    return set(S)


def algorithm_2_oracle(c, lam):
    """Paper Algorithm 2 (fast version).  Returns k = #active predicted."""
    import numpy as np

    c = np.asarray(c, dtype=np.float64)
    lam = np.asarray(lam, dtype=np.float64)
    p = len(c)
    i, k, s = 1, 0, 0.0
    while i + k <= p:
        s += c[i + k - 1] - lam[i + k - 1]  # 1-based in the paper
        if s >= 0:
            k += i
            i = 1
            s = 0.0
        else:
            i += 1
    return k


# ---------------------------------------------------------------------------
# Parallel closed form (jit-safe, shardable)
# ---------------------------------------------------------------------------

@jax.jit
def screen_k(c_sorted: jax.Array, lam: jax.Array) -> jax.Array:
    """k = rightmost argmax of cumsum(c − λ) if the max is ≥ 0 else 0.

    Equivalent to Algorithm 2 (proof sketch in DESIGN.md §1; property-tested
    against :func:`algorithm_2_oracle`).  ``c_sorted`` must be decreasing.
    """
    s = jnp.cumsum(c_sorted.astype(jnp.promote_types(c_sorted.dtype, jnp.float32))
                   - lam.astype(jnp.promote_types(c_sorted.dtype, jnp.float32)))
    p = s.shape[0]
    rev_arg = jnp.argmax(s[::-1])          # first max in reversed = last max
    k = (p - rev_arg).astype(jnp.int32)
    return jnp.where(jnp.max(s) >= 0, k, jnp.int32(0))


@jax.jit
def screen_masked(mag: jax.Array, lam: jax.Array, mask: jax.Array,
                  rank_shift: jax.Array):
    """:func:`screen_k` restricted to the coefficients where ``mask`` is True,
    with no dynamic shapes — the device-engine form of the screening scan.

    Masked entries are replaced by :data:`MASKED_NEG` so they sort to the
    tail and can never be kept (see the sentinel's invariant above); the
    result equals running Algorithm 2 on the unmasked entries alone.
    ``rank_shift`` is added *after* sorting, i.e. it is aligned with λ's
    rank space, not with coordinates — this is how both the strong rule's
    (λ^(m) − λ^(m+1)) surrogate shift and the KKT check's −tol relaxation
    enter the scan.

    Returns ``(keep_mask, k)``: ``keep_mask`` is a coordinate-space boolean
    mask of the kept set (⊆ mask), ``k`` its cardinality.
    """
    mask = mask.astype(bool)
    cm = jnp.where(mask, mag, jnp.asarray(MASKED_NEG, mag.dtype))
    order = jnp.argsort(-cm)
    c = cm[order] + rank_shift.astype(cm.dtype)
    k = screen_k(c, lam)
    n = order.shape[0]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    keep = (rank < k) & mask
    return keep, k


@functools.partial(jax.jit, static_argnames=("tol",))
def support_superset_k(grad: jax.Array, lam: jax.Array, *, tol: float = 0.0):
    """Proposition 1: Algorithm 1/2 with the *true* gradient certifies a
    support superset.  Returns (k, order) — the superset is order[:k].

    At an exact solution the active prefix satisfies cumsum(c − λ) = 0, so
    a finite-precision gradient sits O(solver tol) *below* the boundary:
    the certificate must relax **upward** (c + tol) to stay a superset.
    tol=0 is the paper's exact statement.
    """
    mag = jnp.abs(jnp.ravel(grad))
    order = jnp.argsort(-mag)
    c = mag[order]
    k = screen_k(c + tol, lam)
    return k, order


@jax.jit
def strong_rule(grad_prev: jax.Array, lam_prev: jax.Array, lam_next: jax.Array):
    """The strong rule for SLOPE (paper §2.2.2).

    ``grad_prev`` = ∇f(β̂(λ^(m))) at the previous path solution.  Surrogate
    c = |grad|↓ + (λ^(m) − λ^(m+1)) per the unit-slope bound, screened
    against λ^(m+1).  Returns (k, order): screened set = order[:k].
    """
    mag = jnp.abs(jnp.ravel(grad_prev))
    order = jnp.argsort(-mag)
    gap = (lam_prev - lam_next).astype(mag.dtype)
    c = mag[order] + gap
    k = screen_k(c, lam_next)
    return k, order
