"""`AsyncPathService` — the asynchronous, continuously-batched front end.

The synchronous :class:`~repro.serve.service.PathService` enforces flush
deadlines *on the next service call*: an idle queue can hold a request past
its deadline forever (ROADMAP open item 2).  This subclass closes that gap
with a worker thread and changes the submit contract:

* ``submit`` returns a :class:`concurrent.futures.Future` instead of a
  request id (``future.rid`` carries the id; ``poll`` is disabled).
* A dispatcher thread sleeps until the earliest flush deadline
  (:meth:`~repro.serve.batcher.MicroBatcher.next_deadline`) and flushes on
  time even when no further calls arrive — deadline enforcement is
  timer-driven, not call-driven.
* Admission is bounded: past ``max_queue`` queued requests, ``submit``
  resolves the future immediately with a :class:`Rejection` status (the
  caller sees backpressure in microseconds, not a deadline miss later).
* Masked-engine groups run with **continuous batching**: the grid advances
  in ``step_chunk``-step compiled chunks
  (:func:`repro.core.engine.chunk_path_engine`) with per-slot carried
  state, so a path that early-stops frees its batch slot at the next chunk
  boundary and the next queued same-bucket request joins the *running*
  cohort — seeded mid-flight by :func:`repro.core.engine.path_init_engine`
  with bitwise the state a from-scratch run starts from.  Compact groups
  keep the whole-grid program (compact carried state is not
  slot-swappable).

Bit-identity is preserved end to end: the chunked step body is the SAME
traced body the monolithic engines scan, dead chunk steps hold the carry
exactly, and batch slots are member-invariant — an async-served result
equals the synchronous served result (and the direct padded call) at
tolerance 0.  ``tests/test_serve_async.py`` pins this.

Failure isolation (PR 7): a worker exception fails only the **implicated
cohort** — the requests the failing serve had actually taken — never the
whole outstanding future set.  The cohort is retried with exponential
backoff + jitter (``retry_limit`` attempts); a cohort that keeps failing
is **bisected** until the poison request is isolated — only it gets the
exception, and the innocent members re-dispatch through the normal
execution path, so their results are bit-identical to an unfaulted run
(same program, same padded operands).  Requests the engine quarantines
in-graph (non-finite inputs under ``validate="quarantine"``) resolve
normally with ``PathResponse.quarantined`` set — sick data is a *flagged
result*, not an exception, and never stalls the cohort.

Crash safety (PR 10): :meth:`AsyncPathService.checkpoint` pauses the
dispatcher at a chunk boundary and snapshots every admitted-but-undelivered
request — untaken queue entries plus each live slot's carried engine state
(the same ``(beta, grad, active, L, health)`` host carry the chunk rounds
already round-trip) — into a picklable :class:`ServiceCheckpoint`;
:meth:`AsyncPathService.restore` on a fresh process re-admits the queued
requests and resumes the in-flight ones from their carry, completing them
**bit-identical** to an uninterrupted run.  A ``solve_timeout_ms`` budget
(service-wide or per request) runs each chunk round under a watchdog, so a
hung device dispatch fails only its cohort through the retry/bisect path;
repeated compile/execute failures open a per-program circuit breaker and
latency pressure against request deadlines sheds the lowest-priority
admissions (both reject with a structured :class:`Rejection`).  Pair with
``store=DurableProgramStore(...)`` and a restarted server also skips every
recompile its predecessor already paid for.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import Future

import numpy as np

from ..core.engine import cv_fold_indices, cv_select, cv_val_deviance, \
    null_sigma_grid
from ..core.losses import Family, ols
from ..core.path import _stop_triggered
from ..core.solver import DEFAULT_WS_TIERS
from .batcher import Pending, QueueFull, Rejection
from .buckets import pad_batch
from .cache import ProgramSpec
from .durable import (
    InflightSlot,
    ServiceCheckpoint,
    WatchdogTimeout,
    run_with_watchdog,
    snapshot_queued,
)
from .service import (
    CvResponse,
    PathResponse,
    PathService,
    ResampleResponse,
    _GroupKey,
)

__all__ = ["AsyncPathService", "Rejection", "ServiceCheckpoint"]


@dataclasses.dataclass
class _Slot:
    """One occupied batch slot in a continuous run (host-side bookkeeping;
    the device carry lives in the run's persistent buffers)."""

    pending: Pending
    grid: np.ndarray       # native σ grid in the program dtype, length L
    n: int                 # native rows
    p: int                 # native cols
    inserted: float        # service clock at slot insertion
    batch_size: int        # occupied slots when this one joined
    cache_hit: bool
    early_stop: bool = True  # False for CV fold fits: the aggregation
    #   needs every fold on the full shared grid (sync parity)
    null_dev: float = 0.0
    prev_dev: float = 0.0  # early-stop carry across chunk boundaries
    cursor: int = 1        # next σ index to produce; done at cursor == L
    take: int = 0          # live steps requested from the current chunk
    solve_s: float = 0.0   # accumulated chunk walls while this slot ran
    finished: bool = False
    health0: int = 0       # init-time health word (nonzero: quarantined
    #   on admission — the slot delivers its flagged null head and frees)
    steps: list = dataclasses.field(default_factory=list)
    # each entry: (beta (p, m), n_active, n_screened, n_violations,
    #              refits, solver_iters, deviance, kkt_unrepaired, health)


class AsyncPathService(PathService):
    """Worker-thread path service: futures, SLOs, continuous batching.

    ``step_chunk`` is the continuous-batching granularity: slots can be
    recycled every ``step_chunk`` σ-steps (smaller = faster recycling, more
    host round-trips).  ``max_queue`` bounds queued depth for admission
    control.  ``autostart=False`` leaves the dispatcher stopped (useful for
    testing admission without execution); :meth:`start` launches it.
    """

    def __init__(self, *, max_batch: int = 8, max_delay: float = 0.02,
                 step_chunk: int = 8, max_queue: int | None = 64,
                 retry_limit: int = 2, retry_backoff: float = 0.02,
                 retry_jitter: float = 0.25,
                 autostart: bool = True, policy=None, cache=None,
                 canonicalizer=None, clock=time.perf_counter, faults=None,
                 tracing: bool = False, store=None,
                 solve_timeout_ms: float | None = None,
                 breaker_threshold: int = 5, breaker_cooldown: float = 5.0,
                 shed_threshold: float = 0.9, shed_priority: int = 0,
                 shed_window: int = 8):
        super().__init__(max_batch=max_batch, max_delay=max_delay,
                         max_queue=max_queue, policy=policy, cache=cache,
                         canonicalizer=canonicalizer, clock=clock,
                         faults=faults, tracing=tracing, store=store,
                         solve_timeout_ms=solve_timeout_ms,
                         breaker_threshold=breaker_threshold,
                         breaker_cooldown=breaker_cooldown,
                         shed_threshold=shed_threshold,
                         shed_priority=shed_priority,
                         shed_window=shed_window)
        if step_chunk < 1:
            raise ValueError(f"step_chunk must be ≥ 1, got {step_chunk}")
        if retry_limit < 0:
            raise ValueError(f"retry_limit must be ≥ 0, got {retry_limit}")
        if retry_backoff < 0 or retry_jitter < 0:
            raise ValueError("retry_backoff and retry_jitter must be ≥ 0")
        self.step_chunk = step_chunk
        # transient-failure policy: attempt k sleeps
        # retry_backoff · 2^(k-1) · (1 + retry_jitter·U[0,1)) seconds
        self.retry_limit = retry_limit
        self.retry_backoff = retry_backoff
        self.retry_jitter = retry_jitter
        self._jitter_rng = random.Random(0)  # deterministic under test
        self._futures: dict[int, Future] = {}
        # slot_recycles / chunk_batches / retries / bisections / poisoned
        # live on the inherited MetricsRegistry (self.metrics) — stats()
        # reads them back through the same registry the sync service uses
        self._current_cohort: list[Pending] = []
        self._last_error: BaseException | None = None
        self._cond = threading.Condition()
        self._stop_flag = False
        self._worker: threading.Thread | None = None
        # crash-safety state (PR 10): the continuous runner keeps, per
        # in-flight rid, a copy of the slot's carried engine state at its
        # last chunk boundary (checkpoint() collects these), and restore()
        # parks resumed carries here until the runner inserts them
        self._inflight_state: dict[int, InflightSlot] = {}
        self._resume_state: dict[int, InflightSlot] = {}
        self._ckpt_request = False
        if autostart:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Launch the dispatcher thread (idempotent)."""
        with self._cond:
            if self._worker is not None and self._worker.is_alive():
                return
            self._stop_flag = False
            self._worker = threading.Thread(
                target=self._run, name="repro-serve-dispatch", daemon=True)
            self._worker.start()

    def close(self, *, flush: bool = True, timeout: float = 10.0) -> None:
        """Stop the dispatcher; ``flush=True`` then serves anything still
        queued synchronously so no admitted future is left unresolved.

        A fault raised during the close-time drain must not leave futures
        permanently pending: whatever the flush could not deliver is failed
        explicitly before returning — every admitted future resolves.
        """
        with self._cond:
            self._stop_flag = True
            self._cond.notify_all()
        w = self._worker
        if w is not None:
            w.join(timeout=timeout)
        drain_error: BaseException | None = None
        if flush:
            try:
                self.flush()
            except BaseException as e:
                self._last_error = drain_error = e
        with self._lock:
            leftovers = list(self._futures.items())
            self._futures.clear()
            self._traces.clear()
            self._cv_fold_rids.clear()
            self._rs_member_rids.clear()
            self._solve_timeouts.clear()
            self._resume_state.clear()
            self._inflight_state.clear()
        for rid, fut in leftovers:
            if not fut.done():
                fut.set_exception(RuntimeError(
                    f"service closed with request {rid} undelivered")
                    if drain_error is None else drain_error)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every admitted request has been delivered (or
        ``timeout`` seconds passed; returns False on timeout).

        Waits on the dispatcher's condition variable — every delivery
        notifies it — instead of polling on a sleep loop.  The idle
        predicate is read without ``self._lock`` (deliverers hold it while
        notifying, so taking it here would be an ABBA ordering); a stale
        read only costs one extra wait-and-recheck, never a wrong answer.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._futures or self._batcher.pending():
                if deadline is None:
                    self._cond.wait()
                else:
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cond.wait(timeout=left):
                        return not (self._futures
                                    or self._batcher.pending())
            return True

    # -- checkpoint / restore -----------------------------------------------

    def checkpoint(self, *, timeout: float = 60.0) -> ServiceCheckpoint:
        """Pause serving at the next chunk boundary and snapshot every
        admitted-but-undelivered request.

        The dispatcher is signalled, joined, and the snapshot assembled
        from the batcher queue (untaken requests, non-destructively) plus
        the continuous runner's shadowed per-slot carry (in-flight
        requests at their last chunk boundary).  The service is left
        STOPPED — a checkpoint is the prelude to a process exit; call
        :meth:`start` to keep serving in place, or :meth:`restore` the
        snapshot on a fresh service, where every captured request
        completes bit-identical to an uninterrupted run.
        """
        with self._cond:
            self._ckpt_request = True
            self._stop_flag = True
            self._cond.notify_all()
        w = self._worker
        if w is not None:
            w.join(timeout=timeout)
            if w.is_alive():
                self._ckpt_request = False
                raise RuntimeError(
                    f"dispatcher did not reach a chunk boundary within "
                    f"{timeout} s; checkpoint aborted")
        self._ckpt_request = False
        with self._lock:
            queued = snapshot_queued(self._batcher, self._cv_fold_rids,
                                     self._rs_member_rids)
            queued_rids = {q.rid for q in queued}
            inflight = [st for rid, st in self._inflight_state.items()
                        if rid in self._futures and rid not in queued_rids]
            self.metrics.inc("checkpoints")
        return ServiceCheckpoint(queued=queued, inflight=inflight)

    def restore(self, ckpt: ServiceCheckpoint) -> dict:
        """Re-admit every request a :class:`ServiceCheckpoint` captured;
        returns ``{old_rid: Future}`` keyed by the checkpointed process's
        request ids.

        Queued requests re-enter normal admission.  In-flight requests
        re-enter WITH their carried engine state, which the continuous
        runner scatters into a batch slot in place of init seeding — the
        resumed path picks up at the exact chunk boundary the checkpoint
        cut (per-slot σ windows are cursor-driven, so chunk alignment is
        preserved) and its result is bit-identical to an uninterrupted
        run.  Refuses a checkpoint taken under a different jax/jaxlib/
        backend fingerprint: bit-identity cannot be promised across
        version or backend changes.
        """
        from .durable import backend_fingerprint

        here = backend_fingerprint()
        if ckpt.fingerprint != here:
            raise RuntimeError(
                f"checkpoint fingerprint {ckpt.fingerprint!r} does not "
                f"match this process ({here!r}); resumed execution would "
                f"not be bit-identical")
        futures: dict = {}
        for q in ckpt.queued:
            futures[q.rid] = self._admit(
                q.key, q.item, priority=q.priority,
                _cv_fold=q.cv_fold, _rs_member=q.rs_member)
            self.metrics.inc("restored")
        for st in ckpt.inflight:
            futures[st.rid] = self._admit(
                st.key, st.item, priority=st.priority,
                _cv_fold=st.cv_fold, _resume=st)
            self.metrics.inc("restored")
        return futures

    # -- admission (future-returning) ---------------------------------------

    def _admit(self, key: _GroupKey, item, *, deadline_ms=None, priority=0,
               solve_timeout_ms: float | None = None,
               _cv_fold: bool = False, _rs_member: bool = False,
               _resume: InflightSlot | None = None) -> Future:
        fut: Future = Future()
        t_in = self._clock()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.metrics.inc("submitted")
            fut.rid = rid
            verdict = self._admission_control(
                key, rid, priority=priority, deadline_ms=deadline_ms)
            if verdict is not None:
                # async contract: rejection is a resolved future, not an
                # exception — callers see backpressure without waiting
                fut.set_result(verdict)
                return fut
            if _cv_fold:
                self._cv_fold_rids.add(rid)
            if _rs_member:
                self._rs_member_rids.add(rid)
            if solve_timeout_ms is not None:
                self._solve_timeouts[rid] = solve_timeout_ms / 1e3
            if _resume is not None:
                # restore(): the continuous runner scatters this carried
                # state into the slot instead of init-seeding it
                self._resume_state[rid] = _resume
            item = self._maybe_corrupt(rid, item)
            now = self._clock()
            try:
                self._batcher.admit(
                    key, rid, item, now, priority=priority,
                    deadline=self._flush_by(now, deadline_ms))
            except QueueFull as e:
                self.metrics.inc("rejected")
                self._cv_fold_rids.discard(rid)
                self._rs_member_rids.discard(rid)
                self._solve_timeouts.pop(rid, None)
                self._resume_state.pop(rid, None)
                fut.set_result(Rejection(
                    rid=rid, reason=str(e), queued=self._batcher.pending(),
                    max_queue=self._batcher.max_queue))
                return fut
            self._start_trace(rid, t_in)
            self._futures[rid] = fut
        with self._cond:
            self._cond.notify_all()  # wake the dispatcher: new work/deadline
        return fut

    def _deliver(self, rid: int, resp: PathResponse) -> None:
        """Resolve the request's future (caller holds ``self._lock``)."""
        self.metrics.inc("completed")
        self.metrics.inc("kkt_violations", int(resp.n_violations.sum()))
        self._record_latency(rid, resp)   # before dropping fold membership
        self._finish_trace(rid, resp)
        self._cv_fold_rids.discard(rid)
        self._rs_member_rids.discard(rid)
        self._solve_timeouts.pop(rid, None)
        self._inflight_state.pop(rid, None)
        fut = self._futures.pop(rid, None)
        if fut is not None and not fut.done():
            fut.set_result(resp)
        with self._cond:
            self._cond.notify_all()  # drain() waits on delivery

    def poll(self, rid, *, flush: bool = False):
        raise TypeError("AsyncPathService resolves results through the "
                        "futures submit() returns; there is nothing to poll")

    # -- CV: fold futures aggregate through a done-callback -----------------

    def _submit_cv(self, X, y, lam, family, *, n_folds, stratify, selection,
                   sigmas, path_length, sigma_ratio, screening, solver_tol,
                   max_iter, kkt_tol, max_refits, working_set,
                   ws_tiers=DEFAULT_WS_TIERS, deadline_ms=None,
                   priority=0, solve_timeout_ms=None,
                   validate="strict") -> Future:
        if sigmas is None:
            sigmas = null_sigma_grid(X, y, lam, family,
                                     path_length=path_length,
                                     sigma_ratio=sigma_ratio)
        sigmas = np.asarray(sigmas)
        trains, vals = cv_fold_indices(y, n_folds, family=family,
                                       stratify=stratify)
        fold_futs = [
            self.submit(X[tr], y[tr], family=family, lam=lam, sigmas=sigmas,
                        screening=screening, solver_tol=solver_tol,
                        max_iter=max_iter, kkt_tol=kkt_tol,
                        max_refits=max_refits, working_set=working_set,
                        ws_tiers=ws_tiers, deadline_ms=deadline_ms,
                        priority=priority,
                        solve_timeout_ms=solve_timeout_ms,
                        validate=validate, _cv_fold=True)
            for tr in trains
        ]
        cv_fut: Future = Future()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.metrics.inc("submitted")
        cv_fut.rid = rid
        remaining = [len(fold_futs)]
        agg_lock = threading.Lock()

        def on_fold_done(_):
            with agg_lock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            try:
                folds = [f.result() for f in fold_futs]
                rej = next((r for r in folds if isinstance(r, Rejection)),
                           None)
                if rej is not None:
                    cv_fut.set_result(Rejection(
                        rid=rid,
                        reason=f"CV fold rejected: {rej.reason}",
                        queued=rej.queued, max_queue=rej.max_queue))
                    return
                betas = np.stack([f.betas for f in folds])
                val_dev = cv_val_deviance(X, y, vals, betas, family)
                mean, se, best_min, best_1se = cv_select(val_dev)
                best = best_1se if selection == "1se" else best_min
                self.metrics.inc("completed")
                cv_fut.set_result(CvResponse(
                    rid=rid, sigmas=sigmas, lam=lam, val_deviance=val_dev,
                    mean_val_deviance=mean, se_val_deviance=se,
                    best_index=best, best_sigma=float(sigmas[best]),
                    best_index_min=best_min, best_index_1se=best_1se,
                    selection=selection, fold_responses=folds))
            except BaseException as e:  # pragma: no cover - defensive
                if not cv_fut.done():
                    cv_fut.set_exception(e)

        for f in fold_futs:
            f.add_done_callback(on_fold_done)
        return cv_fut

    # -- resample: member futures aggregate the same way --------------------

    def _register_resample(self, rid, member_futs, W, rs, sigmas,
                           lam) -> Future:
        from ..resample.metrics import track_in_flight

        parent: Future = Future()
        parent.rid = rid
        remaining = [len(member_futs)]
        agg_lock = threading.Lock()

        def on_member_done(_):
            with agg_lock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            try:
                members = [f.result() for f in member_futs]
                track_in_flight(rs.kind, -len(members))
                rej = next((r for r in members if isinstance(r, Rejection)),
                           None)
                if rej is not None:
                    parent.set_result(Rejection(
                        rid=rid,
                        reason=f"replicate member rejected: {rej.reason}",
                        queued=rej.queued, max_queue=rej.max_queue))
                    return
                self.metrics.inc("completed")
                parent.set_result(ResampleResponse(
                    rid=rid, betas=np.stack([f.betas for f in members]),
                    sigmas=sigmas, lam=lam, weights=W, resample=rs,
                    member_responses=members))
            except BaseException as e:  # pragma: no cover - defensive
                if not parent.done():
                    parent.set_exception(e)

        for f in member_futs:
            f.add_done_callback(on_member_done)
        return parent

    # -- the dispatcher -----------------------------------------------------

    def _next_group(self):
        fill = self._batcher.fillable()
        if fill:
            return fill[0], "fill"
        due = self._batcher.due(self._clock())
        if due:
            return due[0], "deadline"
        return None, None

    def _run(self) -> None:
        while True:
            key = trigger = None
            with self._cond:
                while not self._stop_flag:
                    key, trigger = self._next_group()
                    if key is not None:
                        break
                    nd = self._batcher.next_deadline()
                    if nd is None:
                        self._cond.wait()
                    else:
                        # +0.1 ms so the post-sleep clock is past the
                        # deadline and due() actually returns the group
                        self._cond.wait(
                            timeout=max(0.0, nd - self._clock()) + 1e-4)
                if self._stop_flag:
                    return
            self._serve_safely(key, trigger)

    # -- failure isolation: cohort-scoped retry, backoff, bisection ---------

    def _note_taken(self, batch) -> None:
        """Record what the in-flight serve has actually taken — the blast
        radius of a worker exception is exactly this cohort."""
        self._current_cohort.extend(batch)

    def _serve_safely(self, key: _GroupKey, trigger: str) -> None:
        """One dispatcher serve with scoped failure handling.

        On an exception only the implicated cohort (requests this serve
        took) enters recovery; every other outstanding future is untouched.
        A failure *before* anything was taken (e.g. an injected compile
        fault) implicates the queued group, which is popped and recovered
        through the same path so a persistent failure cannot spin the
        dispatcher hot on an undrainable queue.
        """
        self._current_cohort = []
        try:
            self._serve_group(key, trigger)
        except BaseException as e:  # keep serving; recover the cohort
            self._last_error = e
            with self._lock:
                cohort = [p for p in self._current_cohort
                          if p.rid in self._futures]
            if not cohort:
                cohort = self._batcher.take(key)
            self._recover(key, cohort, e)
        finally:
            self._current_cohort = []

    def _sleep_backoff(self, attempt: int) -> None:
        delay = self.retry_backoff * (2 ** (attempt - 1))
        delay *= 1.0 + self.retry_jitter * self._jitter_rng.random()
        if delay > 0:
            time.sleep(delay)

    def _trace_recovery(self, cohort: list[Pending], name: str,
                        **attrs) -> None:
        """Attach a zero-width recovery child span (retry/bisect) to every
        traced cohort member — poison isolation stays visible per request."""
        if not self._traces:
            return
        now = self._clock()
        with self._lock:
            for p in cohort:
                tr = self._traces.get(p.rid)
                if tr is not None:
                    tr.child(name, t0=now, t1=now, **attrs)

    def _recover(self, key: _GroupKey, cohort: list[Pending],
                 exc: BaseException, *, retries: int | None = None) -> None:
        """Retry a failed cohort, then bisect it down to the poison.

        ``retries`` whole-cohort re-serves (exponential backoff + jitter)
        absorb transient faults; a cohort that still fails is split in two
        and each half re-served with zero retries — O(log B) extra serves
        isolate a single poison request, which alone gets the exception.
        Innocent members re-dispatch through the normal execution path, so
        their results are bit-identical to an unfaulted run.  Total work is
        bounded: retries + at most 2·B − 1 bisection serves.
        """
        retries = self.retry_limit if retries is None else retries
        for attempt in range(1, retries + 1):
            with self._lock:
                cohort = [p for p in cohort if p.rid in self._futures]
            if not cohort:
                return
            self._sleep_backoff(attempt)
            self.metrics.inc("retries")
            self._trace_recovery(cohort, "retry", attempt=attempt,
                                 cohort_size=len(cohort))
            try:
                self._serve_cohort(key, cohort)
                return
            except BaseException as e:
                self._last_error = exc = e
        with self._lock:
            cohort = [p for p in cohort if p.rid in self._futures]
        if not cohort:
            return
        if len(cohort) == 1:
            pending = cohort[0]
            with self._lock:
                self.metrics.inc("poisoned")
                self._cv_fold_rids.discard(pending.rid)
                self._solve_timeouts.pop(pending.rid, None)
                self._inflight_state.pop(pending.rid, None)
                fut = self._futures.pop(pending.rid, None)
                tr = self._traces.pop(pending.rid, None)
            if tr is not None:
                # the failed request's timeline rides on the exception so
                # callers can see the retry/bisect history that isolated it
                tr.mark("poisoned", self._clock())
                try:
                    exc.trace = tr
                except Exception:  # exceptions with __slots__
                    pass
            if fut is not None and not fut.done():
                fut.set_exception(exc)
            with self._cond:
                self._cond.notify_all()  # drain() waits on resolution
            return
        self.metrics.inc("bisections")
        self._trace_recovery(cohort, "bisect", cohort_size=len(cohort))
        mid = len(cohort) // 2
        for half in (cohort[:mid], cohort[mid:]):
            try:
                self._serve_cohort(key, half)
            except BaseException as e:
                self._last_error = e
                self._recover(key, half, e, retries=0)

    def _serve_cohort(self, key: _GroupKey, cohort: list[Pending]) -> None:
        """Re-dispatch exactly ``cohort`` (no new queue pulls) through the
        normal execution path — same programs, same padded operands, so a
        successful re-serve is bit-identical to an unfaulted serve."""
        if key.working_set is not None or key.replicates:
            self._execute_batch(key, list(cohort), trigger="retry")
        else:
            self._run_continuous(key, "retry", cohort=list(cohort))

    def _serve_group(self, key: _GroupKey, trigger: str) -> None:
        if key.working_set is not None or key.replicates:
            # compact carried state is not slot-swappable, and replicate
            # chunks already batch continuously over the member axis:
            # whole-grid program, same as the synchronous service (delivery
            # still resolves futures through the _deliver override)
            self._flush_group(key, trigger=trigger)
        else:
            self._run_continuous(key, trigger)

    # -- continuous batching (masked groups) --------------------------------

    def _chunk_specs(self, key: _GroupKey):
        base = dict(
            family=key.family, batch=self.slots, n_rows=key.n_rows,
            n_cols=key.n_cols, path_length=key.path_length,
            screening=key.screening, solver_tol=key.solver_tol,
            max_iter=key.max_iter, kkt_tol=key.kkt_tol,
            max_refits=key.max_refits, dtype=key.dtype, y_dtype=key.y_dtype)
        return (ProgramSpec(**base, variant="init"),
                ProgramSpec(**base, variant="chunk",
                            step_chunk=self.step_chunk))

    def _run_continuous(self, key: _GroupKey, trigger: str,
                        cohort: list[Pending] | None = None) -> None:
        """Breaker-instrumented wrapper around the continuous runner.

        Any failure — injected, device, watchdog timeout — counts one
        consecutive-failure strike against ``key``'s circuit before the
        PR-7 recovery machinery sees it; a clean drain (including the
        innocent halves of a bisection, which re-enter here) resets the
        count, so only a persistent fault opens the circuit.
        """
        try:
            self._run_continuous_impl(key, trigger, cohort=cohort)
        except BaseException:
            if self._breaker.record_failure(key) == "open":
                self._trace_recovery(list(self._current_cohort),
                                     "breaker_open",
                                     threshold=self._breaker.threshold)
            raise
        else:
            self._breaker.record_success(key)

    def _run_continuous_impl(self, key: _GroupKey, trigger: str,
                             cohort: list[Pending] | None = None) -> None:
        """Serve one masked group until it drains, recycling slots.

        Persistent padded operand buffers plus the scan carry round-trip
        through the host between ``step_chunk``-step compiled chunks.  At
        every chunk boundary, finished slots (grid done or early-stopped)
        deliver and free; queued same-group requests take the free slots
        and are seeded by the init program — run on the whole updated batch,
        scattered only into the inserted slots, so standing neighbours'
        state is untouched (bitwise).

        ``cohort`` (retry/bisection re-dispatch) serves exactly those
        pendings and never pulls from the queue — failure recovery must
        not widen its own blast radius.
        """
        family = key.family
        m = family.n_classes
        S, N, P, L = self.slots, key.n_rows, key.n_cols, key.path_length
        C = self.step_chunk
        f = np.dtype(key.dtype)
        init_spec, chunk_spec = self._chunk_specs(key)
        self._faults.fire("compile", rids=(
            () if cohort is None else [p.rid for p in cohort]))
        init_prog, init_hit = self.cache.get(init_spec)
        chunk_prog, chunk_hit = self.cache.get(chunk_spec)
        first_hit = init_hit and chunk_hit

        Xs = np.zeros((S, N, P), f)
        ys = np.zeros((S, N), np.dtype(key.y_dtype))
        lam = np.zeros((S, P * m), f)
        p_valid = np.zeros((S,), np.int32)
        sig_prev = np.ones((S, C), f)
        sig_next = np.ones((S, C), f)
        live = np.zeros((S, C), bool)
        beta = np.zeros((S, P, m), f)
        grad = np.zeros((S, P, m), f)
        active = np.zeros((S, P), bool)
        Lc = np.ones((S,), f)
        Hc = np.zeros((S,), np.int32)
        slots: list[_Slot | None] = [None] * S
        # stable buffer handles for _finish_slot's lane blanking; the chunk
        # outputs below are copied INTO these arrays (np.copyto), never
        # rebound, so this dict cannot go stale
        bufs = dict(Xs=Xs, ys=ys, lam=lam, p_valid=p_valid, beta=beta,
                    grad=grad, active=active, Lc=Lc, Hc=Hc)

        plan_summary = chunk_spec.plan().summary()
        self.metrics.inc("flush", trigger=trigger)
        self.metrics.inc("plans", plan=plan_summary)

        rounds = 0
        while True:
            if self._ckpt_request and cohort is None:
                # checkpoint(): pause at this chunk boundary — untaken work
                # stays queued, live slots' carry is already shadowed in
                # self._inflight_state by the end of the previous round.
                # Recovery cohorts run to completion: their pendings left
                # the queue long ago and re-admission owns no record of
                # them, so pausing mid-recovery would strand futures.
                return
            # refill free slots from the queue (the slot-recycle seam), or —
            # in cohort mode — from the re-dispatched pendings only
            free = [i for i in range(S) if slots[i] is None]
            if cohort is not None:
                taken = [cohort.pop(0)
                         for _ in range(min(len(free), len(cohort)))]
            else:
                taken = (self._batcher.take(key, limit=len(free))
                         if free else [])
                if taken:
                    self._note_taken(taken)
            occupied = S - len(free) + len(taken)
            inserted = []
            resumed = []
            now = self._clock()
            if self._traces and taken:
                with self._lock:
                    for pend in taken:
                        tr = self._traces.get(pend.rid)
                        if tr is not None:
                            tr.mark("queue", now, trigger=trigger)
            for i, pending in zip(free, taken):
                item = pending.item
                pb = pad_batch(
                    [(item.X, item.y, item.lam, item.sigmas)],
                    n_rows=N, n_cols=P, n_slots=1, n_classes=m)
                Xs[i] = pb.Xs[0]
                ys[i] = pb.ys[0]
                lam[i] = pb.lam[0]
                p_valid[i] = pb.p_valid[0]
                with self._lock:
                    es = pending.rid not in self._cv_fold_rids
                    rs = self._resume_state.pop(pending.rid, None)
                slots[i] = _Slot(
                    pending=pending, grid=np.asarray(item.sigmas, f),
                    n=item.X.shape[0], p=item.X.shape[1], inserted=now,
                    batch_size=occupied, early_stop=es,
                    cache_hit=first_hit if rounds == 0 else True)
                if rs is None:
                    inserted.append(i)
                    continue
                # restore(): scatter the checkpointed carry into the lane
                # instead of init-seeding it — the slot continues from the
                # exact chunk boundary the checkpoint cut, so per-slot σ
                # windows (cursor-driven, not round-driven) and every later
                # step are bit-identical to an uninterrupted run
                s = slots[i]
                beta[i] = rs.beta
                grad[i] = rs.grad
                active[i] = rs.active
                Lc[i] = rs.L
                Hc[i] = rs.H
                s.cursor = rs.cursor
                s.steps = list(rs.steps)
                s.null_dev = rs.null_dev
                s.prev_dev = rs.prev_dev
                s.health0 = rs.health0
                s.early_stop = rs.early_stop
                s.solve_s = rs.solve_s
                resumed.append(i)
                if self._traces:
                    with self._lock:
                        tr = self._traces.get(pending.rid)
                    if tr is not None:
                        tr.mark("restore", self._clock(), slot=i,
                                cursor=rs.cursor)
            for i in resumed:
                # a carry checkpointed at the finish line (sick at init, or
                # cursor already past the grid) delivers immediately
                if slots[i].health0 or slots[i].cursor >= L:
                    self._finish_slot(i, slots, key, bufs)
            if inserted:
                if rounds > 0:
                    # joined a cohort already in flight: true recycling
                    self.metrics.inc("slot_recycles", len(inserted))
                # prefill on the WHOLE updated batch, scatter only the new
                # slots — standing neighbours keep their carried state
                g0, nd0, L0, h0 = (np.asarray(a)
                                   for a in init_prog(Xs, ys))
                for i in inserted:
                    beta[i] = 0.0
                    grad[i] = g0[i]
                    active[i] = False
                    Lc[i] = L0[i]
                    Hc[i] = h0[i]
                    slots[i].health0 = int(h0[i])
                    slots[i].null_dev = slots[i].prev_dev = float(nd0[i])
                    if self._traces:
                        with self._lock:
                            tr = self._traces.get(slots[i].pending.rid)
                        if tr is not None:
                            tr.mark("init", self._clock(),
                                    recycled=rounds > 0, slot=i)
                    if L < 2:  # degenerate grid: null model only
                        self._finish_slot(i, slots, key, bufs)
                    elif slots[i].health0:
                        # sick at init (quarantine-mode admission): every
                        # remaining step would be a quarantined no-op —
                        # deliver the flagged null head now, free the slot
                        self._finish_slot(i, slots, key, bufs)
            if all(s is None for s in slots):
                break

            # per-slot chunk inputs from each slot's own grid cursor
            for i in range(S):
                s = slots[i]
                if s is None:
                    sig_prev[i] = 1.0
                    sig_next[i] = 1.0
                    live[i] = False
                    continue
                s.take = min(C, L - s.cursor)
                for c in range(C):
                    if c < s.take:
                        sig_prev[i, c] = s.grid[s.cursor - 1 + c]
                        sig_next[i, c] = s.grid[s.cursor + c]
                        live[i, c] = True
                    else:
                        sig_prev[i, c] = 1.0
                        sig_next[i, c] = 1.0
                        live[i, c] = False

            rids = [s.pending.rid for s in slots if s is not None]

            def _chunk_round():
                # the worker fault site fires INSIDE the watched call, so an
                # injected kind="hang" delay trips the watchdog exactly like
                # a stuck device dispatch would
                self._faults.fire("worker", rids=rids)
                return chunk_prog(
                    Xs, ys, lam, sig_prev, sig_next, live, beta, grad,
                    active, Lc, Hc, p_valid)

            t0 = self._clock()
            try:
                (nb, ng, na, nL, nH), ep = run_with_watchdog(
                    _chunk_round, self._watchdog_budget(rids),
                    label=chunk_spec.short())
            except WatchdogTimeout:
                self.metrics.inc("watchdog_timeouts")
                raise  # cohort-scoped: _serve_safely recovers exactly rids
            # copy INTO the persistent buffers (device outputs view as
            # read-only, and the next insertion scatters into them; copyto
            # keeps the bufs handles above valid)
            np.copyto(beta, nb)
            np.copyto(grad, ng)
            np.copyto(active, na)
            np.copyto(Lc, nL)
            np.copyto(Hc, nH)
            eb = np.asarray(ep.betas)
            edev = np.asarray(ep.deviance)
            scalars = [np.asarray(a) for a in
                       (ep.n_active, ep.n_screened, ep.n_violations,
                        ep.refits, ep.solver_iters)]
            eunrep = np.asarray(ep.kkt_unrepaired)
            ehlth = np.asarray(ep.health)
            wall = self._clock() - t0
            rounds += 1
            n_live = sum(s is not None for s in slots)
            self.metrics.inc("batches")
            self.metrics.inc("chunk_batches")
            self.metrics.observe("batch_occupancy", n_live / S)
            if self._traces:
                t_chunk = self._clock()
                with self._lock:
                    for s in slots:
                        if s is None:
                            continue
                        tr = self._traces.get(s.pending.rid)
                        if tr is not None:
                            tr.mark("chunk", t_chunk, round=rounds,
                                    solve_ms=round(wall * 1e3, 3))

            # harvest: native-width steps, early stop on the growing prefix
            for i in range(S):
                s = slots[i]
                if s is None:
                    continue
                s.solve_s += wall
                for c in range(s.take):
                    b = np.array(eb[i, c, :s.p, :])
                    dev = float(edev[i, c])
                    hw = int(ehlth[i, c])
                    s.steps.append((
                        b, *(int(a[i, c]) for a in scalars), dev,
                        bool(eunrep[i, c]), hw))
                    s.cursor += 1
                    if hw:
                        # quarantined in-graph: the remaining grid would be
                        # no-op placeholder steps (and the NaN-blind stop
                        # predicate below can never fire) — truncate here,
                        # the response carries the sticky health word
                        s.finished = True
                        break
                    # the SAME predicate the sync path applies post-hoc —
                    # it reads only the prefix, so stopping at a chunk
                    # boundary truncates exactly where path_result() would
                    if s.early_stop and _stop_triggered(
                            b, dev, s.prev_dev, s.null_dev, s.n):
                        s.finished = True
                        break
                    s.prev_dev = dev
                if s.finished or s.cursor >= L:
                    self._finish_slot(i, slots, key, bufs)

            # shadow every still-live slot's carry at this chunk boundary —
            # what checkpoint() collects after pausing the runner, and the
            # most a crash can lose per request is the current chunk
            with self._lock:
                for i in range(S):
                    s = slots[i]
                    if s is None:
                        continue
                    self._inflight_state[s.pending.rid] = InflightSlot(
                        rid=s.pending.rid, key=key, item=s.pending.item,
                        priority=s.pending.priority,
                        cv_fold=not s.early_stop,
                        beta=beta[i].copy(), grad=grad[i].copy(),
                        active=active[i].copy(), L=float(Lc[i]),
                        H=int(Hc[i]), cursor=s.cursor,
                        steps=list(s.steps), null_dev=s.null_dev,
                        prev_dev=s.prev_dev, health0=s.health0,
                        early_stop=s.early_stop, solve_s=s.solve_s)

    def _finish_slot(self, i: int, slots: list, key: _GroupKey,
                     bufs: dict) -> None:
        """Assemble the slot's response (null head + harvested steps at
        native shape), deliver its future, and free the slot."""
        s = slots[i]
        m = key.family.n_classes
        f = np.dtype(key.dtype)
        k = 1 + len(s.steps)
        betas = np.zeros((k, s.p, m), f)
        n_act = np.zeros((k,), np.int32)
        n_scr = np.zeros((k,), np.int32)
        viol = np.zeros((k,), np.int32)
        refits = np.zeros((k,), np.int32)
        iters = np.zeros((k,), np.int32)
        dev = np.zeros((k,), f)
        unrep = np.zeros((k,), bool)
        hlth = np.zeros((k,), np.int32)
        dev[0] = s.null_dev
        hlth[0] = s.health0
        for j, st in enumerate(s.steps, start=1):
            (betas[j], n_act[j], n_scr[j], viol[j], refits[j], iters[j],
             dev[j], unrep[j], hlth[j]) = st
        out_betas = betas[:, :, 0] if m == 1 else betas
        item = s.pending.item
        pad_ratio = (key.n_rows * key.n_cols) / (s.n * s.p)
        resp = PathResponse(
            rid=s.pending.rid, betas=out_betas,
            sigmas=np.asarray(item.sigmas)[:k], lam=item.lam, n_samples=s.n,
            n_active=n_act, n_screened=n_scr, n_violations=viol,
            refits=refits, solver_iters=iters, deviance=dev,
            kkt_unrepaired=unrep, kkt_ok=not bool(unrep.any()),
            working_set=None, working_set_top=None, ws_size=None,
            ws_tier=None, compact_fallback=None,
            queue_s=max(0.0, s.inserted - s.pending.submitted),
            solve_s=s.solve_s, batch_size=s.batch_size,
            batch_occupancy=s.batch_size / self.slots,
            padding_ratio=pad_ratio, cache_hit=s.cache_hit, health=hlth)
        self.metrics.observe("padding_ratio", pad_ratio)
        with self._lock:
            if self._traces:
                tr = self._traces.get(s.pending.rid)
                if tr is not None:
                    tr.mark("harvest", self._clock(),
                            padding_ratio=round(pad_ratio, 3))
            self._deliver(s.pending.rid, resp)
        slots[i] = None
        # blank the freed lane EVERYWHERE — operands AND carry: dead lanes
        # still execute in the vmapped chunk program (live=False only gates
        # the results), so a stale non-finite operand or carry (a
        # quarantined member leaves a NaN grad) would spin its lockstep
        # FISTA to max_iter on every remaining chunk.  All-zero lanes
        # converge in one iteration.
        for name in ("Xs", "ys", "lam", "p_valid", "beta", "grad", "Hc"):
            bufs[name][i] = 0
        bufs["active"][i] = False
        bufs["Lc"][i] = 1.0

    # -- warmup & telemetry -------------------------------------------------

    def warmup(self, shapes, *, family: Family = ols, path_length: int = 100,
               screening: str = "strong", solver_tol: float = 1e-8,
               max_iter: int = 5000, kkt_tol: float = 1e-4,
               max_refits: int = 32,
               working_set: int | str | None = None,
               ws_tiers: int | str = DEFAULT_WS_TIERS,
               dtype: str = "float64", y_dtype: str = "float64") -> dict:
        """Pre-compile what async serving actually runs: the (init, chunk)
        program pair for masked shapes; compact shapes defer to the base
        whole-grid warmup."""
        if working_set is not None:
            return super().warmup(
                shapes, family=family, path_length=path_length,
                screening=screening, solver_tol=solver_tol,
                max_iter=max_iter, kkt_tol=kkt_tol, max_refits=max_refits,
                working_set=working_set, ws_tiers=ws_tiers, dtype=dtype,
                y_dtype=y_dtype)
        specs = []
        for n, p in shapes:
            N, P = self.policy.shape_bucket(n, p, family.name)
            base = dict(
                family=family, batch=self.slots, n_rows=N, n_cols=P,
                path_length=path_length, screening=screening,
                solver_tol=solver_tol, max_iter=max_iter, kkt_tol=kkt_tol,
                max_refits=max_refits, dtype=dtype, y_dtype=y_dtype)
            specs.append(ProgramSpec(**base, variant="init"))
            specs.append(ProgramSpec(**base, variant="chunk",
                                     step_chunk=self.step_chunk))
        return self.cache.warmup(specs)

    def stats(self) -> dict:
        """Strict superset of :meth:`PathService.stats` — the async-only
        keys are a read-through over the same :attr:`metrics` registry."""
        out = super().stats()
        m = self.metrics
        with self._lock:
            out.update(
                slot_recycles=m.value("slot_recycles"),
                chunk_batches=m.value("chunk_batches"),
                step_chunk=self.step_chunk,
                inflight=len(self._futures),
                retries=m.value("retries"),
                bisections=m.value("bisections"),
                poisoned=m.value("poisoned"),
                checkpoints=m.value("checkpoints"),
                restored=m.value("restored"),
                retry_limit=self.retry_limit,
                retry_backoff=self.retry_backoff,
                worker_alive=bool(self._worker is not None
                                  and self._worker.is_alive()),
            )
        return out
