"""`AsyncPathService` — the asynchronous, continuously-batched front end.

The synchronous :class:`~repro.serve.service.PathService` enforces flush
deadlines *on the next service call*: an idle queue can hold a request past
its deadline forever (ROADMAP open item 2).  This subclass closes that gap
with a worker thread and changes the submit contract:

* ``submit`` returns a :class:`concurrent.futures.Future` instead of a
  request id (``future.rid`` carries the id; ``poll`` is disabled).
* A dispatcher thread sleeps until the earliest flush deadline
  (:meth:`~repro.serve.batcher.MicroBatcher.next_deadline`) and flushes on
  time even when no further calls arrive — deadline enforcement is
  timer-driven, not call-driven.
* Admission is bounded: past ``max_queue`` queued requests, ``submit``
  resolves the future immediately with a :class:`Rejection` status (the
  caller sees backpressure in microseconds, not a deadline miss later).
* Masked-engine groups run with **continuous batching**: the grid advances
  in ``step_chunk``-step compiled chunks
  (:func:`repro.core.engine.chunk_path_engine`) with per-slot carried
  state, so a path that early-stops frees its batch slot at the next chunk
  boundary and the next queued same-bucket request joins the *running*
  cohort — seeded mid-flight by :func:`repro.core.engine.path_init_engine`
  with bitwise the state a from-scratch run starts from.  Compact groups
  keep the whole-grid program (compact carried state is not
  slot-swappable).

Bit-identity is preserved end to end: the chunked step body is the SAME
traced body the monolithic engines scan, dead chunk steps hold the carry
exactly, and batch slots are member-invariant — an async-served result
equals the synchronous served result (and the direct padded call) at
tolerance 0.  ``tests/test_serve_async.py`` pins this.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from ..core.engine import cv_fold_indices, cv_select, cv_val_deviance, \
    null_sigma_grid
from ..core.losses import Family, ols
from ..core.path import _stop_triggered
from ..core.solver import DEFAULT_WS_TIERS
from .batcher import MicroBatcher, Pending, QueueFull
from .buckets import pad_batch
from .cache import ProgramSpec
from .service import CvResponse, PathResponse, PathService, _GroupKey

__all__ = ["AsyncPathService", "Rejection"]


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Admission-control verdict: the request was NOT queued.

    Resolved into the submit future immediately, so callers distinguish
    "rejected now" from "missed its deadline later" without waiting.
    """

    rid: int
    reason: str
    queued: int            # queue depth at the rejecting admission
    max_queue: int | None  # the capacity that was hit


@dataclasses.dataclass
class _Slot:
    """One occupied batch slot in a continuous run (host-side bookkeeping;
    the device carry lives in the run's persistent buffers)."""

    pending: Pending
    grid: np.ndarray       # native σ grid in the program dtype, length L
    n: int                 # native rows
    p: int                 # native cols
    inserted: float        # service clock at slot insertion
    batch_size: int        # occupied slots when this one joined
    cache_hit: bool
    early_stop: bool = True  # False for CV fold fits: the aggregation
    #   needs every fold on the full shared grid (sync parity)
    null_dev: float = 0.0
    prev_dev: float = 0.0  # early-stop carry across chunk boundaries
    cursor: int = 1        # next σ index to produce; done at cursor == L
    take: int = 0          # live steps requested from the current chunk
    solve_s: float = 0.0   # accumulated chunk walls while this slot ran
    finished: bool = False
    steps: list = dataclasses.field(default_factory=list)
    # each entry: (beta (p, m), n_active, n_screened, n_violations,
    #              refits, solver_iters, deviance, kkt_unrepaired)


class AsyncPathService(PathService):
    """Worker-thread path service: futures, SLOs, continuous batching.

    ``step_chunk`` is the continuous-batching granularity: slots can be
    recycled every ``step_chunk`` σ-steps (smaller = faster recycling, more
    host round-trips).  ``max_queue`` bounds queued depth for admission
    control.  ``autostart=False`` leaves the dispatcher stopped (useful for
    testing admission without execution); :meth:`start` launches it.
    """

    def __init__(self, *, max_batch: int = 8, max_delay: float = 0.02,
                 step_chunk: int = 8, max_queue: int | None = 64,
                 autostart: bool = True, policy=None, cache=None,
                 canonicalizer=None, clock=time.perf_counter):
        super().__init__(max_batch=max_batch, max_delay=max_delay,
                         policy=policy, cache=cache,
                         canonicalizer=canonicalizer, clock=clock)
        if step_chunk < 1:
            raise ValueError(f"step_chunk must be ≥ 1, got {step_chunk}")
        # rebuild the batcher with the admission bound (the base service
        # keeps its historical unbounded queue)
        self._batcher = MicroBatcher(max_batch=max_batch,
                                     max_delay=max_delay,
                                     max_queue=max_queue)
        self.step_chunk = step_chunk
        self._futures: dict[int, Future] = {}
        self._rejected = 0
        self._slot_recycles = 0
        self._chunk_batches = 0
        self._last_error: BaseException | None = None
        self._cond = threading.Condition()
        self._stop_flag = False
        self._worker: threading.Thread | None = None
        if autostart:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Launch the dispatcher thread (idempotent)."""
        with self._cond:
            if self._worker is not None and self._worker.is_alive():
                return
            self._stop_flag = False
            self._worker = threading.Thread(
                target=self._run, name="repro-serve-dispatch", daemon=True)
            self._worker.start()

    def close(self, *, flush: bool = True, timeout: float = 10.0) -> None:
        """Stop the dispatcher; ``flush=True`` then serves anything still
        queued synchronously so no admitted future is left unresolved."""
        with self._cond:
            self._stop_flag = True
            self._cond.notify_all()
        w = self._worker
        if w is not None:
            w.join(timeout=timeout)
        if flush:
            self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every admitted request has been delivered (or
        ``timeout`` seconds passed; returns False on timeout)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                idle = not self._futures and self._batcher.pending() == 0
            if idle:
                return True
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.001)

    # -- admission (future-returning) ---------------------------------------

    def _admit(self, key: _GroupKey, item, *, deadline_ms=None, priority=0,
               _cv_fold: bool = False) -> Future:
        fut: Future = Future()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._submitted += 1
            fut.rid = rid
            if _cv_fold:
                self._cv_fold_rids.add(rid)
            now = self._clock()
            try:
                self._batcher.admit(
                    key, rid, item, now, priority=priority,
                    deadline=self._flush_by(now, deadline_ms))
            except QueueFull as e:
                self._rejected += 1
                self._cv_fold_rids.discard(rid)
                fut.set_result(Rejection(
                    rid=rid, reason=str(e), queued=self._batcher.pending(),
                    max_queue=self._batcher.max_queue))
                return fut
            self._futures[rid] = fut
        with self._cond:
            self._cond.notify_all()  # wake the dispatcher: new work/deadline
        return fut

    def _deliver(self, rid: int, resp: PathResponse) -> None:
        """Resolve the request's future (caller holds ``self._lock``)."""
        self._completed += 1
        self._record_latency(rid, resp)   # before dropping fold membership
        self._cv_fold_rids.discard(rid)
        fut = self._futures.pop(rid, None)
        if fut is not None and not fut.done():
            fut.set_result(resp)

    def poll(self, rid, *, flush: bool = False):
        raise TypeError("AsyncPathService resolves results through the "
                        "futures submit() returns; there is nothing to poll")

    # -- CV: fold futures aggregate through a done-callback -----------------

    def _submit_cv(self, X, y, lam, family, *, n_folds, stratify, selection,
                   sigmas, path_length, sigma_ratio, screening, solver_tol,
                   max_iter, kkt_tol, max_refits, working_set,
                   ws_tiers=DEFAULT_WS_TIERS, deadline_ms=None,
                   priority=0) -> Future:
        if sigmas is None:
            sigmas = null_sigma_grid(X, y, lam, family,
                                     path_length=path_length,
                                     sigma_ratio=sigma_ratio)
        sigmas = np.asarray(sigmas)
        trains, vals = cv_fold_indices(y, n_folds, family=family,
                                       stratify=stratify)
        fold_futs = [
            self.submit(X[tr], y[tr], family=family, lam=lam, sigmas=sigmas,
                        screening=screening, solver_tol=solver_tol,
                        max_iter=max_iter, kkt_tol=kkt_tol,
                        max_refits=max_refits, working_set=working_set,
                        ws_tiers=ws_tiers, deadline_ms=deadline_ms,
                        priority=priority, _cv_fold=True)
            for tr in trains
        ]
        cv_fut: Future = Future()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._submitted += 1
        cv_fut.rid = rid
        remaining = [len(fold_futs)]
        agg_lock = threading.Lock()

        def on_fold_done(_):
            with agg_lock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            try:
                folds = [f.result() for f in fold_futs]
                rej = next((r for r in folds if isinstance(r, Rejection)),
                           None)
                if rej is not None:
                    cv_fut.set_result(Rejection(
                        rid=rid,
                        reason=f"CV fold rejected: {rej.reason}",
                        queued=rej.queued, max_queue=rej.max_queue))
                    return
                betas = np.stack([f.betas for f in folds])
                val_dev = cv_val_deviance(X, y, vals, betas, family)
                mean, se, best_min, best_1se = cv_select(val_dev)
                best = best_1se if selection == "1se" else best_min
                with self._lock:
                    self._completed += 1
                cv_fut.set_result(CvResponse(
                    rid=rid, sigmas=sigmas, lam=lam, val_deviance=val_dev,
                    mean_val_deviance=mean, se_val_deviance=se,
                    best_index=best, best_sigma=float(sigmas[best]),
                    best_index_min=best_min, best_index_1se=best_1se,
                    selection=selection, fold_responses=folds))
            except BaseException as e:  # pragma: no cover - defensive
                if not cv_fut.done():
                    cv_fut.set_exception(e)

        for f in fold_futs:
            f.add_done_callback(on_fold_done)
        return cv_fut

    # -- the dispatcher -----------------------------------------------------

    def _next_group(self):
        fill = self._batcher.fillable()
        if fill:
            return fill[0], "fill"
        due = self._batcher.due(self._clock())
        if due:
            return due[0], "deadline"
        return None, None

    def _run(self) -> None:
        while True:
            key = trigger = None
            with self._cond:
                while not self._stop_flag:
                    key, trigger = self._next_group()
                    if key is not None:
                        break
                    nd = self._batcher.next_deadline()
                    if nd is None:
                        self._cond.wait()
                    else:
                        # +0.1 ms so the post-sleep clock is past the
                        # deadline and due() actually returns the group
                        self._cond.wait(
                            timeout=max(0.0, nd - self._clock()) + 1e-4)
                if self._stop_flag:
                    return
            try:
                self._serve_group(key, trigger)
            except BaseException as e:  # keep serving; fail what's in flight
                self._last_error = e
                with self._lock:
                    futs = list(self._futures.values())
                    self._futures.clear()
                    self._cv_fold_rids.clear()
                for f in futs:
                    if not f.done():
                        f.set_exception(e)

    def _serve_group(self, key: _GroupKey, trigger: str) -> None:
        if key.working_set is not None:
            # compact carried state is not slot-swappable: whole-grid
            # program, same as the synchronous service (delivery still
            # resolves futures through the _deliver override)
            self._flush_group(key, trigger=trigger)
        else:
            self._run_continuous(key, trigger)

    # -- continuous batching (masked groups) --------------------------------

    def _chunk_specs(self, key: _GroupKey):
        base = dict(
            family=key.family, batch=self.slots, n_rows=key.n_rows,
            n_cols=key.n_cols, path_length=key.path_length,
            screening=key.screening, solver_tol=key.solver_tol,
            max_iter=key.max_iter, kkt_tol=key.kkt_tol,
            max_refits=key.max_refits, dtype=key.dtype, y_dtype=key.y_dtype)
        return (ProgramSpec(**base, variant="init"),
                ProgramSpec(**base, variant="chunk",
                            step_chunk=self.step_chunk))

    def _run_continuous(self, key: _GroupKey, trigger: str) -> None:
        """Serve one masked group until it drains, recycling slots.

        Persistent padded operand buffers plus the scan carry round-trip
        through the host between ``step_chunk``-step compiled chunks.  At
        every chunk boundary, finished slots (grid done or early-stopped)
        deliver and free; queued same-group requests take the free slots
        and are seeded by the init program — run on the whole updated batch,
        scattered only into the inserted slots, so standing neighbours'
        state is untouched (bitwise).
        """
        family = key.family
        m = family.n_classes
        S, N, P, L = self.slots, key.n_rows, key.n_cols, key.path_length
        C = self.step_chunk
        f = np.dtype(key.dtype)
        init_spec, chunk_spec = self._chunk_specs(key)
        init_prog, init_hit = self.cache.get(init_spec)
        chunk_prog, chunk_hit = self.cache.get(chunk_spec)
        first_hit = init_hit and chunk_hit

        Xs = np.zeros((S, N, P), f)
        ys = np.zeros((S, N), np.dtype(key.y_dtype))
        lam = np.zeros((S, P * m), f)
        p_valid = np.zeros((S,), np.int32)
        sig_prev = np.ones((S, C), f)
        sig_next = np.ones((S, C), f)
        live = np.zeros((S, C), bool)
        beta = np.zeros((S, P, m), f)
        grad = np.zeros((S, P, m), f)
        active = np.zeros((S, P), bool)
        Lc = np.ones((S,), f)
        slots: list[_Slot | None] = [None] * S

        plan_summary = chunk_spec.plan().summary()
        with self._lock:
            counter = {"fill": "_flush_fill", "deadline": "_flush_deadline",
                       "forced": "_flush_forced"}[trigger]
            setattr(self, counter, getattr(self, counter) + 1)
            self._plans[plan_summary] = self._plans.get(plan_summary, 0) + 1

        rounds = 0
        while True:
            # refill free slots from the queue (the slot-recycle seam)
            free = [i for i in range(S) if slots[i] is None]
            taken = self._batcher.take(key, limit=len(free)) if free else []
            occupied = S - len(free) + len(taken)
            inserted = []
            now = self._clock()
            for i, pending in zip(free, taken):
                item = pending.item
                pb = pad_batch(
                    [(item.X, item.y, item.lam, item.sigmas)],
                    n_rows=N, n_cols=P, n_slots=1, n_classes=m)
                Xs[i] = pb.Xs[0]
                ys[i] = pb.ys[0]
                lam[i] = pb.lam[0]
                p_valid[i] = pb.p_valid[0]
                with self._lock:
                    es = pending.rid not in self._cv_fold_rids
                slots[i] = _Slot(
                    pending=pending, grid=np.asarray(item.sigmas, f),
                    n=item.X.shape[0], p=item.X.shape[1], inserted=now,
                    batch_size=occupied, early_stop=es,
                    cache_hit=first_hit if rounds == 0 else True)
                inserted.append(i)
            if inserted:
                if rounds > 0:
                    # joined a cohort already in flight: true recycling
                    self._slot_recycles += len(inserted)
                # prefill on the WHOLE updated batch, scatter only the new
                # slots — standing neighbours keep their carried state
                g0, nd0, L0 = (np.asarray(a) for a in init_prog(Xs, ys))
                for i in inserted:
                    beta[i] = 0.0
                    grad[i] = g0[i]
                    active[i] = False
                    Lc[i] = L0[i]
                    slots[i].null_dev = slots[i].prev_dev = float(nd0[i])
                    if L < 2:  # degenerate grid: null model only
                        self._finish_slot(i, slots, p_valid, key)
            if all(s is None for s in slots):
                break

            # per-slot chunk inputs from each slot's own grid cursor
            for i in range(S):
                s = slots[i]
                if s is None:
                    sig_prev[i] = 1.0
                    sig_next[i] = 1.0
                    live[i] = False
                    continue
                s.take = min(C, L - s.cursor)
                for c in range(C):
                    if c < s.take:
                        sig_prev[i, c] = s.grid[s.cursor - 1 + c]
                        sig_next[i, c] = s.grid[s.cursor + c]
                        live[i, c] = True
                    else:
                        sig_prev[i, c] = 1.0
                        sig_next[i, c] = 1.0
                        live[i, c] = False

            t0 = self._clock()
            (nb, ng, na, nL), ep = chunk_prog(
                Xs, ys, lam, sig_prev, sig_next, live, beta, grad, active,
                Lc, p_valid)
            # np.array (copy): device outputs view as read-only, but the
            # carry buffers are scattered into at the next insertion
            beta = np.array(nb)
            grad = np.array(ng)
            active = np.array(na)
            Lc = np.array(nL)
            eb = np.asarray(ep.betas)
            edev = np.asarray(ep.deviance)
            scalars = [np.asarray(a) for a in
                       (ep.n_active, ep.n_screened, ep.n_violations,
                        ep.refits, ep.solver_iters)]
            eunrep = np.asarray(ep.kkt_unrepaired)
            wall = self._clock() - t0
            rounds += 1
            n_live = sum(s is not None for s in slots)
            with self._lock:
                self._batches += 1
                self._chunk_batches += 1
                self._occupancies.append(n_live / S)

            # harvest: native-width steps, early stop on the growing prefix
            for i in range(S):
                s = slots[i]
                if s is None:
                    continue
                s.solve_s += wall
                for c in range(s.take):
                    b = np.array(eb[i, c, :s.p, :])
                    dev = float(edev[i, c])
                    s.steps.append((
                        b, *(int(a[i, c]) for a in scalars), dev,
                        bool(eunrep[i, c])))
                    s.cursor += 1
                    # the SAME predicate the sync path applies post-hoc —
                    # it reads only the prefix, so stopping at a chunk
                    # boundary truncates exactly where path_result() would
                    if s.early_stop and _stop_triggered(
                            b, dev, s.prev_dev, s.null_dev, s.n):
                        s.finished = True
                        break
                    s.prev_dev = dev
                if s.finished or s.cursor >= L:
                    self._finish_slot(i, slots, p_valid, key)

    def _finish_slot(self, i: int, slots: list, p_valid: np.ndarray,
                     key: _GroupKey) -> None:
        """Assemble the slot's response (null head + harvested steps at
        native shape), deliver its future, and free the slot."""
        s = slots[i]
        m = key.family.n_classes
        f = np.dtype(key.dtype)
        k = 1 + len(s.steps)
        betas = np.zeros((k, s.p, m), f)
        n_act = np.zeros((k,), np.int32)
        n_scr = np.zeros((k,), np.int32)
        viol = np.zeros((k,), np.int32)
        refits = np.zeros((k,), np.int32)
        iters = np.zeros((k,), np.int32)
        dev = np.zeros((k,), f)
        unrep = np.zeros((k,), bool)
        dev[0] = s.null_dev
        for j, st in enumerate(s.steps, start=1):
            (betas[j], n_act[j], n_scr[j], viol[j], refits[j], iters[j],
             dev[j], unrep[j]) = st
        out_betas = betas[:, :, 0] if m == 1 else betas
        item = s.pending.item
        pad_ratio = (key.n_rows * key.n_cols) / (s.n * s.p)
        resp = PathResponse(
            rid=s.pending.rid, betas=out_betas,
            sigmas=np.asarray(item.sigmas)[:k], lam=item.lam, n_samples=s.n,
            n_active=n_act, n_screened=n_scr, n_violations=viol,
            refits=refits, solver_iters=iters, deviance=dev,
            kkt_unrepaired=unrep, kkt_ok=not bool(unrep.any()),
            working_set=None, working_set_top=None, ws_size=None,
            ws_tier=None, compact_fallback=None,
            queue_s=max(0.0, s.inserted - s.pending.submitted),
            solve_s=s.solve_s, batch_size=s.batch_size,
            batch_occupancy=s.batch_size / self.slots,
            padding_ratio=pad_ratio, cache_hit=s.cache_hit)
        with self._lock:
            self._padding_ratios.append(pad_ratio)
            self._deliver(s.pending.rid, resp)
        slots[i] = None
        p_valid[i] = 0

    # -- warmup & telemetry -------------------------------------------------

    def warmup(self, shapes, *, family: Family = ols, path_length: int = 100,
               screening: str = "strong", solver_tol: float = 1e-8,
               max_iter: int = 5000, kkt_tol: float = 1e-4,
               max_refits: int = 32,
               working_set: int | str | None = None,
               ws_tiers: int | str = DEFAULT_WS_TIERS,
               dtype: str = "float64", y_dtype: str = "float64") -> dict:
        """Pre-compile what async serving actually runs: the (init, chunk)
        program pair for masked shapes; compact shapes defer to the base
        whole-grid warmup."""
        if working_set is not None:
            return super().warmup(
                shapes, family=family, path_length=path_length,
                screening=screening, solver_tol=solver_tol,
                max_iter=max_iter, kkt_tol=kkt_tol, max_refits=max_refits,
                working_set=working_set, ws_tiers=ws_tiers, dtype=dtype,
                y_dtype=y_dtype)
        specs = []
        for n, p in shapes:
            N, P = self.policy.shape_bucket(n, p, family.name)
            base = dict(
                family=family, batch=self.slots, n_rows=N, n_cols=P,
                path_length=path_length, screening=screening,
                solver_tol=solver_tol, max_iter=max_iter, kkt_tol=kkt_tol,
                max_refits=max_refits, dtype=dtype, y_dtype=y_dtype)
            specs.append(ProgramSpec(**base, variant="init"))
            specs.append(ProgramSpec(**base, variant="chunk",
                                     step_chunk=self.step_chunk))
        return self.cache.warmup(specs)

    def stats(self) -> dict:
        out = super().stats()
        with self._lock:
            out.update(
                rejected=self._rejected,
                slot_recycles=self._slot_recycles,
                chunk_batches=self._chunk_batches,
                step_chunk=self.step_chunk,
                max_queue=self._batcher.max_queue,
                inflight=len(self._futures),
                worker_alive=bool(self._worker is not None
                                  and self._worker.is_alive()),
            )
        return out
