"""repro.serve — shape-bucketed SLOPE path serving.

The layer between a stream of heterogeneous fit requests and the batched
device engine: power-of-two shape bucketing with inert zero padding
(:mod:`~repro.serve.buckets`), admission queues with fill/deadline
micro-batching and λ-sequence canonicalization
(:mod:`~repro.serve.batcher`), an AOT compiled-program cache with warmup
and eviction stats (:mod:`~repro.serve.cache`), the synchronous
``submit``/``poll`` front-end (:mod:`~repro.serve.service`), and the
asynchronous future-returning front-end with timer-driven deadline flush
and continuous batching (:mod:`~repro.serve.dispatch`), and the
crash-safety primitives — durable program store, checkpoint/restore,
watchdog, circuit breaker, load shedding
(:mod:`~repro.serve.durable`).

Import layering: ``buckets`` is NumPy-only and is imported *by*
``repro.core.engine`` (the working-set bucket registry lives there), so it
loads eagerly; the other modules import ``repro.core`` and load lazily via
module ``__getattr__`` to stay clear of the initialisation cycle.
"""

from .buckets import (
    BucketRegistry,
    PaddedBatch,
    ShapeBucketPolicy,
    default_policy,
    next_pow2,
    pad_batch,
)

_LAZY = {
    "ProgramCache": "cache",
    "ProgramSpec": "cache",
    "CompiledProgram": "cache",
    "MicroBatcher": "batcher",
    "LambdaCanonicalizer": "batcher",
    "Pending": "batcher",
    "QueueFull": "batcher",
    "Rejection": "batcher",
    "RejectionError": "batcher",
    "lambda_kinds": "batcher",
    "PathService": "service",
    "PathResponse": "service",
    "CvResponse": "service",
    "ResampleResponse": "service",
    "AsyncPathService": "dispatch",
    "FaultPlan": "faults",
    "FaultSpec": "faults",
    "InjectedFault": "faults",
    "NO_FAULTS": "faults",
    "DurableProgramStore": "durable",
    "ServiceCheckpoint": "durable",
    "CircuitBreaker": "durable",
    "LoadShedGovernor": "durable",
    "WatchdogTimeout": "durable",
}

__all__ = [
    "BucketRegistry", "PaddedBatch", "ShapeBucketPolicy", "default_policy",
    "next_pow2", "pad_batch", *sorted(_LAZY),
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(f".{_LAZY[name]}", __name__),
                       name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
