"""Admission queue and micro-batcher for the path service.

Requests land in per-group FIFO queues — a *group* is everything that can
legally share one compiled program: same family, same padded bucket shape,
same path length and solver statics.  A group flushes when it **fills**
(``max_batch`` requests waiting) or when its oldest request passes its
**deadline** (``max_delay`` seconds in the queue).  The service is
synchronous, so deadline flushes happen on the next ``submit``/``poll``
call rather than on a timer thread — the deadline bounds added latency
under load, not wall-clock staleness of an abandoned queue.

λ-sequence canonicalization lives here too: requests that *name* a sequence
(``("bh", q)`` etc.) resolve through one memoised table, so equal specs map
to the same immutable array (one hash, byte-equal padded operands) instead
of freshly generated near-duplicates.  Since PR 4 the declarative
:class:`repro.api.LambdaSpec` is the canonical naming surface — it resolves
through the process-wide shared instance
(:func:`repro.api.shared_canonicalizer`), which is also every
:class:`~repro.serve.service.PathService`'s default, so direct and served
execution share one memo table.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque

import numpy as np

from ..core.lambda_seq import (
    bh_sequence,
    gaussian_sequence,
    lasso_sequence,
    oscar_sequence,
)

__all__ = ["Pending", "MicroBatcher", "LambdaCanonicalizer", "lambda_kinds"]


@dataclasses.dataclass
class Pending:
    """One queued request: opaque payload plus admission bookkeeping."""

    rid: int
    item: object
    submitted: float   # service clock at admission
    deadline: float    # submitted + max_delay


class MicroBatcher:
    """Per-group FIFO queues with fill- and deadline-triggered flushing."""

    def __init__(self, max_batch: int = 8, max_delay: float = 0.02):
        if max_batch < 1:
            raise ValueError(f"max_batch must be ≥ 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be ≥ 0, got {max_delay}")
        self.max_batch = max_batch
        self.max_delay = max_delay
        self._queues: OrderedDict[object, deque[Pending]] = OrderedDict()
        self._lock = threading.Lock()

    def admit(self, key, rid: int, item, now: float) -> bool:
        """Queue one request; True ⇒ the group just filled and should flush."""
        with self._lock:
            q = self._queues.get(key)
            if q is None:
                q = deque()
                self._queues[key] = q
            q.append(Pending(rid, item, now, now + self.max_delay))
            return len(q) >= self.max_batch

    def due(self, now: float) -> list:
        """Groups whose oldest request has passed its deadline."""
        with self._lock:
            return [k for k, q in self._queues.items()
                    if q and q[0].deadline <= now]

    def take(self, key, limit: int | None = None) -> list[Pending]:
        """Pop up to ``limit`` (default ``max_batch``) requests, FIFO."""
        limit = self.max_batch if limit is None else limit
        with self._lock:
            q = self._queues.get(key)
            if not q:
                self._queues.pop(key, None)
                return []
            batch = [q.popleft() for _ in range(min(limit, len(q)))]
            if not q:
                del self._queues[key]
            return batch

    def groups(self) -> list:
        with self._lock:
            return [k for k, q in self._queues.items() if q]

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())


_SEQUENCES = {
    "bh": bh_sequence,
    "gaussian": gaussian_sequence,
    "oscar": oscar_sequence,
    "lasso": lasso_sequence,
}


def lambda_kinds() -> tuple[str, ...]:
    """The named λ-sequence recipes (the single source of truth shared with
    ``repro.api.LambdaSpec`` validation)."""
    return tuple(sorted(_SEQUENCES))


class LambdaCanonicalizer:
    """Memoised named-λ-sequence table: ``(kind, q, size) → one array``.

    The returned arrays are read-only — every request naming the same spec
    shares the same bytes, so padded batches built from them are byte-equal
    and the program inputs (not just the program) are canonical.
    """

    def __init__(self):
        self._memo: dict[tuple, np.ndarray] = {}
        self._lock = threading.Lock()

    def get(self, kind: str, q: float, size: int,
            n: int | None = None) -> np.ndarray:
        # n parameterizes only the gaussian recursion; keying every other
        # kind on it would duplicate byte-identical arrays per problem size
        key = (kind, float(q), int(size), n if kind == "gaussian" else None)
        with self._lock:
            lam = self._memo.get(key)
            if lam is None:
                fn = _SEQUENCES.get(kind)
                if fn is None:
                    raise ValueError(
                        f"unknown λ sequence {kind!r}; choose from "
                        f"{sorted(_SEQUENCES)}")
                if kind == "lasso":
                    lam = np.asarray(fn(size), np.float64)
                elif kind == "gaussian":
                    if n is None:
                        raise ValueError("gaussian sequences need n")
                    lam = np.asarray(fn(size, n, q), np.float64)
                else:
                    lam = np.asarray(fn(size, q), np.float64)
                lam.flags.writeable = False
                self._memo[key] = lam
            return lam

    def __len__(self) -> int:
        with self._lock:
            return len(self._memo)
