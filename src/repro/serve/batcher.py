"""Admission queue and micro-batcher for the path service.

Requests land in per-group priority queues — a *group* is everything that
can legally share one compiled program: same family, same padded bucket
shape, same path length and solver statics.  Within a group, higher
``priority`` pops first; equal priorities keep FIFO order (a stable
sequence number breaks ties), so the default priority-0 stream behaves
exactly like the original FIFO.  A group flushes when it **fills**
(``max_batch`` requests waiting) or when its most urgent request passes
its **flush deadline** (``max_delay`` seconds in the queue, or sooner for
requests carrying their own deadline budget).

Two front-ends drain these queues: the synchronous
:class:`~repro.serve.service.PathService` checks deadlines on the next
``submit``/``poll`` call (no timer thread — the deadline bounds added
latency under load, not wall-clock staleness of an abandoned queue), and
the async :class:`~repro.serve.dispatch.AsyncPathService` runs a worker
thread that sleeps until :meth:`MicroBatcher.next_deadline` and flushes on
time even when no further calls arrive.  ``max_queue`` bounds total queued
depth for admission control: past capacity, :meth:`MicroBatcher.admit`
raises :class:`QueueFull` and the async service rejects-with-status
instead of queueing unboundedly.

λ-sequence canonicalization lives here too: requests that *name* a sequence
(``("bh", q)`` etc.) resolve through one memoised table, so equal specs map
to the same immutable array (one hash, byte-equal padded operands) instead
of freshly generated near-duplicates.  Since PR 4 the declarative
:class:`repro.api.LambdaSpec` is the canonical naming surface — it resolves
through the process-wide shared instance
(:func:`repro.api.shared_canonicalizer`), which is also every
:class:`~repro.serve.service.PathService`'s default, so direct and served
execution share one memo table.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
from collections import OrderedDict

import numpy as np

from ..core.lambda_seq import (
    bh_sequence,
    gaussian_sequence,
    lasso_sequence,
    oscar_sequence,
)

__all__ = ["Pending", "MicroBatcher", "QueueFull", "Rejection",
           "RejectionError", "LambdaCanonicalizer", "lambda_kinds"]


class QueueFull(RuntimeError):
    """Admission rejected: the batcher's bounded queue is at capacity.

    Deprecated alias surface: services raise/convert this into the
    structured :class:`Rejection` form — the synchronous service raises
    :class:`RejectionError` (a ``QueueFull`` subclass, so existing
    ``except QueueFull`` handlers keep working) and the async service
    resolves the future with the :class:`Rejection` value itself.
    """


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Admission-control verdict: the request was NOT queued.

    The ONE structured rejection shape both front-ends speak: the async
    service resolves it into the submit future immediately (callers
    distinguish "rejected now" from "missed its deadline later" without
    waiting), the synchronous service raises it wrapped in
    :class:`RejectionError`.
    """

    rid: int
    reason: str            # queue-capacity text, or the admission-control
    #   verdicts "circuit_open" (per-program circuit breaker is open) and
    #   "shed" (adaptive load shedding under latency pressure)
    queued: int            # queue depth at the rejecting admission
    max_queue: int | None  # the capacity that was hit (None: not a
    #   capacity rejection)


class RejectionError(QueueFull):
    """Synchronous admission rejection carrying the structured verdict.

    Subclasses :class:`QueueFull` so pre-PR-7 ``except QueueFull`` code
    keeps catching capacity rejections; new code should read
    ``err.rejection`` for the structured fields.
    """

    def __init__(self, rejection: Rejection):
        super().__init__(rejection.reason)
        self.rejection = rejection


@dataclasses.dataclass
class Pending:
    """One queued request: opaque payload plus admission bookkeeping."""

    rid: int
    item: object
    submitted: float   # service clock at admission
    deadline: float    # flush-by time (submitted + max_delay, or tighter
    #   when the request carries its own latency budget)
    priority: int = 0  # higher pops first within the group; 0 = default


class MicroBatcher:
    """Per-group priority queues with fill- and deadline-triggered flushing.

    ``max_queue`` (optional) bounds TOTAL queued requests across groups —
    the admission-control knob: at capacity, :meth:`admit` raises
    :class:`QueueFull` instead of queueing (unbounded by default, which is
    the synchronous service's historical behaviour).
    """

    def __init__(self, max_batch: int = 8, max_delay: float = 0.02,
                 max_queue: int | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be ≥ 1, got {max_batch}")
        if max_delay < 0:
            raise ValueError(f"max_delay must be ≥ 0, got {max_delay}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be ≥ 1, got {max_queue}")
        self.max_batch = max_batch
        self.max_delay = max_delay
        self.max_queue = max_queue
        # heap entries (-priority, seq, Pending): priority order, FIFO ties
        self._queues: OrderedDict[object, list] = OrderedDict()
        self._seq = 0
        self._size = 0
        self._lock = threading.Lock()

    def admit(self, key, rid: int, item, now: float, *, priority: int = 0,
              deadline: float | None = None) -> bool:
        """Queue one request; True ⇒ the group just filled and should flush.

        Raises :class:`QueueFull` when ``max_queue`` is set and reached —
        the request is NOT queued and the caller owns the rejection.
        """
        if deadline is None:
            deadline = now + self.max_delay
        with self._lock:
            if self.max_queue is not None and self._size >= self.max_queue:
                raise QueueFull(
                    f"micro-batcher queue at capacity "
                    f"({self._size}/{self.max_queue} queued requests)")
            q = self._queues.get(key)
            if q is None:
                q = []
                self._queues[key] = q
            heapq.heappush(
                q, (-priority, self._seq,
                    Pending(rid, item, now, deadline, priority)))
            self._seq += 1
            self._size += 1
            return len(q) >= self.max_batch

    def due(self, now: float) -> list:
        """Groups holding a request past its flush deadline."""
        with self._lock:
            return [k for k, q in self._queues.items()
                    if q and min(e[2].deadline for e in q) <= now]

    def next_deadline(self) -> float | None:
        """Earliest flush deadline over every queued request (None when
        idle) — what the async worker thread sleeps until."""
        with self._lock:
            deadlines = [e[2].deadline for q in self._queues.values()
                         for e in q]
            return min(deadlines) if deadlines else None

    def fillable(self) -> list:
        """Groups at or above fill capacity (``max_batch`` queued)."""
        with self._lock:
            return [k for k, q in self._queues.items()
                    if len(q) >= self.max_batch]

    def take(self, key, limit: int | None = None) -> list[Pending]:
        """Pop up to ``limit`` (default ``max_batch``) requests — highest
        priority first, FIFO within a priority."""
        limit = self.max_batch if limit is None else limit
        with self._lock:
            q = self._queues.get(key)
            if not q:
                self._queues.pop(key, None)
                return []
            batch = [heapq.heappop(q)[2]
                     for _ in range(min(limit, len(q)))]
            self._size -= len(batch)
            if not q:
                del self._queues[key]
            return batch

    def groups(self) -> list:
        with self._lock:
            return [k for k, q in self._queues.items() if q]

    def snapshot(self) -> list[tuple]:
        """Non-destructive ``(key, Pending)`` view of everything queued, in
        pop order per group — what a service checkpoint records without
        disturbing admission state."""
        with self._lock:
            return [(k, e[2]) for k, q in self._queues.items()
                    for e in sorted(q)]

    def pending(self) -> int:
        with self._lock:
            return self._size


_SEQUENCES = {
    "bh": bh_sequence,
    "gaussian": gaussian_sequence,
    "oscar": oscar_sequence,
    "lasso": lasso_sequence,
}


def lambda_kinds() -> tuple[str, ...]:
    """The named λ-sequence recipes (the single source of truth shared with
    ``repro.api.LambdaSpec`` validation)."""
    return tuple(sorted(_SEQUENCES))


class LambdaCanonicalizer:
    """Memoised named-λ-sequence table: ``(kind, q, size) → one array``.

    The returned arrays are read-only — every request naming the same spec
    shares the same bytes, so padded batches built from them are byte-equal
    and the program inputs (not just the program) are canonical.
    """

    def __init__(self):
        self._memo: dict[tuple, np.ndarray] = {}
        self._lock = threading.Lock()

    def get(self, kind: str, q: float, size: int,
            n: int | None = None) -> np.ndarray:
        # n parameterizes only the gaussian recursion; keying every other
        # kind on it would duplicate byte-identical arrays per problem size
        key = (kind, float(q), int(size), n if kind == "gaussian" else None)
        with self._lock:
            lam = self._memo.get(key)
            if lam is None:
                fn = _SEQUENCES.get(kind)
                if fn is None:
                    raise ValueError(
                        f"unknown λ sequence {kind!r}; choose from "
                        f"{sorted(_SEQUENCES)}")
                if kind == "lasso":
                    lam = np.asarray(fn(size), np.float64)
                elif kind == "gaussian":
                    if n is None:
                        raise ValueError("gaussian sequences need n")
                    lam = np.asarray(fn(size, n, q), np.float64)
                else:
                    lam = np.asarray(fn(size, q), np.float64)
                lam.flags.writeable = False
                self._memo[key] = lam
            return lam

    def __len__(self) -> int:
        with self._lock:
            return len(self._memo)
