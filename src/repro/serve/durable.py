"""Crash-safety primitives for the serve layer (PR 10).

Four cooperating pieces, all optional and inert by default:

* :class:`DurableProgramStore` — serialized AOT executables on disk, keyed
  by :class:`~repro.serve.cache.ProgramSpec`.  A restarted server loads a
  previously-compiled program in milliseconds instead of re-lowering and
  re-compiling it (seconds per shape).  Entries carry a spec hash, a
  jax/jaxlib/backend fingerprint and a payload checksum; anything corrupt
  or mismatched is discarded and rebuilt — a stored entry is never
  trusted.  A **warmup manifest** (JSONL, appended on every build) records
  the specs live traffic actually compiled, so :meth:`replay` at boot
  warms exactly the programs the previous process served.
* :class:`CircuitBreaker` — per-program-group failure gate: K consecutive
  compile/execute faults open the circuit (admissions rejected with
  ``Rejection(reason="circuit_open")``), a cooldown later one probe
  admission is let through (half-open), and its outcome closes or
  re-opens the circuit.  Stops a persistent fault from burning the
  retry/bisection budget on every new admission.
* :class:`LoadShedGovernor` — adaptive admission shedding: when the
  rolling user-scope latency p95 approaches a request's ``deadline_ms``,
  lowest-priority admissions are rejected with
  ``Rejection(reason="shed")`` instead of queueing work already doomed to
  miss its SLO.  The decision is a pure function of (p95 window, deadline,
  priority) — deterministic given the metrics window.
* :func:`run_with_watchdog` — bounded device dispatch: runs a call on a
  sacrificial thread and raises :class:`WatchdogTimeout` after
  ``solve_timeout_ms``, so a hung XLA call fails only its cohort (through
  the PR-7 retry/bisect path) instead of stalling the dispatcher forever.
  The abandoned call finishes (or hangs) on its daemon thread; its result
  is discarded.

:class:`ServiceCheckpoint` is the picklable snapshot
``AsyncPathService.checkpoint()`` produces and ``restore()`` consumes:
admitted-but-undelivered requests plus per-slot carried engine state at a
chunk boundary, so resumed requests complete **bit-identical** to an
uninterrupted run (the chunk carry already round-trips through host
buffers — see :mod:`repro.serve.dispatch`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import threading
import time

import numpy as np

import jax

from ..core.losses import Family, logistic, ols, poisson
from .batcher import Pending

__all__ = [
    "DurableProgramStore", "CircuitBreaker", "LoadShedGovernor",
    "WatchdogTimeout", "run_with_watchdog", "ServiceCheckpoint",
    "QueuedRequest", "InflightSlot",
]

# family registry for manifest round-trips: specs serialize the family by
# name and reconstruct through here (families are code, not data)
_FAMILIES: dict[str, Family] = {f.name: f for f in (ols, logistic, poisson)}

_ENTRY_VERSION = 1


def _spec_token(spec) -> str:
    """Canonical string over every ProgramSpec field (family by name) —
    the integrity token stored with (and checked against) each entry."""
    parts = []
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if isinstance(v, Family):
            v = v.name
        parts.append(f"{f.name}={v!r}")
    return ";".join(parts)


def _spec_to_json(spec) -> dict:
    out = {}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        out[f.name] = v.name if isinstance(v, Family) else v
    return out


def _spec_from_json(d: dict):
    from .cache import ProgramSpec

    d = dict(d)
    fam = _FAMILIES.get(d.pop("family", None))
    if fam is None:
        return None
    known = {f.name for f in dataclasses.fields(ProgramSpec)}
    if set(d) - known:
        return None
    return ProgramSpec(family=fam, **d)


def backend_fingerprint() -> str:
    """What a serialized executable's validity depends on: jax + jaxlib
    versions and the backend it was compiled for."""
    import jaxlib

    return (f"jax={jax.__version__}|jaxlib={jaxlib.__version__}"
            f"|backend={jax.default_backend()}")


def _can_serialize() -> bool:
    try:
        from jax.experimental import serialize_executable  # noqa: F401

        return True
    except ImportError:  # pragma: no cover - backend-dependent
        return False


class DurableProgramStore:
    """Directory-backed store of serialized AOT executables + a warmup
    manifest.

    ``save``/``load`` serialize through
    :mod:`jax.experimental.serialize_executable` (true skip-compile
    restore).  When that is unavailable on the backend, the store degrades
    to wiring :mod:`jax.experimental.compilation_cache` at ``path`` — XLA
    then persists compilation artifacts itself and re-``lower().compile()``
    calls hit that cache; ``load`` returns None so callers rebuild (fast
    against the warmed XLA cache), and the manifest still drives boot
    warmup.  Integrity: every entry stores the spec token, the
    jax/jaxlib/backend fingerprint and a payload checksum; any mismatch or
    unpickling error discards the entry (counted, file unlinked) — a
    corrupt store can cost a rebuild, never a wrong program.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        os.makedirs(self.path, exist_ok=True)
        self.serializable = _can_serialize()
        if not self.serializable:  # pragma: no cover - backend-dependent
            from jax.experimental import compilation_cache

            compilation_cache.set_cache_dir(
                os.path.join(self.path, "xla_cache"))
        self._lock = threading.Lock()
        self.counters = {"saved": 0, "loaded": 0, "discarded": 0,
                         "replayed": 0}

    # -- keying -------------------------------------------------------------

    def _entry_path(self, spec) -> str:
        digest = hashlib.sha256(
            f"{_spec_token(spec)}|{backend_fingerprint()}".encode()
        ).hexdigest()
        return os.path.join(self.path, f"{digest}.prog")

    @property
    def _manifest_path(self) -> str:
        return os.path.join(self.path, "manifest.jsonl")

    # -- entries ------------------------------------------------------------

    def save(self, spec, prog) -> bool:
        """Serialize one :class:`~repro.serve.cache.CompiledProgram` and
        append the spec to the warmup manifest.  Returns False (and still
        records the manifest entry) when executable serialization is
        unavailable."""
        self._append_manifest(spec)
        if not self.serializable:  # pragma: no cover - backend-dependent
            return False
        from jax.experimental import serialize_executable as se

        payload, in_tree, out_tree = se.serialize(prog._compiled)
        entry = {
            "version": _ENTRY_VERSION,
            "token": _spec_token(spec),
            "fingerprint": backend_fingerprint(),
            "sha256": hashlib.sha256(payload).hexdigest(),
            "payload": payload,
            "in_tree": in_tree,
            "out_tree": out_tree,
            "build_seconds": prog.build_seconds,
        }
        target = self._entry_path(spec)
        tmp = f"{target}.tmp.{os.getpid()}"
        with self._lock:
            with open(tmp, "wb") as fh:
                pickle.dump(entry, fh)
            os.replace(tmp, target)  # atomic: never a half-written entry
            self.counters["saved"] += 1
        return True

    def load(self, spec):
        """Deserialize the stored executable for ``spec`` (or None).

        Every integrity check failure — unreadable pickle, token mismatch,
        fingerprint mismatch, payload checksum mismatch, deserialization
        error — discards the entry and returns None: the caller rebuilds
        from source, which is always safe."""
        from .cache import CompiledProgram

        if not self.serializable:  # pragma: no cover - backend-dependent
            return None
        target = self._entry_path(spec)
        if not os.path.exists(target):
            return None
        try:
            with open(target, "rb") as fh:
                entry = pickle.load(fh)
            if (entry["version"] != _ENTRY_VERSION
                    or entry["token"] != _spec_token(spec)
                    or entry["fingerprint"] != backend_fingerprint()
                    or entry["sha256"]
                    != hashlib.sha256(entry["payload"]).hexdigest()):
                raise ValueError("integrity check failed")
            from jax.experimental import serialize_executable as se

            compiled = se.deserialize_and_load(
                entry["payload"], entry["in_tree"], entry["out_tree"])
            prog = CompiledProgram(spec, compiled,
                                   float(entry["build_seconds"]))
            with self._lock:
                self.counters["loaded"] += 1
            return prog
        except BaseException:
            with self._lock:
                self.counters["discarded"] += 1
            try:
                os.unlink(target)
            except OSError:  # pragma: no cover - racing unlink
                pass
            return None

    # -- warmup manifest ----------------------------------------------------

    def _append_manifest(self, spec) -> None:
        line = json.dumps(_spec_to_json(spec), sort_keys=True)
        with self._lock:
            with open(self._manifest_path, "a") as fh:
                fh.write(line + "\n")

    def manifest_specs(self) -> list:
        """The deduped spec list live traffic has compiled (admission
        order), reconstructed from the manifest; undecodable lines and
        unknown families are skipped — the manifest is advisory, never
        load-bearing for correctness."""
        specs, seen = [], set()
        try:
            with open(self._manifest_path) as fh:
                lines = fh.readlines()
        except OSError:
            return []
        for line in lines:
            try:
                d = json.loads(line)
            except ValueError:
                continue
            if not isinstance(d, dict):
                continue
            try:
                spec = _spec_from_json(d)
            except (TypeError, ValueError):
                continue
            if spec is not None and spec not in seen:
                seen.add(spec)
                specs.append(spec)
        return specs

    def replay(self, cache) -> int:
        """Warm ``cache`` with every manifest spec (boot-time warmup).

        Specs resident in the store load without compiling; anything
        missing or discarded rebuilds — and re-saves — on the spot.
        Returns the number of programs warmed."""
        n = 0
        for spec in self.manifest_specs():
            cache.get(spec)
            n += 1
        with self._lock:
            self.counters["replayed"] += n
        return n

    def stats(self) -> dict:
        with self._lock:
            entries = sum(1 for f in os.listdir(self.path)
                          if f.endswith(".prog"))
            return {"path": self.path, "entries": entries,
                    "serializable": self.serializable, **self.counters}


# -- watchdog ---------------------------------------------------------------


class WatchdogTimeout(RuntimeError):
    """A watched device call exceeded its ``solve_timeout_ms`` budget."""


def run_with_watchdog(fn, timeout_s: float | None, *, label: str = ""):
    """Run ``fn()`` with a wall-clock budget.

    ``timeout_s=None`` calls inline (zero overhead — the default path).
    Otherwise ``fn`` runs on a sacrificial daemon thread; past the budget a
    :class:`WatchdogTimeout` is raised to the caller and the stuck call is
    abandoned (an XLA computation cannot be cancelled — the thread finishes
    or hangs on its own, its result discarded).  A per-call thread, not a
    pooled one, so one hung call can never block the next watched call.
    """
    if timeout_s is None:
        return fn()
    box: dict = {}
    done = threading.Event()

    def _target():
        try:
            box["result"] = fn()
        except BaseException as e:
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=_target, daemon=True,
                         name=f"repro-serve-watchdog/{label}")
    t.start()
    if not done.wait(timeout_s):
        raise WatchdogTimeout(
            f"device dispatch exceeded solve_timeout "
            f"({timeout_s * 1e3:.0f} ms){f' [{label}]' if label else ''}")
    if "error" in box:
        raise box["error"]
    return box["result"]


# -- circuit breaker --------------------------------------------------------


@dataclasses.dataclass
class _BreakerState:
    failures: int = 0
    state: str = "closed"      # closed | open | half_open
    opened_at: float = 0.0
    probing: bool = False      # half-open probe admitted, outcome pending


class CircuitBreaker:
    """Per-key consecutive-failure gate with a half-open probe.

    ``record_failure``/``record_success`` are called per compile/execute
    attempt by the serving worker; ``allow`` gates admissions.  K
    (``threshold``) *consecutive* failures open the circuit — interleaved
    successes (e.g. the innocent halves of a bisection) reset the count, so
    only a genuinely persistent fault opens it.  After ``cooldown``
    seconds, ONE admission is let through as the half-open probe; its
    outcome closes (success) or re-opens (failure) the circuit.
    """

    def __init__(self, *, threshold: int = 5, cooldown: float = 5.0,
                 clock=time.perf_counter):
        if threshold < 1:
            raise ValueError(f"threshold must be ≥ 1, got {threshold}")
        if cooldown < 0:
            raise ValueError(f"cooldown must be ≥ 0, got {cooldown}")
        self.threshold = threshold
        self.cooldown = cooldown
        self._clock = clock
        self._states: dict = {}
        self._opens = 0
        self._lock = threading.Lock()

    def allow(self, key) -> bool:
        """Admission gate: False ⇒ reject with ``reason="circuit_open"``."""
        with self._lock:
            st = self._states.get(key)
            if st is None or st.state == "closed":
                return True
            if st.state == "open":
                if self._clock() - st.opened_at < self.cooldown:
                    return False
                st.state = "half_open"
                st.probing = True
                return True  # this admission is the probe
            # half_open: one probe at a time
            if st.probing:
                return False
            st.probing = True
            return True

    def record_success(self, key) -> str:
        with self._lock:
            st = self._states.get(key)
            if st is not None:
                st.failures = 0
                st.state = "closed"
                st.probing = False
            return "closed"

    def record_failure(self, key) -> str:
        """Returns the post-failure state ("open" on a fresh trip)."""
        with self._lock:
            st = self._states.setdefault(key, _BreakerState())
            st.failures += 1
            if st.state == "half_open" or st.failures >= self.threshold:
                freshly = st.state != "open"
                st.state = "open"
                st.opened_at = self._clock()
                st.probing = False
                if freshly:
                    self._opens += 1
                return "open"
            return st.state

    def state(self, key) -> str:
        with self._lock:
            st = self._states.get(key)
            return "closed" if st is None else st.state

    def stats(self) -> dict:
        with self._lock:
            return {
                "tracked": len(self._states),
                "open": sum(1 for s in self._states.values()
                            if s.state == "open"),
                "half_open": sum(1 for s in self._states.values()
                                 if s.state == "half_open"),
                "opens": self._opens,
                "threshold": self.threshold,
                "cooldown_s": self.cooldown,
            }


# -- adaptive load shedding -------------------------------------------------


class LoadShedGovernor:
    """Deterministic admission shedding against the rolling latency window.

    A request is shed when (a) it carries a ``deadline_ms`` budget, (b) the
    user-scope latency window holds at least ``min_window`` observations,
    (c) the window's p95 is at or past ``threshold`` × deadline, and (d)
    the request's priority is at or below ``priority_cutoff`` — so under
    overload the lowest-priority tier is shed first and higher-priority
    admissions are never touched.  A pure function of its inputs: the same
    metrics window and request always produce the same verdict.
    """

    def __init__(self, *, threshold: float = 0.9, priority_cutoff: int = 0,
                 min_window: int = 8):
        if not threshold > 0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        if min_window < 1:
            raise ValueError(f"min_window must be ≥ 1, got {min_window}")
        self.threshold = threshold
        self.priority_cutoff = priority_cutoff
        self.min_window = min_window

    def should_shed(self, p95_s: float, deadline_ms: float | None,
                    priority: int, window: int) -> bool:
        if deadline_ms is None or window < self.min_window:
            return False
        if priority > self.priority_cutoff:
            return False
        return p95_s * 1e3 >= self.threshold * deadline_ms


# -- checkpoint / restore ---------------------------------------------------


@dataclasses.dataclass
class QueuedRequest:
    """One admitted-but-untaken request in a checkpoint."""

    rid: int                  # rid in the checkpointed service (old process)
    key: object               # _GroupKey (picklable: Family is pure data)
    item: object              # _Item — canonicalized native operands
    priority: int
    cv_fold: bool = False
    rs_member: bool = False


@dataclasses.dataclass
class InflightSlot:
    """One occupied batch slot at its last chunk boundary: the host-side
    ``(beta, grad, active, L, health)`` carry plus harvest bookkeeping —
    everything a resumed run needs to continue bit-identically."""

    rid: int
    key: object
    item: object
    priority: int
    cv_fold: bool
    beta: np.ndarray          # (P, m) padded carry row
    grad: np.ndarray          # (P, m)
    active: np.ndarray        # (P,) bool
    L: float                  # FISTA Lipschitz carry
    H: int                    # in-graph health word carry
    cursor: int               # next σ index to produce
    steps: list               # harvested per-step tuples so far
    null_dev: float
    prev_dev: float
    health0: int
    early_stop: bool
    solve_s: float


@dataclasses.dataclass
class ServiceCheckpoint:
    """Picklable snapshot of every admitted-but-undelivered request.

    Produced by ``AsyncPathService.checkpoint()`` at a chunk boundary;
    consumed by ``restore()`` on a fresh service (same code + backend
    versions), which re-admits the queued requests and resumes the
    in-flight slots from their carried state.
    """

    queued: list      # [QueuedRequest]
    inflight: list    # [InflightSlot]
    fingerprint: str = dataclasses.field(default_factory=backend_fingerprint)

    def save(self, path: str | os.PathLike) -> None:
        target = os.fspath(path)
        tmp = f"{target}.tmp.{os.getpid()}"
        with open(tmp, "wb") as fh:
            pickle.dump(self, fh)
        os.replace(tmp, target)

    @classmethod
    def load(cls, path: str | os.PathLike) -> "ServiceCheckpoint":
        with open(os.fspath(path), "rb") as fh:
            ckpt = pickle.load(fh)
        if not isinstance(ckpt, cls):
            raise TypeError(f"{path!r} does not hold a ServiceCheckpoint")
        return ckpt

    def __len__(self) -> int:
        return len(self.queued) + len(self.inflight)


def snapshot_queued(batcher, cv_fold_rids, rs_member_rids) -> list:
    """Build :class:`QueuedRequest` records from a batcher snapshot
    (non-destructive; caller holds the service lock)."""
    out = []
    for key, pend in batcher.snapshot():
        assert isinstance(pend, Pending)
        out.append(QueuedRequest(
            rid=pend.rid, key=key, item=pend.item, priority=pend.priority,
            cv_fold=pend.rid in cv_fold_rids,
            rs_member=pend.rid in rs_member_rids))
    return out
