"""Shape bucketing for the path service: canonical execution shapes.

The serving problem: a stream of heterogeneous ``(n, p)`` fit requests must
share compiled device programs, or XLA compilation dominates wall time.  The
policy here rounds every incoming problem up to a power-of-two bucket and
pads with inert zeros, so the whole stream funnels into a handful of
compiled shapes.

Two properties make the padding *canonical* rather than merely tolerable:

* **Inertness.**  Zero columns are inert for every GLM family (a zero
  column never moves the linear predictor, and its gradient entry
  ``x_jᵀr`` is identically zero), and padded λ entries are zero, so padded
  coefficients stay *exactly* 0 through screening, prox and KKT repair.
  Zero **rows** are inert only for OLS (residual ``z − y = 0 − 0``); other
  families keep their exact row count in the bucket key.
* **Bit-identity by construction.**  XLA programs of different shapes are
  not bitwise-interchangeable (gemm tiling changes with shape), so the
  repo's rule is: one bucket → ONE execution shape, shared by the direct
  ``fit_path_batched(pad="bucket")`` entry point and the
  :class:`repro.serve.service.PathService` micro-batcher.  A request padded
  into a bucket by the service returns bit-identical coefficients to an
  unpadded direct call because both run the *same* compiled program on the
  *same* padded operands.  (Batch slots are bitwise member-invariant for
  B ≥ 2 on this backend — verified in ``tests/test_serve.py`` — which is
  why :meth:`ShapeBucketPolicy.batch_bucket` floors the batch at 2.)

This module is dependency-free (NumPy only): :mod:`repro.core.engine`
imports it for the working-set :class:`BucketRegistry`, so it must be
importable before ``repro.core`` finishes initialising.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

from ..obs import MetricsRegistry

__all__ = [
    "next_pow2",
    "BucketRegistry",
    "ShapeBucketPolicy",
    "default_policy",
    "PaddedBatch",
    "pad_batch",
]

_MISSING = object()


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (1 for x ≤ 1)."""
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


class BucketRegistry:
    """Thread-safe, bounded, introspectable ``key → bucket`` memory.

    Promoted out of ``repro.core.engine``'s module-level ``_WS_BUCKETS``
    dict: the grow-on-overflow working-set memory is now shared between the
    batched engine and the path service (both resolve compact widths through
    the same instance, so a service batch that overflows grows the bucket
    the next direct call sees, and vice versa).

    Correctness never depends on the registry — overflow steps fall back to
    the masked solve in-graph — it only stops the next same-shape call from
    paying the fallback again.  Eviction (LRU, ``capacity`` entries) is
    therefore always safe.

    The mapping interface is dict-like (``reg[key]``, ``key in reg``,
    ``reg.pop(key, default)``) so existing callers and tests keep working;
    :meth:`stats` exposes hit/miss/update/eviction counters plus a snapshot
    of the current entries.
    """

    def __init__(self, name: str = "buckets", capacity: int = 512):
        if capacity < 1:
            raise ValueError(f"capacity must be ≥ 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self._data: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        # counters live on the unified registry (repro.obs); stats() reads
        # back through it so this module stays NumPy+stdlib importable
        self.metrics = MetricsRegistry(f"buckets.{name}")

    def get(self, key, default=None):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.metrics.inc("hits")
                return self._data[key]
            self.metrics.inc("misses")
            return default

    def __getitem__(self, key):
        value = self.get(key, _MISSING)
        if value is _MISSING:
            raise KeyError(key)
        return value

    def __setitem__(self, key, value) -> None:
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            self.metrics.inc("updates")
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.metrics.inc("evictions")

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def grow(self, key, value: int, cap: int | None = None) -> bool:
        """Monotonic, idempotent growth: raise ``key``'s bucket to at least
        ``value`` (clipped to ``cap``), never shrink it.

        This is the ONE write path for grow-on-overflow working-set entries:
        plain ``__setitem__`` is last-write-wins, so two concurrent
        overflowing runs (service flush + direct call) could overwrite a
        larger grown bucket with a smaller one and re-pay the fallback the
        larger run already learned to avoid.  ``cap`` bounds the stored
        bucket at the native column count — a bucket wider than ``p`` is
        wasted compaction (the gather would cover every column and the
        compact solve degenerates to the masked one plus gather overhead).
        Returns True iff the stored value changed.
        """
        if cap is not None:
            value = min(int(value), int(cap))
        with self._lock:
            current = self._data.get(key)
            if current is not None and current >= value:
                self._data.move_to_end(key)
                self.metrics.inc("hits")
                return False
            self._data[key] = value
            self._data.move_to_end(key)
            self.metrics.inc("updates")
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.metrics.inc("evictions")
            return True

    def pop(self, key, default=None):
        with self._lock:
            return self._data.pop(key, default)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> dict:
        m = self.metrics
        with self._lock:
            return {
                "name": self.name,
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": m.value("hits"),
                "misses": m.value("misses"),
                "updates": m.value("updates"),
                "evictions": m.value("evictions"),
                "entries": dict(self._data),
            }

    def summary(self) -> dict:
        """:meth:`stats` with JSON-safe entry keys — what
        ``PathService.stats()`` and the ``BENCH_ci.json`` serve rows embed
        so registry growth is visible in the perf trajectory."""
        st = self.stats()
        st["entries"] = {repr(k): v for k, v in st["entries"].items()}
        return st

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"BucketRegistry({self.name!r}, size={len(self)}, "
                f"capacity={self.capacity})")


@dataclasses.dataclass(frozen=True)
class ShapeBucketPolicy:
    """Power-of-two padding policy for incoming ``(n, p)`` problems.

    * columns: always padded to ``max(min_cols, 2^⌈log₂ p⌉)`` — zero columns
      are inert for every family;
    * rows: padded the same way for OLS only (zero rows change the loss for
      logistic/Poisson/multinomial, so those keep their exact ``n``);
    * batch slots: padded to ``max(min_batch, 2^⌈log₂ B⌉)`` with all-zero
      dummy problems (``p_valid = 0``) in unused slots.  The floor of 2
      matters: B = 1 programs lower to a different gemm than B ≥ 2 and are
      not bitwise-interchangeable with them.

    Floors bound the number of distinct compiled shapes a mixed stream can
    produce; raise them if a deployment sees too many tiny odd shapes.
    """

    min_rows: int = 16
    min_cols: int = 32
    min_batch: int = 2

    def shape_bucket(self, n: int, p: int, family_name: str = "ols"):
        """Execution shape ``(N, P)`` for a native ``(n, p)`` problem."""
        P = max(self.min_cols, next_pow2(p))
        N = max(self.min_rows, next_pow2(n)) if family_name == "ols" else n
        return N, P

    def batch_bucket(self, b: int) -> int:
        """Execution batch width for ``b`` live requests."""
        return max(self.min_batch, next_pow2(b))


_DEFAULT_POLICY = ShapeBucketPolicy()


def default_policy() -> ShapeBucketPolicy:
    """The policy shared by ``fit_path_batched(pad="bucket")`` and the
    service default — one policy, one set of execution shapes."""
    return _DEFAULT_POLICY


@dataclasses.dataclass
class PaddedBatch:
    """Stacked, padded device operands for one engine dispatch."""

    Xs: np.ndarray        # (B_slots, N, P)
    ys: np.ndarray        # (B_slots, N[, ...])
    lam: np.ndarray       # (B_slots, P·m) per-member λ, zero-padded tail
    sigmas: np.ndarray    # (B_slots, L); dummy slots hold a flat grid of 1s
    p_valid: np.ndarray   # (B_slots,) int32 native p per slot (0 = dummy)
    n_batch: int          # leading slots holding real problems

    @property
    def shape(self):
        return self.Xs.shape


def pad_batch(problems, *, n_rows: int, n_cols: int, n_slots: int,
              n_classes: int = 1) -> PaddedBatch:
    """Pad native problems into one ``(n_slots, n_rows, n_cols)`` batch.

    ``problems`` is a sequence of ``(X, y, lam, sigmas)`` tuples at native
    shapes; every ``n_i ≤ n_rows``, ``p_i ≤ n_cols``, and all σ grids share
    one length.  X/λ are padded with zeros (inert — see the module
    docstring), unused batch slots hold all-zero dummy problems with
    ``p_valid = 0`` so screening keeps nothing and their solves freeze
    immediately.  The caller promises zero-row inertness when ``n_i <
    n_rows`` (i.e. rows are only padded for OLS).
    """
    if not problems:
        raise ValueError("pad_batch needs at least one problem")
    if len(problems) > n_slots:
        raise ValueError(f"{len(problems)} problems exceed {n_slots} slots")
    m = n_classes
    L = len(problems[0][3])
    X0, y0 = problems[0][0], problems[0][1]
    dtype = X0.dtype
    Xs = np.zeros((n_slots, n_rows, n_cols), dtype)
    ys = np.zeros((n_slots,) + (n_rows,) + y0.shape[1:], y0.dtype)
    lam = np.zeros((n_slots, n_cols * m), dtype)
    sigmas = np.ones((n_slots, L), dtype)
    p_valid = np.zeros((n_slots,), np.int32)
    for i, (X, y, lam_i, sig_i) in enumerate(problems):
        n_i, p_i = X.shape
        if n_i > n_rows or p_i > n_cols:
            raise ValueError(
                f"problem {i} shape {(n_i, p_i)} exceeds bucket "
                f"{(n_rows, n_cols)}")
        if len(sig_i) != L:
            raise ValueError("all σ grids in a batch must share one length")
        Xs[i, :n_i, :p_i] = X
        ys[i, :n_i] = y
        lam[i, : p_i * m] = np.asarray(lam_i)[: p_i * m]
        sigmas[i] = sig_i
        p_valid[i] = p_i
    return PaddedBatch(Xs=Xs, ys=ys, lam=lam, sigmas=sigmas,
                       p_valid=p_valid, n_batch=len(problems))
