"""`PathService` — a synchronous, shape-bucketed SLOPE path service.

The front door for a stream of heterogeneous fit requests::

    svc = PathService(max_batch=8, max_delay=0.02)
    rid = svc.submit(X, y, family=ols, lam_kind="bh", lam_q=0.1)
    ...                       # more submits; groups flush as they fill
    svc.flush()               # or wait for deadlines
    resp = svc.poll(rid)      # PathResponse with native-shape betas

or, declaratively, the same ``(Problem, PathSpec, SolverPolicy)`` triple
the direct :func:`repro.api.slope_path` front door takes::

    rid = svc.submit(problem=Problem(X, y, family=ols),
                     path=PathSpec(lam=LambdaSpec("bh", q=0.1)),
                     policy=SolverPolicy())   # planned like a direct call

Requests are padded into power-of-two buckets (:mod:`repro.serve.buckets`),
micro-batched per compiled-program group (:mod:`repro.serve.batcher`), and
executed through an AOT compiled-program cache (:mod:`repro.serve.cache`).
Per-request results are unpadded back to native shapes before they are
returned, with KKT status and queue/solve/occupancy telemetry attached.

Guarantees and their boundaries:

* A served request returns **bit-identical** coefficients to a direct
  ``fit_path_batched(X[None], y[None], ..., pad="bucket")`` call: both
  resolve execution shapes through the same policy/registry and batch
  slots are bitwise member-invariant (B ≥ 2).  Exception: under the
  *compact* backend, a co-batched neighbour overflowing the working-set
  bucket sends the whole batch to the masked fallback for that repair
  round — results then agree with the direct call only to solver
  tolerance, and the response flags it in ``compact_fallback``.
* The service is synchronous: deadlines are enforced on the next
  ``submit``/``poll``/``flush`` call, bounding queueing latency under
  load (there is no timer thread to wake an idle queue).

CV requests (``cv_folds=K``) expand into K same-shape fold fits that ride
the same queues as plain fits — they batch with anything else in their
bucket — and aggregate into a :class:`CvResponse` (deviance-based min and
1-SE selection) once every fold has been served.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import numpy as np

from ..api.plan import plan_execution
from ..api.specs import (
    PathSpec,
    Problem,
    SolverPolicy,
    ValidationError,
    apply_weights,
    as_lambda_spec,
    check_weights,
    find_nonfinite,
    shared_canonicalizer,
)
from ..core.engine import (
    CompactStats,
    EnginePath,
    _ws_bucket,
    _WS_BUCKETS,
    cv_fold_indices,
    cv_select,
    cv_val_deviance,
    grow_ws_bucket,
    null_sigma_grid,
    resolve_ws_tiers,
    second_tier_width,
)
from ..core.solver import DEFAULT_WS_TIERS
from ..core.losses import Family, ols
from ..obs import MetricsRegistry, Trace
from ..obs.profile import annotate
from ..resample.metrics import (
    RESAMPLE_METRICS,
    resample_stats,
    track_in_flight,
)
from ..resample.plans import ResamplePlan
from .batcher import (
    LambdaCanonicalizer,
    MicroBatcher,
    QueueFull,
    Rejection,
    RejectionError,
)
from .buckets import ShapeBucketPolicy, default_policy, pad_batch
from .cache import ProgramCache, ProgramSpec
from .durable import (
    CircuitBreaker,
    LoadShedGovernor,
    WatchdogTimeout,
    run_with_watchdog,
)
from .faults import FaultPlan, InjectedFault, NO_FAULTS

__all__ = ["PathService", "PathResponse", "CvResponse", "ResampleResponse"]


@dataclasses.dataclass
class _Item:
    """One admitted request, λ/σ already canonicalized, at native shape."""

    X: np.ndarray
    y: np.ndarray
    lam: np.ndarray        # native (p·m,)
    sigmas: np.ndarray     # native (L,)
    family: Family
    working_set: int | str | None
    weights: np.ndarray | None = None  # (n,) replicate row weights — set
    #   only on resample members; every item in a replicate group shares
    #   the SAME X object, so the flush pads the design once


@dataclasses.dataclass(frozen=True)
class _GroupKey:
    """Everything that must match for two requests to share one compiled
    program (and hence one batch slot assignment)."""

    family: Family
    n_rows: int
    n_cols: int
    path_length: int
    screening: str
    solver_tol: float
    max_iter: int
    kkt_tol: float
    max_refits: int
    working_set: int | str | None   # None | resolved pow2 int | "auto"
    ws_tiers: int                   # canonical tier policy (1 | 2; "auto"
    #   normalizes to 2 at submit, masked requests to 1)
    dtype: str
    y_dtype: str
    replicates: int = 0             # resample-request token (0 = plain
    #   fit): members of ONE ResamplePlan share a token — and hence one
    #   group, one compiled weight-fused program, and ONE padded design —
    #   and never co-batch with plain fits or other resample requests


@dataclasses.dataclass
class PathResponse:
    """One served path fit, unpadded to the request's native shape."""

    rid: int
    betas: np.ndarray            # (L, p) or (L, p, m)
    sigmas: np.ndarray           # (L,)
    lam: np.ndarray              # (p·m,)
    n_samples: int
    n_active: np.ndarray         # (L,)
    n_screened: np.ndarray
    n_violations: np.ndarray
    refits: np.ndarray
    solver_iters: np.ndarray
    deviance: np.ndarray
    kkt_unrepaired: np.ndarray   # (L,) bool per path step
    kkt_ok: bool                 # no step hit the repair cap unclean
    working_set: int | None
    working_set_top: int | None  # second compact tier (None: single tier)
    ws_size: np.ndarray | None
    ws_tier: np.ndarray | None   # (L,) serving tier per step (0 = fallback)
    compact_fallback: np.ndarray | None
    queue_s: float               # admission → flush
    solve_s: float               # batch device wall (shared by the batch)
    batch_size: int              # real requests in the flushed batch
    batch_occupancy: float       # real requests / executed slots
    padding_ratio: float         # padded n·p over native n·p
    cache_hit: bool              # compiled program was already resident
    health: np.ndarray | None = None  # (L,) int32 per-step health word
    #   (sticky; see repro.core.engine.PathHealth — None on pre-PR-7 paths)
    trace: Trace | None = None   # opt-in span timeline (service tracing=True)

    @property
    def total_violations(self) -> int:
        return int(self.n_violations.sum())

    @property
    def quarantined(self) -> bool:
        """True when the engine quarantined this member in-graph (the
        coefficients past the first sick step are zeroed placeholders)."""
        return self.health is not None and bool(np.asarray(self.health)[-1])

    @property
    def health_causes(self) -> tuple[str, ...]:
        from ..core.engine import health_causes

        if self.health is None:
            return ()
        return health_causes(int(np.asarray(self.health)[-1]))

    def path_result(self, *, early_stop: bool = True):
        """The same :class:`repro.core.path.PathResult` contract
        ``fit_path`` returns, early stopping applied post-hoc."""
        from ..core.path import engine_to_path_result

        betas = self.betas
        if betas.ndim == 2:
            betas = betas[:, :, None]
        ep = EnginePath(
            betas=betas, n_active=self.n_active, n_screened=self.n_screened,
            n_violations=self.n_violations, refits=self.refits,
            solver_iters=self.solver_iters, deviance=self.deviance,
            kkt_unrepaired=self.kkt_unrepaired,
            health=(self.health if self.health is not None
                    else np.zeros(len(self.sigmas), np.int32)),
        )
        return engine_to_path_result(ep, self.sigmas, self.lam, self.solve_s,
                                     early_stop=early_stop, n=self.n_samples)


@dataclasses.dataclass
class CvResponse:
    """Aggregated K-fold CV request (fold fits served like plain fits)."""

    rid: int
    sigmas: np.ndarray             # (L,) shared grid
    lam: np.ndarray
    val_deviance: np.ndarray       # (K, L)
    mean_val_deviance: np.ndarray  # (L,)
    se_val_deviance: np.ndarray    # (L,)
    best_index: int                # per the request's selection rule
    best_sigma: float
    best_index_min: int
    best_index_1se: int
    selection: str
    fold_responses: list[PathResponse]


@dataclasses.dataclass
class _CvPending:
    fold_rids: list[int]
    val_indices: list[np.ndarray]
    X: np.ndarray
    y: np.ndarray
    lam: np.ndarray
    sigmas: np.ndarray
    family: Family
    selection: str


@dataclasses.dataclass
class ResampleResponse:
    """Aggregated B-replicate resample request (members served like plain
    fits, chunked through the weight-fused replicate program)."""

    rid: int
    betas: np.ndarray              # (B, L, p) or (B, L, p, m)
    sigmas: np.ndarray             # (L,) shared grid
    lam: np.ndarray
    weights: np.ndarray            # (B, n) per-member row weights
    resample: ResamplePlan
    member_responses: list[PathResponse]

    @property
    def n_replicates(self) -> int:
        return self.betas.shape[0]

    def selection_frequencies(self, *, tol: float = 0.0) -> np.ndarray:
        """Per-(grid-point, predictor) selection frequencies over the
        replicates — the stability-selection statistic."""
        from ..resample.select import selection_frequencies

        betas = self.betas
        if betas.ndim == 3:
            betas = betas[..., None]
        return selection_frequencies(betas, tol=tol)


@dataclasses.dataclass
class _RsPending:
    member_rids: list[int]
    weights: np.ndarray            # (B, n)
    resample: ResamplePlan
    sigmas: np.ndarray
    lam: np.ndarray


class PathService:
    """Shape-bucketed micro-batching front-end over the device path engine.

    ``max_batch`` requests per group trigger a fill flush; a lone request
    flushes once ``max_delay`` seconds old (checked on the next service
    call).  ``max_batch`` is padded up to the policy's batch bucket, so the
    executed program always has the same slot count — unused slots carry
    inert dummy problems.
    """

    def __init__(self, *, max_batch: int = 8, max_delay: float = 0.02,
                 max_queue: int | None = None,
                 policy: ShapeBucketPolicy | None = None,
                 cache: ProgramCache | None = None,
                 canonicalizer: LambdaCanonicalizer | None = None,
                 clock=time.perf_counter,
                 faults: FaultPlan | None = None,
                 tracing: bool = False,
                 store=None,
                 solve_timeout_ms: float | None = None,
                 breaker_threshold: int = 5,
                 breaker_cooldown: float = 5.0,
                 shed_threshold: float = 0.9,
                 shed_priority: int = 0,
                 shed_window: int = 8):
        # explicit None checks: the cache and canonicalizer define __len__,
        # so a freshly shared (still empty) instance is falsy.  The default
        # canonicalizer is the process-wide one repro.api.LambdaSpec
        # resolves through, so named sequences are generated once and
        # shared byte-for-byte between direct and served execution.
        self.policy = policy if policy is not None else default_policy()
        if cache is not None and store is not None:
            if cache.store is not None and cache.store is not store:
                raise ValueError("cache already carries a different durable "
                                 "store; pass one or the other")
            cache.store = store
        self.cache = (cache if cache is not None
                      else ProgramCache(store=store))
        self.store = self.cache.store
        self.canonicalizer = (canonicalizer if canonicalizer is not None
                              else shared_canonicalizer())
        if solve_timeout_ms is not None and not solve_timeout_ms > 0:
            raise ValueError(
                f"solve_timeout_ms must be > 0, got {solve_timeout_ms!r}")
        # watchdog budget on device dispatch: service-wide default, further
        # tightened per request via submit(solve_timeout_ms=...) /
        # SolverPolicy.solve_timeout_ms (the batch runs under the tightest
        # budget of its members)
        self.solve_timeout_ms = solve_timeout_ms
        self._solve_timeouts: dict[int, float] = {}   # rid → seconds
        self._breaker = CircuitBreaker(threshold=breaker_threshold,
                                       cooldown=breaker_cooldown,
                                       clock=clock)
        self._governor = LoadShedGovernor(threshold=shed_threshold,
                                          priority_cutoff=shed_priority,
                                          min_window=shed_window)
        self.slots = self.policy.batch_bucket(max_batch)
        self._batcher = MicroBatcher(max_batch=max_batch, max_delay=max_delay,
                                     max_queue=max_queue)
        self._clock = clock
        # fault injection (tests/chaos benches only; inert by default)
        self._faults = faults if faults is not None else NO_FAULTS
        self._lock = threading.RLock()
        self._next_rid = 0
        # finished-but-unclaimed responses are bounded: clients that never
        # poll must not pin betas arrays forever (oldest evicted, counted)
        self.max_unclaimed = 4096
        self._done: OrderedDict[int, PathResponse] = OrderedDict()
        self._cv: dict[int, _CvPending] = {}
        self._cv_hold: OrderedDict[int, PathResponse] = OrderedDict()
        self._cv_fold_rids: set[int] = set()
        self._rs: dict[int, _RsPending] = {}
        self._rs_hold: OrderedDict[int, PathResponse] = OrderedDict()
        self._rs_member_rids: set[int] = set()
        # every counter/distribution this service reports lives in ONE
        # thread-safe registry; stats() is a read-through view over it, so
        # the dict schema and the incremented numbers cannot drift.
        # Counters: submitted, completed, batches, rejected,
        # validation_rejected, results_evicted, flush{trigger=...},
        # plans{plan=...}, and kkt_violations — the paper's "simple check
        # of the optimality conditions", made observable: strong-rule
        # violations caught by the KKT repair loop.  Histograms (bounded
        # windows — one eviction policy for what used to be ad-hoc deques):
        # batch_occupancy, padding_ratio, and latency_s split by
        # scope=user/internal, because a caller's SLO is measured on what
        # the caller sees and CV fold fits would skew the percentiles
        # toward the service's own internal work.
        self.metrics = MetricsRegistry("serve")
        # opt-in request tracing: when enabled, every admitted request
        # carries a Trace whose cursor-built spans cover admit → deliver
        # with no gaps (PathResponse.trace).  Off by default — every
        # touch-point is guarded by `self._traces` truthiness, so the
        # disabled cost is one falsy dict check.
        self.tracing = bool(tracing)
        self._traces: dict[int, Trace] = {}
        # boot-time warmup: replay the durable store's manifest so every
        # program the previous process compiled for live traffic is
        # resident (loaded from the store, not rebuilt) before the first
        # request arrives
        if self.store is not None:
            self.store.replay(self.cache)

    # -- admission ----------------------------------------------------------

    def submit(self, X=None, y=None, *, family: Family = ols,
               lam: np.ndarray | None = None,
               lam_kind: str = "bh", lam_q: float = 0.1,
               sigmas: np.ndarray | None = None,
               path_length: int = 100, sigma_ratio: float | None = None,
               screening: str = "strong", solver_tol: float = 1e-8,
               max_iter: int = 5000, kkt_tol: float = 1e-4,
               max_refits: int = 32,
               working_set: int | str | None = None,
               ws_tiers: int | str = DEFAULT_WS_TIERS,
               cv_folds: int | None = None, stratify="auto",
               selection: str = "min",
               deadline_ms: float | None = None, priority: int = 0,
               solve_timeout_ms: float | None = None,
               validate: str = "strict",
               _cv_fold: bool = False,
               problem: Problem | None = None,
               path: PathSpec | None = None,
               policy: SolverPolicy | None = None,
               plan=None) -> int:
        """Queue one fit (or, with ``cv_folds``, one K-fold CV) request.

        Returns a request id for :meth:`poll`.  λ can be an explicit array
        (length p·m) or a named sequence (``lam_kind``/``lam_q``) resolved
        through the canonicalizer; the σ grid defaults to the paper's
        recipe evaluated on the *native* (unpadded) problem, so served
        results match direct ``fit_path_batched(pad="bucket")`` calls
        bit-for-bit.

        Spec form: ``submit(problem=Problem(...), path=PathSpec(...),
        policy=SolverPolicy(...))`` (or positionally, ``submit(Problem(...),
        PathSpec(...))``) — a request is then literally the serialized
        ``(Problem, PathSpec, SolverPolicy)`` triple the direct
        :func:`repro.api.slope_path` front door takes, and backend choices
        resolve through the same :func:`repro.api.plan.plan_execution`, so
        plan decisions are identical between direct and served execution.

        ``deadline_ms`` is the request's end-to-end latency budget: it
        tightens the flush deadline (queueing gets at most half the budget)
        and is the SLO the serving telemetry measures against.
        ``priority`` (higher first, default 0) orders requests within a
        group's queue; equal priorities keep FIFO order.  Both are advisory
        for this synchronous service — deadlines still need a service call
        to act on; the async front-end
        (:class:`repro.serve.AsyncPathService`) enforces them on a timer.
        """
        if problem is None and isinstance(X, Problem):
            problem, X = X, None
            if path is None and isinstance(y, PathSpec):
                path, y = y, None
        if problem is not None:
            if X is not None or y is not None:
                raise ValueError("pass either (X, y, ...) kwargs or the "
                                 "problem=/path=/policy= spec triple, not "
                                 "both")
            return self._submit_spec(problem, path, policy, plan=plan,
                                     _cv_fold=_cv_fold)
        if deadline_ms is not None and not deadline_ms > 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms!r}")
        if solve_timeout_ms is not None and not solve_timeout_ms > 0:
            raise ValueError(
                f"solve_timeout_ms must be > 0, got {solve_timeout_ms!r}")
        if isinstance(priority, bool) or not isinstance(priority, int):
            raise ValueError(f"priority must be an int, got {priority!r}")
        X = np.asarray(X)
        y = np.asarray(y)
        if X.ndim != 2 or y.shape[0] != X.shape[0]:
            raise ValueError(f"X must be (n, p) with matching y; got "
                             f"{X.shape} / {y.shape}")
        n, p = X.shape
        m = family.n_classes
        if lam is None:
            lam = self.canonicalizer.get(lam_kind, lam_q, p * m, n=n)
        lam = np.asarray(lam)
        if lam.shape != (p * m,):
            raise ValueError(f"lam must have p·m = {p * m} entries, got "
                             f"{lam.shape}")
        if ws_tiers not in ("auto", 1, 2) or isinstance(ws_tiers, bool):
            raise ValueError(
                f"ws_tiers must be 'auto', 1 or 2, got {ws_tiers!r}")
        if validate not in ("strict", "quarantine", "off"):
            raise ValueError(f"validate must be 'strict', 'quarantine' or "
                             f"'off', got {validate!r}")
        if validate != "off":
            issues = find_nonfinite(X=X, y=y, lam=lam, sigmas=sigmas)
            if issues and validate == "strict":
                # reject host-side before any padding/compile/device work;
                # "quarantine" admits instead and the engine's in-graph
                # health word flags the member (PathResponse.health)
                self.metrics.inc("validation_rejected")
                raise ValidationError(issues)
        # canonical tier knob for the group key: the knob is irrelevant to
        # masked programs, "auto" IS 2 under the shared recipe, and an
        # explicit W whose 2W would span the bucket degenerates to single
        # tier for every knob value — two requests that compile the same
        # program must share a micro-batch.  ("auto" working sets resolve W
        # at flush time, so their degenerate case cannot be folded here.)
        if working_set is None or ws_tiers == 1:
            ws_tiers = 1
        else:
            ws_tiers = 2
        if cv_folds is not None:
            return self._submit_cv(
                X, y, lam, family, n_folds=cv_folds, stratify=stratify,
                selection=selection, sigmas=sigmas, path_length=path_length,
                sigma_ratio=sigma_ratio, screening=screening,
                solver_tol=solver_tol, max_iter=max_iter, kkt_tol=kkt_tol,
                max_refits=max_refits, working_set=working_set,
                ws_tiers=ws_tiers, deadline_ms=deadline_ms,
                priority=priority, solve_timeout_ms=solve_timeout_ms,
                validate=validate)
        if sigmas is None:
            sigmas = null_sigma_grid(X, y, lam, family,
                                     path_length=path_length,
                                     sigma_ratio=sigma_ratio)
        sigmas = np.asarray(sigmas)
        N, P = self.policy.shape_bucket(n, p, family.name)
        ws = working_set
        if isinstance(ws, bool) or not (ws is None or ws == "auto"
                                        or isinstance(ws, int)):
            raise ValueError(f"working_set must be None, an int or 'auto', "
                             f"got {ws!r}")
        if isinstance(ws, int):
            # resolve through the engine's own rule (validation + pow2 cap)
            # so the service can never diverge from the direct path
            ws = _ws_bucket(ws, N, P, (N, P, m, family.name, screening))
            if ws_tiers == 2 and second_tier_width(ws, 2, P) is None:
                ws_tiers = 1  # 2W spans the bucket: single tier either way
        key = _GroupKey(
            family=family, n_rows=N, n_cols=P, path_length=len(sigmas),
            screening=screening, solver_tol=solver_tol, max_iter=max_iter,
            kkt_tol=kkt_tol, max_refits=max_refits, working_set=ws,
            ws_tiers=ws_tiers, dtype=X.dtype.name, y_dtype=y.dtype.name)
        item = _Item(X=X, y=y, lam=lam, sigmas=sigmas, family=family,
                     working_set=ws)
        return self._admit(key, item, deadline_ms=deadline_ms,
                           priority=priority,
                           solve_timeout_ms=solve_timeout_ms,
                           _cv_fold=_cv_fold)

    def _flush_by(self, now: float, deadline_ms: float | None) -> float:
        """Flush deadline for one admission: ``max_delay`` of queueing, or —
        when the request carries a latency budget — at most half the budget,
        leaving the other half for padding/solve/unpad."""
        if deadline_ms is None:
            return now + self._batcher.max_delay
        return now + min(self._batcher.max_delay, deadline_ms / 2e3)

    def _admission_control(self, key: _GroupKey, rid: int, *,
                           priority: int,
                           deadline_ms: float | None) -> Rejection | None:
        """Pre-queue gates (caller holds the lock): the per-program circuit
        breaker first, then adaptive load shedding.  Returns the
        :class:`Rejection` verdict (the request is NOT queued) or None.

        Both verdicts are deterministic: the breaker's state is a pure
        function of the recorded compile/execute outcomes and the clock,
        and the shed decision a pure function of the latency window — the
        ``overload`` fault site forces the shed verdict for chaos tests.
        """
        if not self._breaker.allow(key):
            self.metrics.inc("rejected")
            self.metrics.inc("breaker_rejected")
            return Rejection(
                rid=rid, reason="circuit_open",
                queued=self._batcher.pending(), max_queue=None)
        shed = False
        if self._faults.active():
            try:
                self._faults.fire("overload", rids=(rid,))
            except InjectedFault:
                shed = True
        if not shed and deadline_ms is not None:
            lat = self.metrics.histogram("latency_s", scope="user")
            shed = self._governor.should_shed(
                lat.percentile(95), deadline_ms, priority, lat.retained)
        if shed:
            self.metrics.inc("rejected")
            self.metrics.inc("shed")
            return Rejection(
                rid=rid, reason="shed",
                queued=self._batcher.pending(), max_queue=None)
        return None

    def _admit(self, key: _GroupKey, item: _Item, *,
               deadline_ms: float | None = None, priority: int = 0,
               solve_timeout_ms: float | None = None,
               _cv_fold: bool = False, _rs_member: bool = False) -> int:
        """Queue one canonicalized request; the async subclass overrides
        this to return a future and to reject-with-status at capacity.

        At queue capacity — or on an admission-control verdict (circuit
        breaker open, load shed) — raises :class:`RejectionError`, a
        :class:`QueueFull` subclass carrying the structured
        :class:`Rejection` (``err.rejection``)."""
        t_in = self._clock()
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.metrics.inc("submitted")
            verdict = self._admission_control(
                key, rid, priority=priority, deadline_ms=deadline_ms)
            if verdict is not None:
                raise RejectionError(verdict)
            if _cv_fold:
                # register BEFORE admission: admitting can flush this very
                # group (fill, or a deadline on a neighbour) synchronously,
                # and the flush routes responses by this membership
                self._cv_fold_rids.add(rid)
            if _rs_member:
                self._rs_member_rids.add(rid)  # same ordering constraint
            if solve_timeout_ms is not None:
                self._solve_timeouts[rid] = solve_timeout_ms / 1e3
            item = self._maybe_corrupt(rid, item)
            now = self._clock()
            try:
                filled = self._batcher.admit(
                    key, rid, item, now, priority=priority,
                    deadline=self._flush_by(now, deadline_ms))
            except QueueFull as e:
                self.metrics.inc("rejected")
                self._cv_fold_rids.discard(rid)
                self._rs_member_rids.discard(rid)
                self._solve_timeouts.pop(rid, None)
                raise RejectionError(Rejection(
                    rid=rid, reason=str(e), queued=self._batcher.pending(),
                    max_queue=self._batcher.max_queue)) from None
            self._start_trace(rid, t_in)
            if filled:
                self._flush_group(key, trigger="fill")
            self._flush_due(now)
            return rid

    def _start_trace(self, rid: int, t_in: float) -> None:
        """Open a request trace (tracing opt-in only): the "admit" span
        covers rid assignment, fault hooks and queue insertion.  Must run
        BEFORE any flush this admission triggers — a fill flush delivers
        (and closes) the trace synchronously.  Caller holds the lock."""
        if self.tracing:
            tr = Trace(rid=rid, t0=t_in)
            tr.mark("admit", self._clock())
            self._traces[rid] = tr

    def _maybe_corrupt(self, rid: int, item: _Item) -> _Item:
        """Fault-injection "admit" site: a ``nan`` spec poisons this
        request's design matrix (chaos tests only; inert in production)."""
        if not self._faults.active():
            return item
        Xf = self._faults.corrupt("admit", rid, item.X)
        if Xf is item.X:
            return item
        return dataclasses.replace(item, X=Xf)

    def _submit_spec(self, problem: Problem, path: PathSpec | None,
                     policy: SolverPolicy | None, *, plan=None,
                     _cv_fold: bool = False) -> int:
        """Admit a declarative ``(Problem, PathSpec, SolverPolicy)`` triple.

        The triple is planned through the SAME :func:`plan_execution` the
        direct front door uses (with the serving context made explicit), so
        masked-vs-compact and working-set choices can never diverge between
        ``slope_path(policy=SolverPolicy(backend="serve"))`` and a direct
        ``submit``.  ``plan`` skips re-planning when the caller (e.g.
        ``slope_path``) already resolved the triple.
        """
        path = path if path is not None else PathSpec()
        policy = policy if policy is not None else SolverPolicy()
        if policy.backend == "host":
            raise ValueError(
                "PathService cannot execute host plans; call "
                "repro.api.slope_path directly for the gathered host driver")
        if problem.batched:
            raise ValueError("PathService serves single (n, p) problems; "
                             "submit batch members individually (the "
                             "service micro-batches them)")
        if plan is None:
            plan_policy = (dataclasses.replace(policy, backend="serve")
                           if policy.backend == "auto" else policy)
            plan = plan_execution(problem, path, plan_policy)
        pln = plan
        if path.resample is not None:
            return self._submit_resample(problem, path, policy, pln)
        ws = None
        if pln.mode == "compact":
            ws = policy.working_set
            ws = "auto" if ws is None or ws == "auto" else ws
        Xw, yw = apply_weights(problem)
        m = problem.family.n_classes
        lam = as_lambda_spec(path.lam).resolve(
            problem.p * m, n=problem.n, canonicalizer=self.canonicalizer)
        return self.submit(
            Xw, yw, family=problem.family, lam=lam, sigmas=path.sigmas,
            path_length=path.path_length, sigma_ratio=path.sigma_ratio,
            screening=policy.screening, solver_tol=policy.solver_tol,
            max_iter=policy.max_iter, kkt_tol=policy.kkt_tol,
            max_refits=policy.max_refits, working_set=ws,
            ws_tiers=policy.ws_tiers,
            cv_folds=path.cv_folds, stratify=path.stratify,
            selection=path.selection, deadline_ms=policy.deadline_ms,
            priority=policy.priority,
            solve_timeout_ms=policy.solve_timeout_ms,
            validate=policy.validate,
            _cv_fold=_cv_fold)

    def _submit_cv(self, X, y, lam, family, *, n_folds, stratify, selection,
                   sigmas, path_length, sigma_ratio, screening, solver_tol,
                   max_iter, kkt_tol, max_refits, working_set,
                   ws_tiers=DEFAULT_WS_TIERS, deadline_ms=None,
                   priority=0, solve_timeout_ms=None,
                   validate="strict") -> int:
        if sigmas is None:
            sigmas = null_sigma_grid(X, y, lam, family,
                                     path_length=path_length,
                                     sigma_ratio=sigma_ratio)
        sigmas = np.asarray(sigmas)
        trains, vals = cv_fold_indices(y, n_folds, family=family,
                                       stratify=stratify)
        # fold fits inherit the CV request's budget and priority: the CV
        # answer is only as timely as its slowest fold
        fold_rids = [
            self.submit(X[tr], y[tr], family=family, lam=lam, sigmas=sigmas,
                        screening=screening, solver_tol=solver_tol,
                        max_iter=max_iter, kkt_tol=kkt_tol,
                        max_refits=max_refits, working_set=working_set,
                        ws_tiers=ws_tiers, deadline_ms=deadline_ms,
                        priority=priority,
                        solve_timeout_ms=solve_timeout_ms,
                        validate=validate, _cv_fold=True)
            for tr in trains
        ]
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.metrics.inc("submitted")
            self._cv[rid] = _CvPending(
                fold_rids=fold_rids, val_indices=vals, X=X, y=y, lam=lam,
                sigmas=sigmas, family=family, selection=selection)
            return rid

    def _submit_resample(self, problem: Problem, path: PathSpec,
                         policy: SolverPolicy, pln):
        """Fan a :class:`~repro.resample.ResamplePlan` out into B replicate
        members riding the normal shape-bucketed queues.

        Every member carries its (n,) row-weight vector and a reference to
        the SAME native design; the group key's ``replicates`` token keeps
        one request's members together, so each flushed chunk runs the
        weight-fused replicate program against ONE padded X (operands stay
        O(n·p + slots·n) per chunk — no (B, n, p) stack, no per-member X
        copies).  Chunks of up to ``slots`` members form by the same fill /
        deadline rules as plain fits — continuous chunked batching over the
        replicate axis.  Members aggregate like CV folds: collection (sync
        ``poll`` / async future) returns a :class:`ResampleResponse` once
        every member has been served.
        """
        rs = path.resample
        X = np.asarray(problem.X)
        y = np.asarray(problem.y)
        family = problem.family
        n, p = X.shape
        m = family.n_classes
        lam = as_lambda_spec(path.lam).resolve(
            p * m, n=n, canonicalizer=self.canonicalizer)
        lam = np.asarray(lam)
        if policy.validate == "strict":
            issues = find_nonfinite(X=X, y=y, lam=lam, sigmas=path.sigmas)
            if issues:
                self.metrics.inc("validation_rejected")
                raise ValidationError(issues)
        sigmas = path.sigmas
        if sigmas is None:
            # shared grid from the ORIGINAL problem — replicates compare
            # like with like, exactly as CV folds share the full-data grid
            sigmas = null_sigma_grid(X, y, lam, family,
                                     path_length=path.path_length,
                                     sigma_ratio=path.sigma_ratio)
        sigmas = np.asarray(sigmas)
        W = np.asarray(rs.row_weights(n, dtype=X.dtype))
        if problem.weights is not None:
            W = W * check_weights(problem)[None, :]
        y_members = (np.asarray(rs.permuted_targets(y))
                     if rs.kind == "permutation" else None)

        ws = None
        ws_tiers = 1
        if pln.mode == "compact":
            ws = policy.working_set
            ws = "auto" if ws is None or ws == "auto" else ws
            ws_tiers = 1 if policy.ws_tiers == 1 else 2
        N, P = self.policy.shape_bucket(n, p, family.name)
        if isinstance(ws, int):
            ws = _ws_bucket(ws, N, P, (N, P, m, family.name, policy.screening))
            if ws_tiers == 2 and second_tier_width(ws, 2, P) is None:
                ws_tiers = 1
        with self._lock:
            parent_rid = self._next_rid
            self._next_rid += 1
            self.metrics.inc("submitted")
        key = _GroupKey(
            family=family, n_rows=N, n_cols=P, path_length=len(sigmas),
            screening=policy.screening, solver_tol=policy.solver_tol,
            max_iter=policy.max_iter, kkt_tol=policy.kkt_tol,
            max_refits=policy.max_refits, working_set=ws, ws_tiers=ws_tiers,
            dtype=X.dtype.name, y_dtype=y.dtype.name,
            replicates=parent_rid + 1)
        handles = [
            self._admit(
                key,
                _Item(X=X, y=(y if y_members is None else y_members[b]),
                      lam=lam, sigmas=sigmas, family=family, working_set=ws,
                      weights=W[b]),
                deadline_ms=policy.deadline_ms, priority=policy.priority,
                solve_timeout_ms=policy.solve_timeout_ms, _rs_member=True)
            for b in range(rs.n_replicates)
        ]
        RESAMPLE_METRICS.inc("replicates", rs.n_replicates, kind=rs.kind,
                             backend="serve")
        track_in_flight(rs.kind, rs.n_replicates)
        return self._register_resample(parent_rid, handles, W, rs, sigmas,
                                       lam)

    def _register_resample(self, rid: int, member_rids: list[int],
                           W: np.ndarray, rs: ResamplePlan,
                           sigmas: np.ndarray, lam: np.ndarray) -> int:
        """Record the pending aggregation (``poll`` collects it); the async
        subclass overrides this to aggregate member futures instead."""
        with self._lock:
            self._rs[rid] = _RsPending(member_rids=member_rids, weights=W,
                                       resample=rs, sigmas=sigmas, lam=lam)
        return rid

    # -- flushing -----------------------------------------------------------

    def flush(self) -> int:
        """Force-flush every pending group; returns batches executed."""
        with self._lock:
            count = 0
            for key in self._batcher.groups():
                while self._flush_group(key, trigger="forced"):
                    count += 1
            return count

    def _flush_due(self, now: float) -> None:
        for key in self._batcher.due(now):
            self._flush_group(key, trigger="deadline")

    def _flush_group(self, key: _GroupKey, *, trigger: str) -> bool:
        batch = self._batcher.take(key)
        if not batch:
            return False
        self._note_taken(batch)
        self._execute_batch(key, batch, trigger=trigger)
        return True

    def _note_taken(self, batch) -> None:
        """In-flight cohort hook: the async subclass records the requests a
        serve implicates, so a worker failure is scoped to exactly that
        cohort.  Base (synchronous) service: no-op — exceptions propagate
        to the submitting caller directly."""

    def _pad_replicate(self, batch, N: int, P: int, m: int):
        """Padded operands for one weight-fused replicate chunk.

        Returns ``((X, ys, lam, sigmas, weights, p_valid), n_batch)`` in the
        replicate program's call convention: ONE shared padded (N, P)
        design, (slots, N) member responses and row weights (zero rows on
        padding and on empty slots — exactly inert under the engine's
        zero-weight guard), shared λ/σ, scalar ``p_valid``.
        """
        item0 = batch[0].item
        X0 = item0.X
        n, p = X0.shape
        dtype = X0.dtype
        Xp = np.zeros((N, P), dtype)
        Xp[:n, :p] = X0
        lam = np.zeros((P * m,), dtype)
        lam[: p * m] = np.asarray(item0.lam)[: p * m]
        ys = np.zeros((self.slots, N), item0.y.dtype)
        Wts = np.zeros((self.slots, N), dtype)
        for i, pending in enumerate(batch):
            it = pending.item
            ys[i, :n] = it.y
            Wts[i, :n] = it.weights
        sigmas = np.asarray(item0.sigmas, dtype)
        return (Xp, ys, lam, sigmas, Wts, np.int32(p)), len(batch)

    def _watchdog_budget(self, rids) -> float | None:
        """Effective watchdog budget (seconds) for one device dispatch: the
        tightest of the service-wide ``solve_timeout_ms`` and the
        per-request budgets of the batch members (None: unbounded)."""
        with self._lock:
            per = [self._solve_timeouts[r] for r in rids
                   if r in self._solve_timeouts]
        if self.solve_timeout_ms is not None:
            per.append(self.solve_timeout_ms / 1e3)
        return min(per) if per else None

    def _execute_batch(self, key: _GroupKey, batch, *, trigger: str) -> None:
        """Pad, compile-or-fetch, execute and deliver one taken batch.

        Also the retry/bisection re-dispatch path: serving the same
        pendings through here is bit-identical to the original serve (same
        program, same padded operands, slot assignment by batch order).

        Compile and execute run under the per-program circuit breaker
        (consecutive faults open it — admissions then reject with
        ``reason="circuit_open"`` until the half-open probe) and the device
        call under the watchdog: past the effective ``solve_timeout_ms``
        the dispatch is abandoned and :class:`WatchdogTimeout` raised — the
        synchronous service propagates it to the caller, the async
        dispatcher recovers the cohort through retry/bisection.
        """
        now = self._clock()
        family = key.family
        m = family.n_classes
        N, P, L = key.n_rows, key.n_cols, key.path_length
        W = key.working_set
        W2 = None
        ws_key = None
        if W is not None:
            # resolve tier widths through the engine's own recipe so the
            # served program shape can never diverge from a direct call
            ws_key = (N, P, m, family.name, key.screening)
            W, W2 = resolve_ws_tiers(W, key.ws_tiers, N, P, ws_key)
            if key.working_set != "auto":
                ws_key = None  # explicit widths never touch the registry
        spec = ProgramSpec(
            family=family, batch=self.slots, n_rows=N, n_cols=P,
            path_length=L, screening=key.screening,
            solver_tol=key.solver_tol, max_iter=key.max_iter,
            kkt_tol=key.kkt_tol, max_refits=key.max_refits, working_set=W,
            working_set_top=W2, dtype=key.dtype, y_dtype=key.y_dtype,
            variant="replicate" if key.replicates else "path")
        rids = [p.rid for p in batch]
        # opt-in tracing: traces for the rids this serve carries (empty
        # dict when tracing is off — the disabled cost is one falsy check)
        trs = ([t for t in (self._traces.get(r) for r in rids)
                if t is not None] if self._traces else [])
        for t in trs:
            # the queue span ended when the batcher released the request;
            # flush covers padding + program-spec assembly
            t.mark("queue", now)
        if key.replicates:
            # replicate chunk: every member references the SAME native X
            # (the group token guarantees it), so the design is padded
            # ONCE and members contribute only a (N,) response row and a
            # (N,) weight row — empty slots keep all-zero weights, which
            # the weight-fused engine solves as exact null members
            operands, n_batch = self._pad_replicate(batch, N, P, m)
        else:
            pb = pad_batch(
                [(it.item.X, it.item.y, it.item.lam, it.item.sigmas)
                 for it in batch],
                n_rows=N, n_cols=P, n_slots=self.slots, n_classes=m)
            operands = (pb.Xs, pb.ys, pb.lam, pb.sigmas, pb.p_valid)
            n_batch = pb.n_batch
        t0 = self._clock()

        def _device_call():
            # the worker fault site fires INSIDE the watched call, so an
            # injected "hang" trips the watchdog exactly like a stuck
            # device dispatch would
            self._faults.fire("worker", rids=rids)
            with annotate(f"repro.serve.execute/{spec.short()}"):
                out = prog(*operands)
                stats = None
                if W is not None:
                    out, stats = out
                ep = EnginePath(*(np.asarray(a) for a in out))
                if stats is not None:
                    stats = CompactStats(*(np.asarray(a) for a in stats))
            return ep, stats

        try:
            self._faults.fire("compile", rids=rids)
            for t in trs:
                t.mark("flush", self._clock(), trigger=trigger,
                       slots=self.slots, batch=n_batch)
            prog, hit = self.cache.get(spec)
            for t in trs:
                t.mark("compile", self._clock(), hit=hit,
                       program=spec.short())
            t0 = self._clock()
            ep, stats = run_with_watchdog(
                _device_call, self._watchdog_budget(rids),
                label=spec.short())
        except BaseException as e:
            if isinstance(e, WatchdogTimeout):
                self.metrics.inc("watchdog_timeouts")
            self._breaker.record_failure(key)
            raise
        else:
            self._breaker.record_success(key)
        wall = self._clock() - t0
        for t in trs:
            t.mark("execute", self._clock(), solve_ms=round(wall * 1e3, 3))
        B_real = n_batch
        # grow-on-overflow through the same helper (and the same registry)
        # fit_path_batched(working_set="auto") uses
        if ws_key is not None and stats is not None:
            grow_ws_bucket(ws_key, stats.ws_size[:B_real],
                           stats.fell_back[:B_real], W, P,
                           two_tier=key.ws_tiers != 1)
        occupancy = B_real / self.slots
        plan_summary = spec.plan().summary()
        with self._lock:
            self.metrics.inc("batches")
            self.metrics.inc("plans", plan=plan_summary)
            self.metrics.observe("batch_occupancy", occupancy)
            self.metrics.inc("flush", trigger=trigger)
            for i, pending in enumerate(batch):
                item = pending.item
                n_i, p_i = item.X.shape
                betas = ep.betas[i][:, :p_i, :]
                if m == 1:
                    betas = betas[:, :, 0]
                unrep = ep.kkt_unrepaired[i]
                pad_ratio = (N * P) / (n_i * p_i)
                resp = PathResponse(
                    rid=pending.rid, betas=betas, sigmas=item.sigmas,
                    lam=item.lam, n_samples=n_i,
                    n_active=ep.n_active[i], n_screened=ep.n_screened[i],
                    n_violations=ep.n_violations[i], refits=ep.refits[i],
                    solver_iters=ep.solver_iters[i],
                    deviance=ep.deviance[i], kkt_unrepaired=unrep,
                    kkt_ok=not bool(unrep.any()), working_set=W,
                    working_set_top=W2,
                    ws_size=None if stats is None else stats.ws_size[i],
                    ws_tier=None if stats is None else stats.tier[i],
                    compact_fallback=(None if stats is None
                                      else stats.fell_back[i]),
                    queue_s=max(0.0, now - pending.submitted), solve_s=wall,
                    batch_size=B_real, batch_occupancy=occupancy,
                    padding_ratio=pad_ratio, cache_hit=hit,
                    health=ep.health[i])
                self.metrics.observe("padding_ratio", pad_ratio)
                if trs:
                    t = self._traces.get(pending.rid)
                    if t is not None:
                        t.mark("harvest", self._clock(),
                               padding_ratio=round(pad_ratio, 3))
                self._deliver(pending.rid, resp)

    def _record_latency(self, rid: int, resp: PathResponse) -> None:
        """Queue+solve latency, routed to the user-facing or the internal
        (CV-fold-fit) window — percentiles must measure what a caller sees."""
        lat = resp.queue_s + resp.solve_s
        internal = rid in self._cv_fold_rids or rid in self._rs_member_rids
        self.metrics.observe("latency_s", lat,
                             scope="internal" if internal else "user")

    def _finish_trace(self, rid: int, resp: PathResponse) -> None:
        """Close and attach the request's trace (the final "deliver" span)."""
        if not self._traces:
            return
        tr = self._traces.pop(rid, None)
        if tr is not None:
            tr.mark("deliver", self._clock())
            resp.trace = tr

    def _deliver(self, rid: int, resp: PathResponse) -> None:
        """Hand one finished response over for collection (``poll`` here;
        the async subclass overrides this to resolve the request's future).
        Caller holds ``self._lock``."""
        self.metrics.inc("completed")
        self.metrics.inc("kkt_violations", int(resp.n_violations.sum()))
        self._record_latency(rid, resp)
        self._finish_trace(rid, resp)
        self._solve_timeouts.pop(rid, None)
        if rid in self._cv_fold_rids:
            self._store(self._cv_hold, rid, resp)
        elif rid in self._rs_member_rids:
            self._store(self._rs_hold, rid, resp)
        else:
            self._store(self._done, rid, resp)

    def _store(self, table: OrderedDict, rid: int, resp) -> None:
        table[rid] = resp
        while len(table) > self.max_unclaimed:
            old, _ = table.popitem(last=False)
            # an evicted fold orphans its CV request; drop the membership
            # so the set cannot grow unboundedly with abandoned folds
            self._cv_fold_rids.discard(old)
            self._rs_member_rids.discard(old)
            self.metrics.inc("results_evicted")

    # -- collection ---------------------------------------------------------

    def poll(self, rid: int, *, flush: bool = False):
        """Collect a finished request (None while still pending).

        ``flush=True`` force-flushes first — the synchronous way to say
        "I need this result now" without waiting for fill or deadline.
        Responses are handed out once; polling again returns None.
        """
        if flush:
            self.flush()
        with self._lock:
            self._flush_due(self._clock())
            if rid in self._cv:
                return self._collect_cv(rid)
            if rid in self._rs:
                return self._collect_rs(rid)
            return self._done.pop(rid, None)

    def _collect_cv(self, rid: int):
        cv = self._cv[rid]
        if not all(r in self._cv_hold for r in cv.fold_rids):
            return None
        del self._cv[rid]
        folds = [self._cv_hold.pop(r) for r in cv.fold_rids]
        self._cv_fold_rids.difference_update(cv.fold_rids)
        betas = np.stack([f.betas for f in folds])
        val_dev = cv_val_deviance(cv.X, cv.y, cv.val_indices, betas,
                                  cv.family)
        mean, se, best_min, best_1se = cv_select(val_dev)
        best = best_1se if cv.selection == "1se" else best_min
        self.metrics.inc("completed")
        return CvResponse(
            rid=rid, sigmas=cv.sigmas, lam=cv.lam, val_deviance=val_dev,
            mean_val_deviance=mean, se_val_deviance=se, best_index=best,
            best_sigma=float(cv.sigmas[best]), best_index_min=best_min,
            best_index_1se=best_1se, selection=cv.selection,
            fold_responses=folds)

    def _collect_rs(self, rid: int):
        rp = self._rs[rid]
        if not all(r in self._rs_hold for r in rp.member_rids):
            return None
        del self._rs[rid]
        members = [self._rs_hold.pop(r) for r in rp.member_rids]
        self._rs_member_rids.difference_update(rp.member_rids)
        self.metrics.inc("completed")
        track_in_flight(rp.resample.kind, -len(members))
        return ResampleResponse(
            rid=rid, betas=np.stack([f.betas for f in members]),
            sigmas=rp.sigmas, lam=rp.lam, weights=rp.weights,
            resample=rp.resample, member_responses=members)

    # -- warmup & telemetry -------------------------------------------------

    def warmup(self, shapes, *, family: Family = ols, path_length: int = 100,
               screening: str = "strong", solver_tol: float = 1e-8,
               max_iter: int = 5000, kkt_tol: float = 1e-4,
               max_refits: int = 32,
               working_set: int | str | None = None,
               ws_tiers: int | str = DEFAULT_WS_TIERS,
               dtype: str = "float64", y_dtype: str = "float64") -> dict:
        """Pre-compile the programs a list of native ``(n, p)`` shapes will
        need, so the first live request pays no XLA latency."""
        specs = []
        for n, p in shapes:
            N, P = self.policy.shape_bucket(n, p, family.name)
            W = W2 = None
            if working_set is not None:
                ws_key = (N, P, family.n_classes, family.name, screening)
                W, W2 = resolve_ws_tiers(working_set, ws_tiers, N, P, ws_key)
            specs.append(ProgramSpec(
                family=family, batch=self.slots, n_rows=N, n_cols=P,
                path_length=path_length, screening=screening,
                solver_tol=solver_tol, max_iter=max_iter, kkt_tol=kkt_tol,
                max_refits=max_refits, working_set=W, working_set_top=W2,
                dtype=dtype, y_dtype=y_dtype))
        return self.cache.warmup(specs)

    def stats(self) -> dict:
        """Service-level telemetry: throughput, occupancy, latency
        percentiles, cache and bucket-registry counters.

        A read-through view over :attr:`metrics` (the unified
        :class:`repro.obs.MetricsRegistry`) — the key schema is pinned by
        ``tests/test_obs.py`` and the async override is a strict superset."""
        m = self.metrics
        with self._lock:
            lat = m.histogram("latency_s", scope="user")
            lat_int = m.histogram("latency_s", scope="internal")
            occ = m.histogram("batch_occupancy")
            pads = m.histogram("padding_ratio")
            return {
                "submitted": m.value("submitted"),
                "completed": m.value("completed"),
                "pending": (self._batcher.pending() + len(self._cv)
                            + len(self._rs)),
                "unclaimed": (len(self._done) + len(self._cv_hold)
                              + len(self._rs_hold)),
                "results_evicted": m.value("results_evicted"),
                "batches": m.value("batches"),
                "flush_fill": m.value("flush", trigger="fill"),
                "flush_deadline": m.value("flush", trigger="deadline"),
                "flush_forced": m.value("flush", trigger="forced"),
                "flush_retry": m.value("flush", trigger="retry"),
                "rejected": m.value("rejected"),
                "validation_rejected": m.value("validation_rejected"),
                "shed": m.value("shed"),
                "watchdog_timeouts": m.value("watchdog_timeouts"),
                "breaker": {**self._breaker.stats(),
                            "rejected": m.value("breaker_rejected")},
                "kkt_violations": m.value("kkt_violations"),
                "max_queue": self._batcher.max_queue,
                "faults": self._faults.stats() if self._faults.active()
                          else None,
                "slots": self.slots,
                "occupancy_mean": occ.mean(),
                "padding_ratio_mean": pads.mean(),
                # user-facing requests only — internal CV fold fits are
                # reported apart so SLO rows measure what a caller sees
                "latency_ms_p50": lat.percentile(50) * 1e3,
                "latency_ms_p95": lat.percentile(95) * 1e3,
                "latency_count": lat.retained,
                "internal_latency_ms_p50": lat_int.percentile(50) * 1e3,
                "internal_latency_ms_p95": lat_int.percentile(95) * 1e3,
                "internal_latency_count": lat_int.retained,
                "cache": self.cache.stats(),
                # executed ExecutionPlan summaries → batch counts: the
                # planner/program decisions behind the numbers above
                "plans": m.label_values("plans", "plan"),
                "ws_buckets": _WS_BUCKETS.summary(),
                # the resampling subsystem's registry (ns=resample) — one
                # read-through dict, shared with direct execution
                "resample": resample_stats(),
            }
