"""Deterministic fault injection for the serve layer.

The chaos-testing contract of PR 7: every recovery path the dispatcher
claims to have (cohort-scoped failure, retry with backoff, bisection down
to the poison request, close-mid-fault drain) must be *provable* under an
injected fault, not just plausible from reading the code.  A
:class:`FaultPlan` is a list of :class:`FaultSpec` triggers armed at named
**sites** inside the services:

========  ==============================================================
site      fires
========  ==============================================================
admit     during ``submit``, per request — ``kind="nan"`` corrupts the
          request's design matrix (the poison-request injector)
overload  during admission control, per request — an ``error`` spec here
          forces the adaptive load-shedding verdict
          (``Rejection(reason="shed")``) regardless of the latency
          window, so shedding is chaos-testable without generating load
compile   in the worker, before the program-cache lookup for a batch
worker    in the worker, after compile / before the compiled call —
          per execution round, with the in-flight rids attached
========  ==============================================================

Triggers are deterministic: a spec fires on occurrences ``after <= k <
after + times`` of its site (counted per spec), optionally gated on a
specific request id — so a test can say "the 2nd worker call crashes" or
"request 17's X gains a NaN" and replay it exactly.  ``kind``:

- ``"error"`` — raise :class:`InjectedFault` (worker crash / compile
  failure; transient when ``times`` is finite, persistent when large)
- ``"nan"``   — return a corrupted copy of the array at an ``admit`` site
  (seeded positions, so the poisoned operand is reproducible)
- ``"delay"`` — sleep ``delay_s`` (deadline overruns, slow workers)
- ``"hang"``  — sleep ``delay_s`` like ``delay``, but declared as a hang:
  the spec must set ``delay_s`` *past* the service's watchdog budget
  (``solve_timeout_ms``), so the watchdog — not the sleep — ends the
  wait and the cohort recovers through retry/bisection

Services hold a plan (default :data:`NO_FAULTS`, inert) and call
:meth:`FaultPlan.fire` / :meth:`FaultPlan.corrupt` at the sites above;
every firing is appended to :attr:`FaultPlan.events` for assertions.
Production code never constructs a plan — the hook costs one falsy check
per site when inert.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

__all__ = ["FaultSpec", "FaultPlan", "InjectedFault", "NO_FAULTS"]

_KINDS = ("error", "nan", "delay", "hang")


class InjectedFault(RuntimeError):
    """The synthetic failure raised by ``kind="error"`` fault specs."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed trigger: *what* goes wrong, *where*, and *when*.

    ``times``/``after`` window the firing on the site's occurrence count
    (per spec): occurrences ``[after, after + times)`` fire.  ``rid``
    (optional) gates on a specific request id — at ``admit`` the request
    being admitted, at worker sites any in-flight rid.
    """

    site: str
    kind: str = "error"
    times: int = 1
    after: int = 0
    rid: int | None = None
    delay_s: float = 0.0
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if self.times < 1:
            raise ValueError(f"times must be ≥ 1, got {self.times}")
        if self.after < 0:
            raise ValueError(f"after must be ≥ 0, got {self.after}")
        if self.kind == "hang" and not self.delay_s > 0:
            raise ValueError(
                "kind='hang' needs delay_s > 0 (longer than the watchdog "
                f"budget it is meant to trip), got {self.delay_s!r}")


class FaultPlan:
    """A deterministic schedule of injected faults, shared by one service.

    Thread-safe: the dispatcher's worker thread and submitting threads
    both hit the counters.
    """

    def __init__(self, specs: tuple[FaultSpec, ...] | list[FaultSpec] = (),
                 *, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.events: list[tuple[str, str, int | None]] = []  # (site, kind, rid)
        self._counts = [0] * len(self.specs)
        self._lock = threading.Lock()

    def active(self) -> bool:
        return bool(self.specs)

    def _match(self, spec: FaultSpec, i: int, site: str,
               rids: tuple[int, ...]) -> bool:
        # caller holds the lock; counts advance only for matching sites so
        # "the 2nd worker call" means the 2nd call AT that site
        if spec.site != site:
            return False
        if spec.rid is not None and spec.rid not in rids:
            return False
        k = self._counts[i]
        self._counts[i] = k + 1
        return spec.after <= k < spec.after + spec.times

    def fire(self, site: str, *, rids: tuple[int, ...] | list[int] = ()) -> None:
        """Trip any armed ``error``/``delay`` spec at ``site``.

        ``rids`` are the request ids implicated by this execution (used
        both for rid-gated specs and for the event log).  Raises
        :class:`InjectedFault` for ``error`` kinds.
        """
        if not self.specs:
            return
        rids = tuple(int(r) for r in rids)
        delay, err = 0.0, None
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.kind == "nan" or not self._match(spec, i, site, rids):
                    continue
                self.events.append((site, spec.kind, spec.rid))
                if spec.kind in ("delay", "hang"):
                    delay = max(delay, spec.delay_s)
                elif err is None:
                    err = InjectedFault(f"{spec.message} [site={site}]")
        if delay:
            time.sleep(delay)
        if err is not None:
            raise err

    def corrupt(self, site: str, rid: int, x: np.ndarray) -> np.ndarray:
        """Return ``x`` poisoned per any matching ``nan`` spec (or ``x``
        itself, untouched, when none fires)."""
        if not self.specs:
            return x
        fire = False
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.kind != "nan":
                    continue
                if self._match(spec, i, site, (int(rid),)):
                    self.events.append((site, "nan", int(rid)))
                    fire = True
        if not fire:
            return x
        bad = np.array(x, dtype=float, copy=True)
        # seeded poison positions — the corrupted operand is replayable
        rng = np.random.default_rng(self.seed + int(rid))
        flat = bad.reshape(-1)
        k = max(1, flat.size // 16)
        flat[rng.choice(flat.size, size=k, replace=False)] = np.nan
        return bad

    def stats(self) -> dict:
        with self._lock:
            return {
                "armed": len(self.specs),
                "fired": len(self.events),
                "by_site": {s: sum(1 for e in self.events if e[0] == s)
                            for s in {e[0] for e in self.events}},
            }


NO_FAULTS = FaultPlan()
"""The inert plan every service defaults to (``active()`` is False)."""
