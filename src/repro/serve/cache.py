"""Keyed compiled-executable cache for the path service.

The engine's jitted entry points already memoise compilations inside JAX,
but a serving layer needs more than a hidden dispatch cache: it needs to
**warm** programs before traffic arrives, **account** for compile time and
hit rates, and **bound** resident executables with real eviction.  So this
cache compiles ahead-of-time — ``jit(engine).lower(shapes...).compile()``
on :class:`jax.ShapeDtypeStruct` specs, no example data needed — and owns
the resulting executables outright (AOT executables bypass JAX's dispatch
cache, so evicting an entry actually frees the program).

AOT-compiled and jit-dispatched runs of the same program are bitwise
identical (same HLO, same pipeline — asserted in ``tests/test_serve.py``),
which is what lets the service guarantee served results match direct
``fit_path_batched(pad="bucket")`` calls exactly.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import numpy as np

import jax

from ..core.losses import Family
from ..obs import MetricsRegistry
from ..obs.profile import annotate

__all__ = ["ProgramSpec", "CompiledProgram", "ProgramCache"]


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """Static description of one compiled path program (the cache key).

    ``working_set=None`` selects the masked full-width engine; an int is the
    *resolved* static compact width W (power-of-two, resolution happens in
    the service/engine, not here).  ``working_set_top`` is the resolved
    second-tier width (None: single tier) — part of the key because the
    two-tier engine is a different compiled program.  ``n_rows``/``n_cols``
    are the padded bucket shape, ``batch`` the padded slot count.

    ``variant`` keys the slot-recycling program family the async dispatcher
    uses: ``"path"`` is the whole-grid program, ``"chunk"`` advances carried
    state by ``step_chunk`` σ-steps per call
    (:func:`repro.core.engine.chunk_path_engine` — masked engine only), and
    ``"init"`` is the batched prefill that seeds a newly inserted slot
    (:func:`repro.core.engine.path_init_engine`).  ``"replicate"`` is the
    weight-fused resample program: ``batch`` row-reweighted members against
    ONE shared ``(n_rows, n_cols)`` design
    (:func:`repro.core.engine.replicate_path_engine`, or the compact
    variant when ``working_set`` is set) — the resident operands are
    O(n·p + B·n), never a (B, n, p) stack.
    """

    family: Family
    batch: int
    n_rows: int
    n_cols: int
    path_length: int
    screening: str = "strong"
    solver_tol: float = 1e-8
    max_iter: int = 5000
    kkt_tol: float = 1e-4
    max_refits: int = 32
    working_set: int | None = None
    working_set_top: int | None = None
    dtype: str = "float64"
    y_dtype: str = "float64"
    variant: str = "path"
    step_chunk: int | None = None

    def __post_init__(self):
        if self.variant not in ("path", "chunk", "init", "replicate"):
            raise ValueError(f"variant must be 'path', 'chunk', 'init' or "
                             f"'replicate', got {self.variant!r}")
        if self.variant == "chunk":
            if self.step_chunk is None or self.step_chunk < 1:
                raise ValueError("variant='chunk' needs step_chunk ≥ 1, got "
                                 f"{self.step_chunk!r}")
            if self.working_set is not None:
                raise ValueError(
                    "continuous chunk programs run the masked engine only "
                    "(compact carried state is not slot-swappable); "
                    "working_set must be None for variant='chunk'")
        elif self.step_chunk is not None:
            raise ValueError(
                f"step_chunk only applies to variant='chunk', got "
                f"variant={self.variant!r}")

    def short(self) -> str:
        w = f"W{self.working_set}" if self.working_set else "masked"
        if self.working_set and self.working_set_top:
            w += f"+{self.working_set_top}"
        s = (f"{self.family.name}/B{self.batch}n{self.n_rows}"
             f"p{self.n_cols}L{self.path_length}/{w}")
        if self.variant == "chunk":
            s += f"/chunk{self.step_chunk}"
        elif self.variant == "init":
            s += "/init"
        elif self.variant == "replicate":
            s += "/replicate"
        return s

    def plan(self):
        """The :class:`repro.api.plan.ExecutionPlan` this compiled program
        realises — how the serving layer exposes its (pinned) execution
        choices through the same introspection surface the planner uses."""
        from ..api.plan import ExecutionPlan

        if self.working_set is None:
            tiers = None
        elif self.working_set_top is None:
            tiers = (self.working_set,)
        else:
            tiers = (self.working_set, self.working_set_top)
        reason = f"pinned by compiled program group {self.short()}"
        if self.variant == "chunk":
            reason += (f" (continuous batching: {self.step_chunk}-step "
                       f"chunks, slots recycled at chunk boundaries)")
        elif self.variant == "replicate":
            reason += (f" (weight-fused replicates: {self.batch} members "
                       f"share ONE {self.n_rows}×{self.n_cols} design via "
                       f"per-member row weights)")
        return ExecutionPlan(
            backend="serve",
            mode="compact" if self.working_set else "masked",
            batch=self.batch, n=self.n_rows, p=self.n_cols,
            working_set=self.working_set, ws_tiers=tiers, pad="bucket",
            exec_shape=(self.batch, self.n_rows, self.n_cols),
            screening=self.screening,
            device=jax.default_backend(),
            reasons=(reason,),
        )


class CompiledProgram:
    """One AOT-compiled engine executable plus its call convention.

    ``"path"`` programs take ``(Xs, ys, lam, sigmas, p_valid)``; ``"chunk"``
    programs take ``(Xs, ys, lam, sig_prev, sig_next, live, beta, grad,
    active, L, health, p_valid)``; ``"init"`` programs take ``(Xs, ys)``;
    ``"replicate"`` programs take ``(X, ys, lam, sigmas, weights, p_valid)``
    with one shared (N, P) design, (B, N) member responses/weights and a
    scalar ``p_valid``.  Operands
    are converted as-is — AOT executables demand exact dtypes, so callers
    own them — except the trailing int32 ``p_valid``, which is cast for
    convenience on the variants that end with it.
    """

    def __init__(self, spec: ProgramSpec, compiled, build_seconds: float):
        self.spec = spec
        self.build_seconds = build_seconds
        self.calls = 0
        self._compiled = compiled

    def __call__(self, *operands):
        import jax.numpy as jnp

        self.calls += 1
        args = [jnp.asarray(a) for a in operands]
        if self.spec.variant in ("path", "chunk", "replicate"):
            args[-1] = jnp.asarray(args[-1], jnp.int32)  # p_valid
        return self._compiled(*args)


def _build(spec: ProgramSpec) -> tuple:
    """Lower + compile the engine for ``spec`` from shape specs alone."""
    from ..core.engine import (
        batched_path_engine,
        chunk_path_engine,
        compact_path_engine,
        path_init_engine,
        replicate_compact_path_engine,
        replicate_path_engine,
    )

    m = spec.family.n_classes
    f = np.dtype(spec.dtype)
    B, N, P, L = spec.batch, spec.n_rows, spec.n_cols, spec.path_length
    sds = jax.ShapeDtypeStruct
    data = (
        sds((B, N, P), f),                      # Xs
        sds((B, N), np.dtype(spec.y_dtype)),    # ys
    )
    lam = sds((B, P * m), f)                    # per-member λ
    pv = sds((B,), np.int32)
    kw = dict(screening=spec.screening, max_iter=spec.max_iter,
              tol=spec.solver_tol, kkt_tol=spec.kkt_tol,
              max_refits=spec.max_refits)
    t0 = time.perf_counter()
    if spec.variant == "replicate":
        # ONE shared (N, P) design, (B, N) member responses and row
        # weights, one shared λ/σ grid, scalar p_valid
        rdata = (
            sds((N, P), f),                         # shared X
            sds((B, N), np.dtype(spec.y_dtype)),    # per-member y
            sds((P * m,), f),                       # shared λ
            sds((L,), f),                           # shared σ grid
            sds((B, N), f),                         # per-member row weights
        )
        rpv = sds((), np.int32)
        if spec.working_set is None:
            lowered = replicate_path_engine.lower(*rdata, spec.family, rpv,
                                                  **kw)
        else:
            lowered = replicate_compact_path_engine.lower(
                *rdata, spec.family, rpv, width=spec.working_set,
                width2=spec.working_set_top, **kw)
    elif spec.variant == "init":
        lowered = path_init_engine.lower(*data, spec.family)
    elif spec.variant == "chunk":
        C = spec.step_chunk
        lowered = chunk_path_engine.lower(
            *data, lam,
            sds((B, C), f), sds((B, C), f), sds((B, C), bool),  # σ pairs, live
            sds((B, P, m), f), sds((B, P, m), f),               # beta, grad
            sds((B, P), bool), sds((B,), f),                    # active, L
            sds((B,), np.int32),                                # health
            spec.family, pv, **kw)
    elif spec.working_set is None:
        lowered = batched_path_engine.lower(*data, lam, sds((B, L), f),
                                            spec.family, pv, **kw)
    else:
        lowered = compact_path_engine.lower(*data, lam, sds((B, L), f),
                                            spec.family, pv,
                                            width=spec.working_set,
                                            width2=spec.working_set_top,
                                            **kw)
    with annotate(f"repro.compile/{spec.short()}"):
        compiled = lowered.compile()
    return compiled, time.perf_counter() - t0


class ProgramCache:
    """Bounded LRU cache of :class:`CompiledProgram` executables.

    ``get`` compiles on miss (slow — seconds) and returns ``(program,
    hit)``; ``warmup`` pre-compiles a list of specs so the first real
    request never pays XLA latency.  All mutation happens under one lock;
    compilation itself holds the lock too (simpler, and the service flushes
    batches from one thread — concurrent builders would just duplicate
    work).

    ``store`` (optional, a :class:`repro.serve.DurableProgramStore`) makes
    misses crash-safe: a miss first tries the store's serialized
    executable (milliseconds) before compiling from source (seconds), and
    every fresh build is saved back plus appended to the store's warmup
    manifest — so a restarted process replays the manifest at boot and
    compiles nothing it has already seen.  ``misses`` counts cache misses
    regardless of where the program came from; ``builds`` counts actual
    XLA compilations (a warm-store boot shows misses > 0, builds == 0).
    """

    def __init__(self, capacity: int = 32, store=None):
        if capacity < 1:
            raise ValueError(f"capacity must be ≥ 1, got {capacity}")
        self.capacity = capacity
        self.store = store
        self._data: OrderedDict[ProgramSpec, CompiledProgram] = OrderedDict()
        self._lock = threading.Lock()
        # hits/misses/evictions/build_seconds live on the unified registry;
        # stats() below is a read-through view preserving the legacy keys
        self.metrics = MetricsRegistry("cache")

    def get(self, spec: ProgramSpec) -> tuple[CompiledProgram, bool]:
        with self._lock:
            prog = self._data.get(spec)
            if prog is not None:
                self._data.move_to_end(spec)
                self.metrics.inc("hits")
                return prog, True
            self.metrics.inc("misses")
            prog = None if self.store is None else self.store.load(spec)
            if prog is None:
                compiled, dt = _build(spec)
                prog = CompiledProgram(spec, compiled, dt)
                self.metrics.inc("builds")
                self.metrics.inc("build_seconds", dt)
                self.metrics.observe("build_s", dt)
                if self.store is not None:
                    self.store.save(spec, prog)
            self._data[spec] = prog
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.metrics.inc("evictions")
            return prog, False

    def warmup(self, specs) -> dict[str, float]:
        """Compile every spec now; returns ``{spec.short(): build_seconds}``
        (0.0 for specs that were already resident)."""
        out = {}
        for spec in specs:
            prog, hit = self.get(spec)
            out[spec.short()] = 0.0 if hit else prog.build_seconds
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, spec: ProgramSpec) -> bool:
        with self._lock:
            return spec in self._data

    def stats(self) -> dict:
        m = self.metrics
        with self._lock:
            hits = m.value("hits")
            misses = m.value("misses")
            total = hits + misses
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": hits,
                "misses": misses,
                "hit_rate": hits / total if total else 0.0,
                "evictions": m.value("evictions"),
                "builds": m.value("builds"),
                "build_seconds": round(m.value("build_seconds", 0.0), 3),
                "programs": {s.short(): p.calls for s, p in self._data.items()},
                "store": None if self.store is None else self.store.stats(),
            }
