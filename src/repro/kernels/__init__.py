"""Pallas TPU kernels for SLOPE's compute hot spots (validated in
interpret mode on CPU; see ops.py for dispatch and ref.py for oracles)."""

from .ops import (
    CompactGemvStats,
    compact_gemv_stats,
    slope_gradient,
    slope_gradient_compact,
    slope_gradient_masked,
    slope_gradient_replicate,
    slope_residual,
    slope_residual_compact,
    slope_residual_masked,
    slope_residual_replicate,
    slope_loss_residual,
    slope_loss_residual_compact,
    slope_loss_residual_replicate,
    screen_scan,
    prox_pool,
    prox_sorted_l1_kernel,
)

__all__ = [
    "CompactGemvStats",
    "compact_gemv_stats",
    "slope_gradient",
    "slope_gradient_compact",
    "slope_gradient_masked",
    "slope_gradient_replicate",
    "slope_residual",
    "slope_residual_compact",
    "slope_residual_masked",
    "slope_residual_replicate",
    "slope_loss_residual",
    "slope_loss_residual_compact",
    "slope_loss_residual_replicate",
    "screen_scan",
    "prox_pool",
    "prox_sorted_l1_kernel",
]
