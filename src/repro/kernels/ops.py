"""jit'd public wrappers around the Pallas kernels.

Responsibilities: pad to block multiples, pick interpret mode, fall back to
the pure-jnp oracle where a kernel's preconditions don't hold (e.g. prox
pooling beyond the VMEM budget, or a block-compacted call whose mask is a
tracer), and — for the ``*_compact`` wrappers — build the live-block index
list on the host and record per-call live-block telemetry
(:func:`compact_gemv_stats`) so tests and benchmarks can assert that the
remapped grid covers exactly the live blocks.

Interpret mode: Pallas TPU kernels execute via the interpreter on CPU —
that is how this container validates them; on a real TPU
``interpret=False`` compiles to Mosaic.  The ``REPRO_PALLAS_INTERPRET``
environment variable overrides the backend sniff (``1``/``true`` forces
the interpreter even on TPU — useful to bisect Mosaic lowering bugs;
``0``/``false`` forces compiled mode).  It is read at trace time, so flip
it before the first call of a given shape.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import threading

import numpy as np

import jax
import jax.numpy as jnp

from ..obs import MetricsRegistry
from . import ref as _ref
from .prox_sorted_l1 import VMEM_ELEM_LIMIT, prox_pool_kernel_call
from .screen_scan import DEFAULT_BLOCK, screen_scan_kernel_call
from .slope_gemv import (
    DEFAULT_BN,
    DEFAULT_BP,
    xb_loss_residual,
    xb_loss_residual_compact,
    xb_loss_residual_replicate,
    xb_residual,
    xb_residual_compact,
    xb_residual_masked,
    xb_residual_replicate,
    xt_matmul,
    xt_matmul_compact,
    xt_matmul_masked,
    xt_matmul_replicate,
)

__all__ = [
    "slope_gradient",
    "slope_gradient_masked",
    "slope_gradient_compact",
    "slope_gradient_replicate",
    "slope_residual",
    "slope_residual_masked",
    "slope_residual_compact",
    "slope_residual_replicate",
    "slope_loss_residual",
    "slope_loss_residual_compact",
    "slope_loss_residual_replicate",
    "screen_scan",
    "prox_pool",
    "prox_sorted_l1_kernel",
    "CompactGemvStats",
    "compact_gemv_stats",
    "COMPACT_METRICS",
]


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env:  # empty (or unset) falls through to the backend sniff
        return env not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("bn", "bp", "use_kernel"))
def slope_gradient(X, R, *, bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                   use_kernel: bool = True):
    """∇f = Xᵀ R.  X (n, p); R (n,) or (n, m) → matches R's rank."""
    squeeze = R.ndim == 1
    R2 = R[:, None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_ref(X, R2)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R2, bn_, 0), 128, 1)
    out = xt_matmul(Xp, Rp, bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:p, : R2.shape[1]]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("bn", "bp", "use_kernel"))
def slope_gradient_masked(X, R, mask, *, bn: int = DEFAULT_BN,
                          bp: int = DEFAULT_BP, use_kernel: bool = True):
    """∇f = (X ⊙ mask)ᵀ R with fully-masked column blocks skipped.

    ``mask`` is a (p,) column mask (bool or 0/1); masked columns' gradient
    rows are exactly 0.  Zero-padded mask columns keep the padding blocks
    dead, so padding adds no compute.
    """
    squeeze = R.ndim == 1
    R2 = R[:, None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_masked_ref(X, R2, mask)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R2, bn_, 0), 128, 1)
    Mp = _pad_to(mask.astype(X.dtype)[None, :], bp_, 1)
    out = xt_matmul_masked(Xp, Rp, Mp, bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:p, : R2.shape[1]]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_residual(X, B, Y, *, family: str = "none", bn: int = DEFAULT_BN,
                   bp: int = DEFAULT_BP, use_kernel: bool = True):
    """r = ∂ℓ/∂z at z = X·B, fused GEMV + GLM epilogue."""
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        out = _ref.xb_residual_ref(X, B2, Y2, family)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    out = xb_residual(
        Xp, Bp, Yp, family=family, m_actual=m, bn=bn_, bp=bp_, interpret=_interpret()
    )
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_residual_masked(X, B, Y, mask, *, family: str = "none",
                          bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                          use_kernel: bool = True):
    """r = ∂ℓ/∂z at z = (X ⊙ mask)·B, skipping fully-masked column blocks."""
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        out = _ref.xb_residual_masked_ref(X, B2, Y2, mask, family)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    Mp = _pad_to(mask.astype(X.dtype)[None, :], bp_, 1)
    out = xb_residual_masked(
        Xp, Bp, Yp, Mp, family=family, m_actual=m, bn=bn_, bp=bp_,
        interpret=_interpret(),
    )
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# replicate GEMVs: B row-reweighted members against ONE shared X
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bn", "bp", "use_kernel"))
def slope_gradient_replicate(X, R, W, *, bn: int = DEFAULT_BN,
                             bp: int = DEFAULT_BP, use_kernel: bool = True):
    """G_b = Xᵀ (w_b ⊙ R_b) for B replicate members, one shared X.

    X (n, p); R (B, n) or (B, n, m); W (B, n) per-member row weights
    (bootstrap counts / subsample masks / ones).  Zero-weight rows are
    exactly inert.  X is never materialized per member — the kernel's
    member axis rides the grid with an X index map that ignores it.
    """
    squeeze = R.ndim == 2
    R3 = R[..., None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_replicate_ref(X, R3, W)
        return out[..., 0] if squeeze else out
    n, p = X.shape
    m = R3.shape[2]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R3, bn_, 1), 128, 2)
    Wt = _pad_to(W.astype(X.dtype).T, bn_, 0)  # (n, B), padded rows w = 0
    out = xt_matmul_replicate(Xp, Rp, Wt, bn=bn_, bp=bp_,
                              interpret=_interpret())
    out = out[:, :p, :m]
    return out[..., 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp",
                                             "use_kernel"))
def slope_residual_replicate(X, B, Y, W, *, family: str = "none",
                             bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                             use_kernel: bool = True):
    """r_b = w_b ⊙ ∂ℓ/∂z at z_b = X·B_b, one shared X, fused epilogue.

    B (Bm, p) or (Bm, p, m) per-member coefficients; Y (Bm, n[, m])
    per-member responses; W (Bm, n).  Returns the already-weighted
    residual stack ready for :func:`slope_gradient_replicate` — note the
    weights must then NOT be applied again there (pass ones), or use this
    pair as (residual: weighted, gradient: plain per-member xt_matmul).
    """
    squeeze = B.ndim == 2
    B3 = B[..., None] if squeeze else B
    Y3 = Y[..., None] if Y.ndim == 2 else Y
    if not use_kernel:
        out = _ref.xb_residual_replicate_ref(X, B3, Y3, W, family)
        return out[..., 0] if squeeze else out
    n, p = X.shape
    m = B3.shape[2]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B3, bp_, 1), 128, 2)
    Yp = _pad_to(_pad_to(Y3, bn_, 1), 128, 2)
    Wt = _pad_to(W.astype(X.dtype).T, bn_, 0)
    out = xb_residual_replicate(Xp, Bp, Yp, Wt, family=family, m_actual=m,
                                bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:, :n, :m]
    return out[..., 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp",
                                             "use_kernel"))
def slope_loss_residual_replicate(X, B, Y, W, *, family: str = "none",
                                  bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                                  use_kernel: bool = True):
    """Per-member fused forward pair (weighted loss, weighted residual).

    Returns ``(loss (Bm,), r (Bm, n[, m]))`` — each member's weighted loss
    Σᵢ w_{b,i}·ℓ(z_{b,i}, y_{b,i}) and weighted residual from ONE pass
    over the shared X per member.
    """
    squeeze = B.ndim == 2
    B3 = B[..., None] if squeeze else B
    Y3 = Y[..., None] if Y.ndim == 2 else Y
    if not use_kernel:
        r, rows = _ref.xb_loss_residual_replicate_ref(X, B3, Y3, W, family)
        return jnp.sum(rows, axis=1), (r[..., 0] if squeeze else r)
    n, p = X.shape
    m = B3.shape[2]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B3, bp_, 1), 128, 2)
    Yp = _pad_to(_pad_to(Y3, bn_, 1), 128, 2)
    Wt = _pad_to(W.astype(X.dtype).T, bn_, 0)
    r, rows = xb_loss_residual_replicate(
        Xp, Bp, Yp, Wt, family=family, m_actual=m, bn=bn_, bp=bp_,
        interpret=_interpret())
    # padded rows carry w = 0 → their loss rows are exactly 0, but slice
    # the real rows anyway (mirrors the unweighted wrappers' convention)
    loss = jnp.sum(rows[:, :n, 0], axis=1)
    r = r[:, :n, :m]
    return loss, (r[..., 0] if squeeze else r)


# ---------------------------------------------------------------------------
# block-compacted GEMVs: live-block grid remap via scalar prefetch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompactGemvStats:
    """Telemetry for one block-compacted GEMV dispatch."""

    op: str              # which wrapper ran
    blocks_total: int    # column blocks in the padded (P/bp) grid axis
    blocks_live: int     # blocks with ≥ 1 unmasked column == remapped extent
    grid: tuple          # the Pallas grid actually launched

    @property
    def live_ratio(self) -> float:
        return self.blocks_live / max(self.blocks_total, 1)


# last dispatch per op — the assertion surface for "dead blocks were not
# fetched": tests/benches check stats.grid's column extent == blocks_live.
# Thread-LOCAL so a caller always reads its own dispatch, never another
# thread's interleaved one (e.g. parallel test workers in one process)
_COMPACT_TELEMETRY = threading.local()

# process-wide dispatch accounting (counts + live-ratio histogram, labeled
# by op) — the aggregate view the serving stack's exporters can dump; the
# thread-local table above stays the per-dispatch assertion surface
COMPACT_METRICS = MetricsRegistry("kernels.compact")


def _record_compact(op: str, stats: "CompactGemvStats") -> None:
    table = getattr(_COMPACT_TELEMETRY, "table", None)
    if table is None:
        table = _COMPACT_TELEMETRY.table = {}
    table[op] = stats
    COMPACT_METRICS.inc("dispatches", op=op)
    COMPACT_METRICS.inc("blocks_live", stats.blocks_live, op=op)
    COMPACT_METRICS.inc("blocks_total", stats.blocks_total, op=op)
    COMPACT_METRICS.observe("live_ratio", stats.live_ratio, op=op)


def compact_gemv_stats(op: str | None = None):
    """Live-block telemetry of the calling thread's most recent compact
    dispatch(es).

    ``op`` is one of ``"gradient"`` / ``"residual"`` / ``"loss_residual"``
    (None returns the whole table).  Host-side bookkeeping only — the
    values describe the launched grid, not traced array contents.
    """
    table = getattr(_COMPACT_TELEMETRY, "table", {})
    if op is None:
        return dict(table)
    return table.get(op)


def _live_blocks(mask_np: np.ndarray, P: int, bp: int) -> np.ndarray:
    """Ascending indices of the (bp-wide) column blocks with any survivor."""
    padded = np.zeros(P, bool)
    padded[: mask_np.shape[0]] = mask_np
    return np.flatnonzero(padded.reshape(P // bp, bp).any(axis=1)).astype(
        np.int32)


def _concrete_mask(mask) -> np.ndarray | None:
    """The mask as a host bool array, or None when it is a tracer (a
    traced mask cannot size a static grid — callers fall back to the
    masked kernels, which are semantically identical)."""
    if isinstance(mask, jax.core.Tracer):
        return None
    return np.asarray(mask).astype(bool)


def slope_gradient_compact(X, R, mask, *, bn: int = DEFAULT_BN,
                           bp: int = DEFAULT_BP, use_kernel: bool = True):
    """∇f = (X ⊙ mask)ᵀ R with dead column blocks never DMA'd.

    The live-block list is built host-side from ``mask`` (which must be
    concrete; a traced mask silently degrades to
    :func:`slope_gradient_masked` — same results, block-skip without the
    bandwidth saving) and remaps the Pallas grid via scalar prefetch, so
    a working set of W columns streams ⌈W/bp⌉ blocks of X instead of p/bp.
    Bit-identical to the masked kernel; dead columns' gradient rows are
    exactly 0.
    """
    squeeze = R.ndim == 1
    R2 = R[:, None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_compact_ref(X, R2, mask)
        return out[:, 0] if squeeze else out
    mask_np = _concrete_mask(mask)
    if mask_np is None:
        return slope_gradient_masked(X, R, mask, bn=bn, bp=bp)
    n, p = X.shape
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    P = _round_up(p, bp_)
    live = _live_blocks(mask_np, P, bp_)
    n_live = int(live.shape[0])
    _record_compact("gradient", CompactGemvStats(
        op="gradient", blocks_total=P // bp_, blocks_live=n_live,
        grid=(n_live, _round_up(n, bn_) // bn_)))
    mR = R2.shape[1]
    if n_live == 0:
        out = jnp.zeros((p, mR), X.dtype)
        return out[:, 0] if squeeze else out
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R2, bn_, 0), 128, 1)
    Mp = _pad_to(mask_np.astype(X.dtype)[None, :], bp_, 1)
    outc = xt_matmul_compact(Xp, Rp, Mp, jnp.asarray(live), bn=bn_, bp=bp_,
                             interpret=_interpret())
    full = jnp.zeros((P // bp_, bp_, outc.shape[1]), outc.dtype)
    full = full.at[jnp.asarray(live)].set(
        outc.reshape(n_live, bp_, outc.shape[1]))
    out = full.reshape(P, -1)[:p, :mR]
    return out[:, 0] if squeeze else out


def slope_residual_compact(X, B, Y, mask, *, family: str = "none",
                           bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                           use_kernel: bool = True):
    """r = ∂ℓ/∂z at z = (X ⊙ mask)·B with dead column blocks never DMA'd.

    Same contract as :func:`slope_residual_masked` (bit-identical results);
    a traced mask degrades to the masked kernel.
    """
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        out = _ref.xb_residual_compact_ref(X, B2, Y2, mask, family)
        return out[:, 0] if squeeze else out
    mask_np = _concrete_mask(mask)
    if mask_np is None:
        return slope_residual_masked(X, B, Y, mask, family=family, bn=bn,
                                     bp=bp)
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    P = _round_up(p, bp_)
    live = _live_blocks(mask_np, P, bp_)
    n_live = int(live.shape[0])
    _record_compact("residual", CompactGemvStats(
        op="residual", blocks_total=P // bp_, blocks_live=n_live,
        grid=(_round_up(n, bn_) // bn_, n_live)))
    if n_live == 0:  # z ≡ 0: the epilogue alone decides the residual
        z = jnp.zeros((n, m), jnp.promote_types(X.dtype, jnp.float32))
        out = _ref._epilogue(z, Y2.astype(z.dtype), family).astype(X.dtype)
        return out[:, 0] if squeeze else out
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    Mp = _pad_to(mask_np.astype(X.dtype)[None, :], bp_, 1)
    out = xb_residual_compact(
        Xp, Bp, Yp, Mp, jnp.asarray(live), family=family, m_actual=m,
        bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


def slope_loss_residual_compact(X, B, Y, mask, *, family: str = "none",
                                bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                                use_kernel: bool = True):
    """(ℓ(z, y), r) at z = (X ⊙ mask)·B in one live-blocks-only pass over X.

    The compact analogue of :func:`slope_loss_residual`.  A traced mask
    degrades to the pure-jnp masked oracle (one ``X ⊙ mask`` pass for both
    halves — there is no fused *masked* Pallas kernel to fall back on,
    unlike the gradient/residual wrappers which degrade to their masked
    kernels).
    """
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        r, rows = _ref.xb_loss_residual_compact_ref(X, B2, Y2, mask, family)
        return jnp.sum(rows), (r[:, 0] if squeeze else r)
    mask_np = _concrete_mask(mask)
    if mask_np is None:
        r, rows = _ref.xb_loss_residual_compact_ref(X, B2, Y2, mask, family)
        return jnp.sum(rows), (r[:, 0] if squeeze else r)
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    P = _round_up(p, bp_)
    live = _live_blocks(mask_np, P, bp_)
    n_live = int(live.shape[0])
    _record_compact("loss_residual", CompactGemvStats(
        op="loss_residual", blocks_total=P // bp_, blocks_live=n_live,
        grid=(_round_up(n, bn_) // bn_, n_live)))
    if n_live == 0:
        z = jnp.zeros((n, m), jnp.promote_types(X.dtype, jnp.float32))
        Yz = Y2.astype(z.dtype)
        r = _ref._epilogue(z, Yz, family).astype(X.dtype)
        loss = jnp.sum(_ref._row_loss(z, Yz, family))
        return loss, (r[:, 0] if squeeze else r)
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    Mp = _pad_to(mask_np.astype(X.dtype)[None, :], bp_, 1)
    r, rows = xb_loss_residual_compact(
        Xp, Bp, Yp, Mp, jnp.asarray(live), family=family, m_actual=m,
        bn=bn_, bp=bp_, interpret=_interpret())
    # padded rows see z = 0, y = 0 — nonzero loss for e.g. logistic — so
    # the reduction must slice the real rows first (as in the fused kernel)
    loss = jnp.sum(rows[:n, 0])
    r = r[:n, :m]
    return loss, (r[:, 0] if squeeze else r)


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_loss_residual(X, B, Y, *, family: str = "none", bn: int = DEFAULT_BN,
                        bp: int = DEFAULT_BP, use_kernel: bool = True):
    """(ℓ(z, y), r = ∂ℓ/∂z) at z = X·B in ONE pass over X.

    The fused forward pair a FISTA step needs — the loss is the scalar sum
    over rows, the residual feeds the gradient matvec.
    """
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        r, rows = _ref.xb_loss_residual_ref(X, B2, Y2, family)
        return jnp.sum(rows), (r[:, 0] if squeeze else r)
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    r, rows = xb_loss_residual(
        Xp, Bp, Yp, family=family, m_actual=m, bn=bn_, bp=bp_,
        interpret=_interpret(),
    )
    # padded rows see z = 0, y = 0 — nonzero loss for e.g. logistic — so the
    # reduction must slice the real rows first
    loss = jnp.sum(rows[:n, 0])
    r = r[:n, :m]
    return loss, (r[:, 0] if squeeze else r)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel"))
def screen_scan(c, lam, *, block: int = DEFAULT_BLOCK, use_kernel: bool = True):
    """Algorithm-2 screen: k = #kept (c, λ in the sorted order)."""
    if not use_kernel:
        return _ref.screen_scan_ref(c, lam)
    (p,) = c.shape
    blk = min(block, _round_up(p, 128))
    # pad with c − λ = −1: strictly decreasing tail can never host the
    # rightmost argmax, so k is unaffected
    cp = _pad_to(c.astype(jnp.float32), blk, 0, value=-1.0)
    lp = _pad_to(lam.astype(jnp.float32), blk, 0, value=0.0)
    return screen_scan_kernel_call(cp, lp, block=blk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def prox_pool(w, *, use_kernel: bool = True):
    """Non-increasing isotonic projection + clip at 0."""
    if not use_kernel or w.shape[0] > VMEM_ELEM_LIMIT:
        return _ref.prox_pool_ref(w)
    return prox_pool_kernel_call(w, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def prox_sorted_l1_kernel(v, lam, *, use_kernel: bool = True):
    """Full sorted-ℓ1 prox: XLA sort + Pallas pooling + unsort."""
    shape = v.shape
    v = jnp.ravel(v)
    lam = jnp.ravel(lam).astype(v.dtype)
    sign = jnp.sign(v)
    mag = jnp.abs(v)
    order = jnp.argsort(-mag)
    w = mag[order] - lam
    x_sorted = prox_pool(w, use_kernel=use_kernel)
    x = jnp.zeros_like(v).at[order].set(x_sorted.astype(v.dtype))
    return (sign * x).reshape(shape)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
