"""jit'd public wrappers around the Pallas kernels.

Responsibilities: pad to block multiples, pick interpret mode (Pallas TPU
kernels execute via the interpreter on CPU — that is how this container
validates them; on a real TPU ``interpret=False`` compiles to Mosaic),
fall back to the pure-jnp oracle where a kernel's preconditions don't hold
(e.g. prox pooling beyond the VMEM budget).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .prox_sorted_l1 import VMEM_ELEM_LIMIT, prox_pool_kernel_call
from .screen_scan import DEFAULT_BLOCK, screen_scan_kernel_call
from .slope_gemv import (
    DEFAULT_BN,
    DEFAULT_BP,
    xb_loss_residual,
    xb_residual,
    xb_residual_masked,
    xt_matmul,
    xt_matmul_masked,
)

__all__ = [
    "slope_gradient",
    "slope_gradient_masked",
    "slope_residual",
    "slope_residual_masked",
    "slope_loss_residual",
    "screen_scan",
    "prox_pool",
    "prox_sorted_l1_kernel",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("bn", "bp", "use_kernel"))
def slope_gradient(X, R, *, bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                   use_kernel: bool = True):
    """∇f = Xᵀ R.  X (n, p); R (n,) or (n, m) → matches R's rank."""
    squeeze = R.ndim == 1
    R2 = R[:, None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_ref(X, R2)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R2, bn_, 0), 128, 1)
    out = xt_matmul(Xp, Rp, bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:p, : R2.shape[1]]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("bn", "bp", "use_kernel"))
def slope_gradient_masked(X, R, mask, *, bn: int = DEFAULT_BN,
                          bp: int = DEFAULT_BP, use_kernel: bool = True):
    """∇f = (X ⊙ mask)ᵀ R with fully-masked column blocks skipped.

    ``mask`` is a (p,) column mask (bool or 0/1); masked columns' gradient
    rows are exactly 0.  Zero-padded mask columns keep the padding blocks
    dead, so padding adds no compute.
    """
    squeeze = R.ndim == 1
    R2 = R[:, None] if squeeze else R
    if not use_kernel:
        out = _ref.xt_matmul_masked_ref(X, R2, mask)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Rp = _pad_to(_pad_to(R2, bn_, 0), 128, 1)
    Mp = _pad_to(mask.astype(X.dtype)[None, :], bp_, 1)
    out = xt_matmul_masked(Xp, Rp, Mp, bn=bn_, bp=bp_, interpret=_interpret())
    out = out[:p, : R2.shape[1]]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_residual(X, B, Y, *, family: str = "none", bn: int = DEFAULT_BN,
                   bp: int = DEFAULT_BP, use_kernel: bool = True):
    """r = ∂ℓ/∂z at z = X·B, fused GEMV + GLM epilogue."""
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        out = _ref.xb_residual_ref(X, B2, Y2, family)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    out = xb_residual(
        Xp, Bp, Yp, family=family, m_actual=m, bn=bn_, bp=bp_, interpret=_interpret()
    )
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_residual_masked(X, B, Y, mask, *, family: str = "none",
                          bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                          use_kernel: bool = True):
    """r = ∂ℓ/∂z at z = (X ⊙ mask)·B, skipping fully-masked column blocks."""
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        out = _ref.xb_residual_masked_ref(X, B2, Y2, mask, family)
        return out[:, 0] if squeeze else out
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    Mp = _pad_to(mask.astype(X.dtype)[None, :], bp_, 1)
    out = xb_residual_masked(
        Xp, Bp, Yp, Mp, family=family, m_actual=m, bn=bn_, bp=bp_,
        interpret=_interpret(),
    )
    out = out[:n, :m]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("family", "bn", "bp", "use_kernel"))
def slope_loss_residual(X, B, Y, *, family: str = "none", bn: int = DEFAULT_BN,
                        bp: int = DEFAULT_BP, use_kernel: bool = True):
    """(ℓ(z, y), r = ∂ℓ/∂z) at z = X·B in ONE pass over X.

    The fused forward pair a FISTA step needs — the loss is the scalar sum
    over rows, the residual feeds the gradient matvec.
    """
    squeeze = B.ndim == 1
    B2 = B[:, None] if squeeze else B
    Y2 = Y[:, None] if Y.ndim == 1 else Y
    if not use_kernel:
        r, rows = _ref.xb_loss_residual_ref(X, B2, Y2, family)
        return jnp.sum(rows), (r[:, 0] if squeeze else r)
    n, p = X.shape
    m = B2.shape[1]
    bn_ = min(bn, _round_up(n, 8))
    bp_ = min(bp, _round_up(p, 128))
    Xp = _pad_to(_pad_to(X, bn_, 0), bp_, 1)
    Bp = _pad_to(_pad_to(B2, bp_, 0), 128, 1)
    Yp = _pad_to(_pad_to(Y2, bn_, 0), 128, 1)
    r, rows = xb_loss_residual(
        Xp, Bp, Yp, family=family, m_actual=m, bn=bn_, bp=bp_,
        interpret=_interpret(),
    )
    # padded rows see z = 0, y = 0 — nonzero loss for e.g. logistic — so the
    # reduction must slice the real rows first
    loss = jnp.sum(rows[:n, 0])
    r = r[:n, :m]
    return loss, (r[:, 0] if squeeze else r)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel"))
def screen_scan(c, lam, *, block: int = DEFAULT_BLOCK, use_kernel: bool = True):
    """Algorithm-2 screen: k = #kept (c, λ in the sorted order)."""
    if not use_kernel:
        return _ref.screen_scan_ref(c, lam)
    (p,) = c.shape
    blk = min(block, _round_up(p, 128))
    # pad with c − λ = −1: strictly decreasing tail can never host the
    # rightmost argmax, so k is unaffected
    cp = _pad_to(c.astype(jnp.float32), blk, 0, value=-1.0)
    lp = _pad_to(lam.astype(jnp.float32), blk, 0, value=0.0)
    return screen_scan_kernel_call(cp, lp, block=blk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def prox_pool(w, *, use_kernel: bool = True):
    """Non-increasing isotonic projection + clip at 0."""
    if not use_kernel or w.shape[0] > VMEM_ELEM_LIMIT:
        return _ref.prox_pool_ref(w)
    return prox_pool_kernel_call(w, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def prox_sorted_l1_kernel(v, lam, *, use_kernel: bool = True):
    """Full sorted-ℓ1 prox: XLA sort + Pallas pooling + unsort."""
    shape = v.shape
    v = jnp.ravel(v)
    lam = jnp.ravel(lam).astype(v.dtype)
    sign = jnp.sign(v)
    mag = jnp.abs(v)
    order = jnp.argsort(-mag)
    w = mag[order] - lam
    x_sorted = prox_pool(w, use_kernel=use_kernel)
    x = jnp.zeros_like(v).at[order].set(x_sorted.astype(v.dtype))
    return (sign * x).reshape(shape)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
