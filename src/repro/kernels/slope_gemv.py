"""Pallas TPU kernels for the two matvecs that dominate SLOPE solves.

Per FISTA iteration the solver reads X twice: once for the linear predictor
z = X·β (+ the GLM residual epilogue, fused here so z never round-trips
through HBM) and once for the gradient ∇f = Xᵀ·r.  With p ≫ n these GEMVs
are memory-bound on X, so the kernels tile X through VMEM in MXU-aligned
(bn × bp) blocks, accumulate in f32, and stream the small operands (r, β,
y) alongside.

Layouts (m = #classes; 1 for scalar GLMs, padded to the lane width by ops.py):
  xt_matmul:    X (n, p), R (n, m)      → G (p, m)     grid (p/bp, n/bn)
  xb_residual:  X (n, p), B (p, m), Y (n, m) → r (n, m) grid (n/bn, p/bp)

Mask-aware variants (``*_masked``) take a (1, p) column mask alongside X and
skip the MXU work of any (bn × bp) block whose bp-wide mask slice is all
zero — the per-block summary is reduced from the mask tile in VMEM, so a
screened working set of W columns costs ⌈W/bp⌉ column blocks of compute
instead of p/bp.  The block DMA still streams every block, dead or alive.

Block-compacted variants (``*_compact``) close that bandwidth gap: they
take a **live-block index list** (the column blocks whose mask slice has
any survivor, computed on the host from the per-block mask summary) as a
scalar-prefetch operand and remap the Pallas grid through it — the grid's
column axis has exactly ``len(live_idx)`` steps and the ``BlockSpec`` index
maps read ``live_idx[pb]``, so dead (bn × bp) blocks are never DMA'd at
all.  Scalar prefetch makes the indices available before the kernel body
runs, which is what lets Mosaic schedule the remapped DMAs on TPU; on CPU
the same kernels execute in interpret mode (how this container validates
them).  Within a live block the mask still zeroes dead columns, so compact
results are bit-identical to the masked kernels.

``xb_loss_residual`` fuses the loss reduction into the residual epilogue so
one pass over X yields both ℓ(z, y) and r = ∂ℓ/∂z — the pair every FISTA
step needs — instead of two separate streams of X.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "xt_matmul",
    "xt_matmul_masked",
    "xt_matmul_compact",
    "xb_residual",
    "xb_residual_masked",
    "xb_residual_compact",
    "xb_loss_residual",
    "xb_loss_residual_compact",
    "xt_matmul_replicate",
    "xb_residual_replicate",
    "xb_loss_residual_replicate",
    "DEFAULT_BN",
    "DEFAULT_BP",
]

DEFAULT_BN = 256
DEFAULT_BP = 512


def _xt_matmul_kernel(x_ref, r_ref, o_ref, acc_ref):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        r_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # Xᵀ·R without transpose copy
        preferred_element_type=jnp.float32,
    )

    @pl.when(nb == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def xt_matmul(
    X: jax.Array,
    R: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """G = Xᵀ R; shapes (n, p) × (n, m) → (p, m).  Caller pads to blocks."""
    n, p = X.shape
    m = R.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    grid = (p // bp, n // bn)
    return pl.pallas_call(
        _xt_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda pb, nb: (nb, pb)),
            pl.BlockSpec((bn, m), lambda pb, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bp, m), lambda pb, nb: (pb, 0)),
        out_shape=jax.ShapeDtypeStruct((p, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bp, m), jnp.float32)],
        interpret=interpret,
    )(X, R)


def _xt_matmul_masked_kernel(x_ref, r_ref, mask_ref, o_ref, acc_ref):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mb = mask_ref[...]  # (1, bp) — this column block's mask slice
    # per-block summary: a fully-masked (bn × bp) block contributes nothing,
    # so its MXU pass is skipped outright (the strong rule typically leaves
    # W ≪ p columns alive → ⌈W/bp⌉ blocks of compute instead of p/bp)
    @pl.when(jnp.any(mb > 0))
    def _acc():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...] * mb,  # zero masked columns inside kept blocks
            r_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(nb == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def xt_matmul_masked(
    X: jax.Array,
    R: jax.Array,
    mask: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """G = (X ⊙ mask)ᵀ R with fully-masked column blocks skipped.

    ``mask`` is a (1, p) column mask in X's dtype (0/1); masked columns'
    gradient rows come back exactly 0.  Caller pads to blocks.
    """
    n, p = X.shape
    m = R.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    assert mask.shape == (1, p), mask.shape
    grid = (p // bp, n // bn)
    return pl.pallas_call(
        _xt_matmul_masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda pb, nb: (nb, pb)),
            pl.BlockSpec((bn, m), lambda pb, nb: (nb, 0)),
            pl.BlockSpec((1, bp), lambda pb, nb: (0, pb)),
        ],
        out_specs=pl.BlockSpec((bp, m), lambda pb, nb: (pb, 0)),
        out_shape=jax.ShapeDtypeStruct((p, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bp, m), jnp.float32)],
        interpret=interpret,
    )(X, R, mask)


def _epilogue(z, y, family: str, m_actual: int):
    if family == "none":
        return z
    if family == "ols":
        return z - y
    if family == "logistic":
        return jax.nn.sigmoid(z) - y
    if family == "poisson":
        return jnp.exp(z) - y
    if family == "multinomial":
        # mask padded class lanes out of the softmax
        lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, dimension=z.ndim - 1)
        zm = jnp.where(lane < m_actual, z, -jnp.inf)
        sm = jax.nn.softmax(zm, axis=-1)
        return jnp.where(lane < m_actual, sm - y, 0.0)
    raise ValueError(f"unknown family {family!r}")


def _xb_residual_kernel(x_ref, b_ref, y_ref, o_ref, acc_ref, *, family, m_actual):
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        o_ref[...] = _epilogue(z, y_ref[...].astype(jnp.float32), family, m_actual).astype(
            o_ref.dtype
        )


def xb_residual(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """r = ∂ℓ/∂z at z = X·B, fused.  Shapes (n,p) × (p,m), Y (n,m) → (n,m)."""
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    m_actual = m if m_actual is None else m_actual
    grid = (n // bn, p // bp)
    kernel = functools.partial(_xb_residual_kernel, family=family, m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb: (nb, pb)),
            pl.BlockSpec((bp, m), lambda nb, pb: (pb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y)


def _xb_residual_masked_kernel(x_ref, b_ref, y_ref, mask_ref, o_ref, acc_ref,
                               *, family, m_actual):
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mb = mask_ref[...]  # (1, bp)

    @pl.when(jnp.any(mb > 0))
    def _acc():
        acc_ref[...] += jnp.dot(x_ref[...] * mb, b_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        o_ref[...] = _epilogue(z, y_ref[...].astype(jnp.float32), family,
                               m_actual).astype(o_ref.dtype)


def xb_residual_masked(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    mask: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """r = ∂ℓ/∂z at z = (X ⊙ mask)·B, skipping fully-masked column blocks.

    The masked-FISTA invariant (coefficients of masked columns are exactly
    0) makes the mask multiply redundant for solver calls, but the kernel
    applies it anyway so the contract holds for arbitrary ``B``.
    """
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    assert mask.shape == (1, p), mask.shape
    m_actual = m if m_actual is None else m_actual
    grid = (n // bn, p // bp)
    kernel = functools.partial(_xb_residual_masked_kernel, family=family,
                               m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb: (nb, pb)),
            pl.BlockSpec((bp, m), lambda nb, pb: (pb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
            pl.BlockSpec((1, bp), lambda nb, pb: (0, pb)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y, mask)


def _xt_matmul_compact_kernel(live_ref, x_ref, r_ref, mask_ref, o_ref,
                              acc_ref):
    del live_ref  # consumed by the BlockSpec index maps, not the body
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # every visited block is live by construction (the grid is the
    # live-block list); the mask multiply only zeroes dead columns *inside*
    # live blocks, keeping results bit-identical to the masked kernel
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...] * mask_ref[...],
        r_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(nb == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def xt_matmul_compact(
    X: jax.Array,
    R: jax.Array,
    mask: jax.Array,
    live_idx: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """G blocks of (X ⊙ mask)ᵀ R for the live column blocks only.

    ``live_idx`` is a static-length (n_live,) int32 list of column-block
    indices (ascending); it rides in as a scalar-prefetch operand and the
    grid's column axis is remapped through it, so dead (bn × bp) blocks of
    X are neither DMA'd nor computed.  Returns the **compacted**
    ``(n_live·bp, m)`` output — block ``k`` holds the gradient rows of
    column block ``live_idx[k]`` (the ops-layer wrapper scatters them back
    to p-space, dead blocks exactly 0).  Caller pads to blocks.
    """
    n, p = X.shape
    m = R.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    assert mask.shape == (1, p), mask.shape
    n_live = live_idx.shape[0]
    assert n_live >= 1, "use the ops-layer wrapper for all-dead masks"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_live, n // bn),
        in_specs=[
            pl.BlockSpec((bn, bp), lambda pb, nb, live: (nb, live[pb])),
            pl.BlockSpec((bn, m), lambda pb, nb, live: (nb, 0)),
            pl.BlockSpec((1, bp), lambda pb, nb, live: (0, live[pb])),
        ],
        out_specs=pl.BlockSpec((bp, m), lambda pb, nb, live: (pb, 0)),
        scratch_shapes=[pltpu.VMEM((bp, m), jnp.float32)],
    )
    return pl.pallas_call(
        _xt_matmul_compact_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_live * bp, m), X.dtype),
        interpret=interpret,
    )(live_idx, X, R, mask)


def _xb_residual_compact_kernel(live_ref, x_ref, b_ref, y_ref, mask_ref,
                                o_ref, acc_ref, *, family, m_actual):
    del live_ref
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...] * mask_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        o_ref[...] = _epilogue(z, y_ref[...].astype(jnp.float32), family,
                               m_actual).astype(o_ref.dtype)


def xb_residual_compact(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    mask: jax.Array,
    live_idx: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """r = ∂ℓ/∂z at z = (X ⊙ mask)·B over the live column blocks only.

    The accumulation axis is remapped through ``live_idx`` (scalar
    prefetch), so z sums exactly the live blocks' contributions — the same
    partial sums, in the same order, the masked kernel accumulates while
    still streaming every block.  Dead blocks contribute exactly 0 there,
    so skipping their DMA leaves the result bit-identical.
    """
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    assert mask.shape == (1, p), mask.shape
    m_actual = m if m_actual is None else m_actual
    n_live = live_idx.shape[0]
    assert n_live >= 1, "use the ops-layer wrapper for all-dead masks"
    kernel = functools.partial(_xb_residual_compact_kernel, family=family,
                               m_actual=m_actual)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // bn, n_live),
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb, live: (nb, live[pb])),
            pl.BlockSpec((bp, m), lambda nb, pb, live: (live[pb], 0)),
            pl.BlockSpec((bn, m), lambda nb, pb, live: (nb, 0)),
            pl.BlockSpec((1, bp), lambda nb, pb, live: (0, live[pb])),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda nb, pb, live: (nb, 0)),
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), X.dtype),
        interpret=interpret,
    )(live_idx, X, B, Y, mask)


def _xb_loss_residual_compact_kernel(live_ref, x_ref, b_ref, y_ref, mask_ref,
                                     r_ref, loss_ref, acc_ref, *, family,
                                     m_actual):
    del live_ref
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...] * mask_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        y = y_ref[...].astype(jnp.float32)
        r_ref[...] = _epilogue(z, y, family, m_actual).astype(r_ref.dtype)
        rl = _row_loss(z, y, family, m_actual)  # (bn,)
        loss_ref[...] = jnp.broadcast_to(rl[:, None],
                                         loss_ref.shape).astype(loss_ref.dtype)


def xb_loss_residual_compact(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    mask: jax.Array,
    live_idx: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused (r, per-row loss) at z = (X ⊙ mask)·B, live blocks only.

    The compact analogue of :func:`xb_loss_residual`: one remapped pass
    over the live blocks of X yields both halves of the FISTA forward
    pair, with dead-block DMA skipped entirely.
    """
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    assert mask.shape == (1, p), mask.shape
    m_actual = m if m_actual is None else m_actual
    n_live = live_idx.shape[0]
    assert n_live >= 1, "use the ops-layer wrapper for all-dead masks"
    kernel = functools.partial(_xb_loss_residual_compact_kernel,
                               family=family, m_actual=m_actual)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // bn, n_live),
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb, live: (nb, live[pb])),
            pl.BlockSpec((bp, m), lambda nb, pb, live: (live[pb], 0)),
            pl.BlockSpec((bn, m), lambda nb, pb, live: (nb, 0)),
            pl.BlockSpec((1, bp), lambda nb, pb, live: (0, live[pb])),
        ],
        out_specs=[
            pl.BlockSpec((bn, m), lambda nb, pb, live: (nb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb, live: (nb, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, m), X.dtype),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        interpret=interpret,
    )(live_idx, X, B, Y, mask)


# ---------------------------------------------------------------------------
# replicate variants: B row-reweighted problems against ONE shared X
# ---------------------------------------------------------------------------
#
# The resampling engine represents a bootstrap/subsample member as a per-row
# weight vector w_b against the shared (n, p) design, so its matvecs are
#
#     G_b = Xᵀ (w_b ⊙ r_b)         r_b = w_b ⊙ ∂ℓ/∂z at z_b = X·β_b
#
# — the X operand is the SAME array for every member.  These kernels put the
# member axis on the grid and give X a BlockSpec index map that ignores it,
# so X is held once in HBM (O(n·p), not O(B·n·p)) while the per-member
# operands stay O(B·n).  Weights ride in transposed as (n, B) so a member's
# slice is a clean (bn, 1) column block broadcasting against (bn, m) tiles.
# Zero-weight rows are where-guarded to an exact 0 (the same guard as
# ``Family.weighted_residual``), so a w = 0 row can never leak a non-finite
# residual into the sums — and so results are bit-identical to applying the
# guarded weight host-side and calling the unweighted kernels per member.


def _apply_w(w, a):
    """w ⊙ a with zero-weight rows exact 0; w (bn, 1), a (bn, m)."""
    return jnp.where(w == 0, jnp.zeros((), a.dtype), w * a)


def _xt_matmul_replicate_kernel(x_ref, r_ref, w_ref, o_ref, acc_ref):
    nb = pl.program_id(2)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        _apply_w(w_ref[...], r_ref[0]),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(nb == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def xt_matmul_replicate(
    X: jax.Array,
    R: jax.Array,
    W: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """G_b = Xᵀ (w_b ⊙ R_b) for all B members against one shared X.

    Shapes: X (n, p) shared, R (B, n, m) per-member residuals, W (n, B)
    transposed row weights → G (B, p, m).  Per member the block schedule
    (and therefore every partial sum) is exactly :func:`xt_matmul`'s on the
    pre-weighted residual, so results are bit-identical to the materialized
    reference.  Caller pads n/p to blocks.
    """
    n, p = X.shape
    B, n_r, m = R.shape
    assert n_r == n and W.shape == (n, B), (X.shape, R.shape, W.shape)
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    grid = (B, p // bp, n // bn)
    return pl.pallas_call(
        _xt_matmul_replicate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda b, pb, nb: (nb, pb)),  # shared X
            pl.BlockSpec((1, bn, m), lambda b, pb, nb: (b, nb, 0)),
            pl.BlockSpec((bn, 1), lambda b, pb, nb: (nb, b)),
        ],
        out_specs=pl.BlockSpec((1, bp, m), lambda b, pb, nb: (b, pb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, p, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bp, m), jnp.float32)],
        interpret=interpret,
    )(X, R, W)


def _xb_residual_replicate_kernel(x_ref, b_ref, y_ref, w_ref, o_ref, acc_ref,
                                  *, family, m_actual):
    pb = pl.program_id(2)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], b_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(2) - 1)
    def _flush():
        z = acc_ref[...]
        # cast the epilogue to the output dtype BEFORE weighting, so the
        # result is bit-identical to host-weighting the unweighted kernel's
        # output (w stays in its native dtype, as it would host-side)
        r = _epilogue(z, y_ref[0].astype(jnp.float32), family,
                      m_actual).astype(o_ref.dtype)
        o_ref[0] = _apply_w(w_ref[...], r)


def xb_residual_replicate(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    W: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """r_b = w_b ⊙ ∂ℓ/∂z at z_b = X·B_b, one shared X, fused epilogue.

    Shapes: X (n, p), B (Bm, p, m) per-member coefficients, Y (Bm, n, m)
    per-member responses (permutation replicates differ per member; others
    broadcast), W (n, Bm) → r (Bm, n, m) already weighted for the gradient
    matvec.
    """
    n, p = X.shape
    Bm, p_b, m = B.shape
    assert p_b == p and Y.shape == (Bm, n, m) and W.shape == (n, Bm), (
        X.shape, B.shape, Y.shape, W.shape)
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    m_actual = m if m_actual is None else m_actual
    grid = (Bm, n // bn, p // bp)
    kernel = functools.partial(_xb_residual_replicate_kernel, family=family,
                               m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda b, nb, pb: (nb, pb)),  # shared X
            pl.BlockSpec((1, bp, m), lambda b, nb, pb: (b, pb, 0)),
            pl.BlockSpec((1, bn, m), lambda b, nb, pb: (b, nb, 0)),
            pl.BlockSpec((bn, 1), lambda b, nb, pb: (nb, b)),
        ],
        out_specs=pl.BlockSpec((1, bn, m), lambda b, nb, pb: (b, nb, 0)),
        out_shape=jax.ShapeDtypeStruct((Bm, n, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y, W)


def _xb_loss_residual_replicate_kernel(x_ref, b_ref, y_ref, w_ref, r_ref,
                                       loss_ref, acc_ref, *, family,
                                       m_actual):
    pb = pl.program_id(2)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], b_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(2) - 1)
    def _flush():
        z = acc_ref[...]
        y = y_ref[0].astype(jnp.float32)
        w = w_ref[...]
        # epilogue → output dtype first, then native-dtype weighting: bit-
        # identical to host-weighting the unweighted kernel's outputs
        r = _epilogue(z, y, family, m_actual).astype(r_ref.dtype)
        r_ref[0] = _apply_w(w, r)
        rl = _row_loss(z, y, family, m_actual)[:, None]  # (bn, 1) f32
        loss_ref[0] = jnp.broadcast_to(
            _apply_w(w.astype(jnp.float32), rl),
            loss_ref.shape[1:]).astype(loss_ref.dtype)


def xb_loss_residual_replicate(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    W: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused (w ⊙ r, per-row weighted loss) for B members, one shared X.

    The replicate analogue of :func:`xb_loss_residual`: one pass over the
    shared X per member yields both halves of that member's FISTA forward
    pair — ``loss_rows[b, i]`` carries ``w_{b,i}·ℓ(z_{b,i}, y_{b,i})``
    broadcast across lanes (sum lane 0 over un-padded rows for the
    member's weighted loss).
    """
    n, p = X.shape
    Bm, p_b, m = B.shape
    assert p_b == p and Y.shape == (Bm, n, m) and W.shape == (n, Bm), (
        X.shape, B.shape, Y.shape, W.shape)
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    m_actual = m if m_actual is None else m_actual
    grid = (Bm, n // bn, p // bp)
    kernel = functools.partial(_xb_loss_residual_replicate_kernel,
                               family=family, m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda b, nb, pb: (nb, pb)),  # shared X
            pl.BlockSpec((1, bp, m), lambda b, nb, pb: (b, pb, 0)),
            pl.BlockSpec((1, bn, m), lambda b, nb, pb: (b, nb, 0)),
            pl.BlockSpec((bn, 1), lambda b, nb, pb: (nb, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn, m), lambda b, nb, pb: (b, nb, 0)),
            pl.BlockSpec((1, bn, m), lambda b, nb, pb: (b, nb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bm, n, m), X.dtype),
            jax.ShapeDtypeStruct((Bm, n, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y, W)


def _row_loss(z, y, family: str, m_actual: int):
    """Per-row loss ℓ(z_i, y_i) from the same z the epilogue consumes.

    Padded class lanes (≥ m_actual) are masked out so ops.py's 128-lane
    padding contributes exactly 0 to the loss.
    """
    lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, dimension=z.ndim - 1)
    lm = lane < m_actual
    if family == "none":
        return jnp.zeros(z.shape[:-1], z.dtype)
    if family == "ols":
        per = 0.5 * jnp.square(z - y)
    elif family == "logistic":
        per = jnp.logaddexp(0.0, z) - y * z
    elif family == "poisson":
        per = jnp.exp(z) - y * z
    elif family == "multinomial":
        zm = jnp.where(lm, z, -jnp.inf)
        lse = jax.nn.logsumexp(zm, axis=-1)
        return lse - jnp.sum(jnp.where(lm, y * z, 0.0), axis=-1)
    else:
        raise ValueError(f"unknown family {family!r}")
    return jnp.sum(jnp.where(lm, per, 0.0), axis=-1)


def _xb_loss_residual_kernel(x_ref, b_ref, y_ref, r_ref, loss_ref, acc_ref,
                             *, family, m_actual):
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        y = y_ref[...].astype(jnp.float32)
        r_ref[...] = _epilogue(z, y, family, m_actual).astype(r_ref.dtype)
        rl = _row_loss(z, y, family, m_actual)  # (bn,)
        loss_ref[...] = jnp.broadcast_to(rl[:, None],
                                         loss_ref.shape).astype(loss_ref.dtype)


def xb_loss_residual(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One pass over X → (r, per-row loss); the FISTA forward pair fused.

    Returns ``(r (n, m), loss_rows (n, m))`` — each row of ``loss_rows``
    carries ℓ(z_i, y_i) broadcast across lanes; callers sum lane 0 over the
    un-padded rows.  Reads X once where loss + gradient previously streamed
    it twice.
    """
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    m_actual = m if m_actual is None else m_actual
    grid = (n // bn, p // bp)
    kernel = functools.partial(_xb_loss_residual_kernel, family=family,
                               m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb: (nb, pb)),
            pl.BlockSpec((bp, m), lambda nb, pb: (pb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), X.dtype),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y)
