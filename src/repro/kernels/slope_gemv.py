"""Pallas TPU kernels for the two matvecs that dominate SLOPE solves.

Per FISTA iteration the solver reads X twice: once for the linear predictor
z = X·β (+ the GLM residual epilogue, fused here so z never round-trips
through HBM) and once for the gradient ∇f = Xᵀ·r.  With p ≫ n these GEMVs
are memory-bound on X, so the kernels tile X through VMEM in MXU-aligned
(bn × bp) blocks, accumulate in f32, and stream the small operands (r, β,
y) alongside.

Layouts (m = #classes; 1 for scalar GLMs, padded to the lane width by ops.py):
  xt_matmul:    X (n, p), R (n, m)      → G (p, m)     grid (p/bp, n/bn)
  xb_residual:  X (n, p), B (p, m), Y (n, m) → r (n, m) grid (n/bn, p/bp)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["xt_matmul", "xb_residual", "DEFAULT_BN", "DEFAULT_BP"]

DEFAULT_BN = 256
DEFAULT_BP = 512


def _xt_matmul_kernel(x_ref, r_ref, o_ref, acc_ref):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        r_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),  # Xᵀ·R without transpose copy
        preferred_element_type=jnp.float32,
    )

    @pl.when(nb == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def xt_matmul(
    X: jax.Array,
    R: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """G = Xᵀ R; shapes (n, p) × (n, m) → (p, m).  Caller pads to blocks."""
    n, p = X.shape
    m = R.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    grid = (p // bp, n // bn)
    return pl.pallas_call(
        _xt_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda pb, nb: (nb, pb)),
            pl.BlockSpec((bn, m), lambda pb, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bp, m), lambda pb, nb: (pb, 0)),
        out_shape=jax.ShapeDtypeStruct((p, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bp, m), jnp.float32)],
        interpret=interpret,
    )(X, R)


def _epilogue(z, y, family: str, m_actual: int):
    if family == "none":
        return z
    if family == "ols":
        return z - y
    if family == "logistic":
        return jax.nn.sigmoid(z) - y
    if family == "poisson":
        return jnp.exp(z) - y
    if family == "multinomial":
        # mask padded class lanes out of the softmax
        lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, dimension=z.ndim - 1)
        zm = jnp.where(lane < m_actual, z, -jnp.inf)
        sm = jax.nn.softmax(zm, axis=-1)
        return jnp.where(lane < m_actual, sm - y, 0.0)
    raise ValueError(f"unknown family {family!r}")


def _xb_residual_kernel(x_ref, b_ref, y_ref, o_ref, acc_ref, *, family, m_actual):
    pb = pl.program_id(1)

    @pl.when(pb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pb == pl.num_programs(1) - 1)
    def _flush():
        z = acc_ref[...]
        o_ref[...] = _epilogue(z, y_ref[...].astype(jnp.float32), family, m_actual).astype(
            o_ref.dtype
        )


def xb_residual(
    X: jax.Array,
    B: jax.Array,
    Y: jax.Array,
    *,
    family: str = "none",
    m_actual: int | None = None,
    bn: int = DEFAULT_BN,
    bp: int = DEFAULT_BP,
    interpret: bool = False,
) -> jax.Array:
    """r = ∂ℓ/∂z at z = X·B, fused.  Shapes (n,p) × (p,m), Y (n,m) → (n,m)."""
    n, p = X.shape
    m = B.shape[1]
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    m_actual = m if m_actual is None else m_actual
    grid = (n // bn, p // bp)
    kernel = functools.partial(_xb_residual_kernel, family=family, m_actual=m_actual)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda nb, pb: (nb, pb)),
            pl.BlockSpec((bp, m), lambda nb, pb: (pb, 0)),
            pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda nb, pb: (nb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), X.dtype),
        scratch_shapes=[pltpu.VMEM((bn, m), jnp.float32)],
        interpret=interpret,
    )(X, B, Y)
