"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and ``assert_allclose`` against the
corresponding function here.  These are also the implementations used when
running on a backend without Pallas support (dispatch in :mod:`ops`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["xt_matmul_ref", "xb_residual_ref", "screen_scan_ref", "prox_pool_ref"]


def xt_matmul_ref(X: jax.Array, R: jax.Array) -> jax.Array:
    """Gradient matvec: ∇f = Xᵀ R  with X (n, p), R (n, m) → (p, m)."""
    return jnp.einsum(
        "np,nm->pm", X, R, preferred_element_type=jnp.promote_types(X.dtype, jnp.float32)
    ).astype(X.dtype)


def _epilogue(z: jax.Array, y: jax.Array, family: str) -> jax.Array:
    if family == "none":
        return z
    if family == "ols":
        return z - y
    if family == "logistic":
        return jax.nn.sigmoid(z) - y
    if family == "poisson":
        return jnp.exp(z) - y
    if family == "multinomial":
        # y carries one-hot targets (n, m) so the kernel stays elementwise
        return jax.nn.softmax(z, axis=-1) - y
    raise ValueError(f"unknown family {family!r}")


def xb_residual_ref(X: jax.Array, B: jax.Array, y: jax.Array, family: str = "none") -> jax.Array:
    """Fused z = X·B followed by the GLM residual r = ∂ℓ/∂z (n, m).

    ``y`` is (n, m): the observed response broadcast per class column
    (one-hot for multinomial).  family='none' returns z itself.
    """
    z = jnp.einsum(
        "np,pm->nm", X, B, preferred_element_type=jnp.promote_types(X.dtype, jnp.float32)
    ).astype(X.dtype)
    return _epilogue(z, y, family).astype(X.dtype)


def screen_scan_ref(c: jax.Array, lam: jax.Array) -> jax.Array:
    """Closed-form Algorithm 2: k = rightmost argmax of cumsum(c−λ) if ≥ 0."""
    s = jnp.cumsum(c.astype(jnp.float32) - lam.astype(jnp.float32))
    p = s.shape[0]
    k = (p - jnp.argmax(s[::-1])).astype(jnp.int32)
    return jnp.where(jnp.max(s) >= 0, k, jnp.int32(0))


def prox_pool_ref(w: jax.Array) -> jax.Array:
    """Non-increasing isotonic projection + clip at 0 (the PAVA stage of the
    sorted-ℓ1 prox; input is |v| sorted decreasing minus λ)."""
    from repro.core.sorted_l1 import isotonic_decreasing

    return jnp.maximum(isotonic_decreasing(w), 0).astype(w.dtype)
