"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and ``assert_allclose`` against the
corresponding function here.  These are also the implementations used when
running on a backend without Pallas support (dispatch in :mod:`ops`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "xt_matmul_ref",
    "xt_matmul_masked_ref",
    "xt_matmul_compact_ref",
    "xb_residual_ref",
    "xb_residual_masked_ref",
    "xb_residual_compact_ref",
    "xb_loss_residual_ref",
    "xb_loss_residual_compact_ref",
    "xt_matmul_replicate_ref",
    "xb_residual_replicate_ref",
    "xb_loss_residual_replicate_ref",
    "screen_scan_ref",
    "prox_pool_ref",
]


def _apply_w_ref(w: jax.Array, a: jax.Array) -> jax.Array:
    """w ⊙ a (per-row weights against a row-major block) with zero-weight
    rows guarded to an exact 0 — the ``Family.weighted_residual`` guard."""
    wb = w if a.ndim == w.ndim else w[..., None]
    return jnp.where(wb == 0, jnp.zeros((), a.dtype), wb * a)


def xt_matmul_ref(X: jax.Array, R: jax.Array) -> jax.Array:
    """Gradient matvec: ∇f = Xᵀ R  with X (n, p), R (n, m) → (p, m)."""
    return jnp.einsum(
        "np,nm->pm", X, R, preferred_element_type=jnp.promote_types(X.dtype, jnp.float32)
    ).astype(X.dtype)


def xt_matmul_masked_ref(X: jax.Array, R: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked gradient matvec: (X ⊙ mask)ᵀ R; ``mask`` is a (p,) column mask."""
    return xt_matmul_ref(X * mask.astype(X.dtype)[None, :], R)


# The block-compacted kernels are an *execution* strategy, not new math:
# skipping the DMA of a dead (bn × bp) block must not change a single bit
# of the result.  Their oracles are therefore exactly the masked ones —
# the kernel tests pin compact == masked == oracle at every block pattern.
xt_matmul_compact_ref = xt_matmul_masked_ref


def _epilogue(z: jax.Array, y: jax.Array, family: str) -> jax.Array:
    if family == "none":
        return z
    if family == "ols":
        return z - y
    if family == "logistic":
        return jax.nn.sigmoid(z) - y
    if family == "poisson":
        return jnp.exp(z) - y
    if family == "multinomial":
        # y carries one-hot targets (n, m) so the kernel stays elementwise
        return jax.nn.softmax(z, axis=-1) - y
    raise ValueError(f"unknown family {family!r}")


def xb_residual_ref(X: jax.Array, B: jax.Array, y: jax.Array, family: str = "none") -> jax.Array:
    """Fused z = X·B followed by the GLM residual r = ∂ℓ/∂z (n, m).

    ``y`` is (n, m): the observed response broadcast per class column
    (one-hot for multinomial).  family='none' returns z itself.
    """
    z = jnp.einsum(
        "np,pm->nm", X, B, preferred_element_type=jnp.promote_types(X.dtype, jnp.float32)
    ).astype(X.dtype)
    return _epilogue(z, y, family).astype(X.dtype)


def xb_residual_masked_ref(X: jax.Array, B: jax.Array, y: jax.Array,
                           mask: jax.Array, family: str = "none") -> jax.Array:
    """Masked residual: r at z = (X ⊙ mask)·B; ``mask`` is a (p,) column mask."""
    return xb_residual_ref(X * mask.astype(X.dtype)[None, :], B, y, family)


xb_residual_compact_ref = xb_residual_masked_ref  # see xt_matmul_compact_ref


def xb_loss_residual_compact_ref(X: jax.Array, B: jax.Array, y: jax.Array,
                                 mask: jax.Array, family: str = "none"):
    """Masked fused forward pair — the oracle for the block-compacted
    loss+residual kernel (see :data:`xt_matmul_compact_ref`)."""
    return xb_loss_residual_ref(X * mask.astype(X.dtype)[None, :], B, y,
                                family)


def _row_loss(z: jax.Array, y: jax.Array, family: str) -> jax.Array:
    if family == "none":
        return jnp.zeros(z.shape[:-1], z.dtype)
    if family == "ols":
        return jnp.sum(0.5 * jnp.square(z - y), axis=-1)
    if family == "logistic":
        return jnp.sum(jnp.logaddexp(0.0, z) - y * z, axis=-1)
    if family == "poisson":
        return jnp.sum(jnp.exp(z) - y * z, axis=-1)
    if family == "multinomial":
        return jax.nn.logsumexp(z, axis=-1) - jnp.sum(y * z, axis=-1)
    raise ValueError(f"unknown family {family!r}")


def xb_loss_residual_ref(X: jax.Array, B: jax.Array, y: jax.Array,
                         family: str = "none") -> tuple[jax.Array, jax.Array]:
    """Fused forward pair: (r = ∂ℓ/∂z, per-row loss ℓ(z_i, y_i)) at z = X·B."""
    z = jnp.einsum(
        "np,pm->nm", X, B, preferred_element_type=jnp.promote_types(X.dtype, jnp.float32)
    ).astype(X.dtype)
    return _epilogue(z, y, family).astype(X.dtype), _row_loss(z, y, family)


# The replicate oracles are the *materialized* reference the weight-fused
# kernels are bit-identity-tested against: per member, weight the small
# (n, m) operand host-side (zero-guarded, native dtype) and call the plain
# unweighted oracle against the shared X — which is exactly what a
# materialized (B, n, p) execution computes, without ever building it.


def xt_matmul_replicate_ref(X: jax.Array, R: jax.Array,
                            W: jax.Array) -> jax.Array:
    """G_b = Xᵀ (w_b ⊙ R_b); X (n, p), R (B, n, m), W (B, n) → (B, p, m)."""
    return jax.vmap(lambda r, w: xt_matmul_ref(X, _apply_w_ref(w, r)))(R, W)


def xb_residual_replicate_ref(X: jax.Array, B: jax.Array, Y: jax.Array,
                              W: jax.Array, family: str = "none") -> jax.Array:
    """r_b = w_b ⊙ ∂ℓ/∂z at z_b = X·B_b; B (Bm, p, m), Y (Bm, n, m),
    W (Bm, n) → (Bm, n, m)."""
    return jax.vmap(
        lambda b, y, w: _apply_w_ref(w, xb_residual_ref(X, b, y, family)))(
            B, Y, W)


def xb_loss_residual_replicate_ref(X: jax.Array, B: jax.Array, Y: jax.Array,
                                   W: jax.Array, family: str = "none"):
    """Per-member fused pair: (w_b ⊙ r_b, w_b ⊙ per-row losses)."""

    def one(b, y, w):
        r, rows = xb_loss_residual_ref(X, b, y, family)
        return _apply_w_ref(w, r), _apply_w_ref(w.astype(rows.dtype), rows)

    return jax.vmap(one)(B, Y, W)


def screen_scan_ref(c: jax.Array, lam: jax.Array) -> jax.Array:
    """Closed-form Algorithm 2: k = rightmost argmax of cumsum(c−λ) if ≥ 0."""
    s = jnp.cumsum(c.astype(jnp.float32) - lam.astype(jnp.float32))
    p = s.shape[0]
    k = (p - jnp.argmax(s[::-1])).astype(jnp.int32)
    return jnp.where(jnp.max(s) >= 0, k, jnp.int32(0))


def prox_pool_ref(w: jax.Array) -> jax.Array:
    """Non-increasing isotonic projection + clip at 0 (the PAVA stage of the
    sorted-ℓ1 prox; input is |v| sorted decreasing minus λ)."""
    from repro.core.sorted_l1 import isotonic_decreasing

    return jnp.maximum(isotonic_decreasing(w), 0).astype(w.dtype)
