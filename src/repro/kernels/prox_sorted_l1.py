"""Pallas kernel for the pooling stage of the sorted-ℓ1 prox.

The prox (FastProxSL1) is sort → subtract λ → PAVA (non-increasing) → clip.
The sort stays in XLA (`jax.lax.sort` is already systolic-sort optimal on
TPU); this kernel keeps the PAVA pooling entirely VMEM-resident: input,
block stack (sums/counts) and output never touch HBM between passes.  PAVA
is inherently sequential (each push may pool with earlier blocks), so the
kernel is a single-program scan — its value on TPU is locality, not
parallelism; we document this honestly and bound applicability to
p ≤ ~5·10⁵ f32 (VMEM).  ops.py falls back to the lax.while_loop version
beyond that.

Implementation note: ``lax.while_loop`` *cond* functions must not read Refs
(state discharge evaluates them against a snapshot), so both loops carry a
continue-flag computed inside the body — do-while style.

Pass 1 (stack build):    one push per element, amortised one pool per push.
Pass 2 (expansion):      two-pointer sweep writing block means, clipped at 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["prox_pool_kernel_call", "VMEM_ELEM_LIMIT"]

VMEM_ELEM_LIMIT = 512 * 1024


def _load1(ref, i):
    return pl.load(ref, (pl.ds(i, 1),))[0]


def _store1(ref, i, val, dtype=jnp.float32):
    pl.store(ref, (pl.ds(i, 1),), jnp.full((1,), val, dtype))


def _prox_pool_kernel(w_ref, o_ref, sums_ref, counts_ref):
    p = w_ref.shape[0]

    def push(i, top):
        w_i = _load1(w_ref, i).astype(jnp.float32)

        # current (not yet stored) block rides in the carry; pool downward
        # while it violates monotonicity against the stored block below
        def body(carry):
            t, s, c, _ = carry
            below = jnp.maximum(t - 1, 0)
            s_p = _load1(sums_ref, below)
            c_p = _load1(counts_ref, below)
            do_pool = (t > 0) & (s * c_p >= s_p * c)
            s = jnp.where(do_pool, s + s_p, s)
            c = jnp.where(do_pool, c + c_p, c)
            t = jnp.where(do_pool, t - 1, t)
            return t, s, c, do_pool

        def cond(carry):
            return carry[3]

        t, s, c, _ = lax.while_loop(
            cond, body, (top, w_i, jnp.float32(1.0), jnp.bool_(True))
        )
        _store1(sums_ref, t, s)
        _store1(counts_ref, t, c)
        return t + 1

    lax.fori_loop(0, p, push, 0)

    # Pass 2: expand block means.  (block index b, elements consumed) sweep.
    def emit(i, carry):
        b, consumed = carry

        def body(carry):
            b, consumed, _ = carry
            cnt = _load1(counts_ref, b).astype(jnp.int32)
            adv = i >= consumed + cnt
            b = jnp.where(adv, b + 1, b)
            consumed = jnp.where(adv, consumed + cnt, consumed)
            return b, consumed, adv

        def cond(carry):
            return carry[2]

        b, consumed, _ = lax.while_loop(cond, body, (b, consumed, jnp.bool_(True)))
        val = jnp.maximum(_load1(sums_ref, b) / _load1(counts_ref, b), 0.0)
        _store1(o_ref, i, val, o_ref.dtype)
        return b, consumed

    lax.fori_loop(0, p, emit, (0, 0))


def prox_pool_kernel_call(w: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Non-increasing isotonic projection of ``w`` clipped at 0."""
    (p,) = w.shape
    return pl.pallas_call(
        _prox_pool_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((p,), lambda _: (0,))],
        out_specs=pl.BlockSpec((p,), lambda _: (0,)),
        out_shape=jax.ShapeDtypeStruct((p,), w.dtype),
        scratch_shapes=[
            pltpu.VMEM((p,), jnp.float32),
            pltpu.VMEM((p,), jnp.float32),
        ],
        interpret=interpret,
    )(w)
