"""Pallas TPU kernel for the strong-rule screen (paper Algorithm 2).

Uses the closed form derived in DESIGN.md §1: with s = cumsum(c − λ),
k = rightmost argmax of s when max(s) ≥ 0, else 0.  The kernel streams
(c, λ) through VMEM in blocks, carrying three scalars across the sequential
TPU grid: the running total of (c − λ), the best (rightmost-max) cumsum
value, and its global index.  One pass, O(p) HBM traffic — the screen is
bandwidth-bound by construction, matching the paper's "cheaper than one
gradient step" claim.

Caller pads the tail with c − λ = −1 (strictly decreasing ⇒ never the
rightmost argmax) — see ops.screen_scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["screen_scan_kernel_call", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 2048


def _screen_kernel(c_ref, lam_ref, o_ref, total_ref, best_ref, idx_ref):
    b = pl.program_id(0)
    bp = c_ref.shape[0]

    @pl.when(b == 0)
    def _init():
        # explicit f32: under jax_enable_x64 bare Python literals are weak
        # f64 and cannot be stored into the f32 SMEM scratch
        total_ref[0] = jnp.float32(0.0)
        best_ref[0] = jnp.float32(-jnp.inf)
        idx_ref[0] = jnp.int32(0)

    d = c_ref[...].astype(jnp.float32) - lam_ref[...].astype(jnp.float32)
    s = jnp.cumsum(d) + total_ref[0]

    # rightmost local argmax: first max of the reversed prefix sums
    rev = s[::-1]
    j = jnp.argmax(rev)
    local_best = rev[j]
    local_idx = b * bp + (bp - 1 - j.astype(jnp.int32))

    better = local_best >= best_ref[0]  # ≥ keeps the *rightmost* on ties
    best_ref[0] = jnp.where(better, local_best, best_ref[0])
    idx_ref[0] = jnp.where(better, local_idx, idx_ref[0])
    total_ref[0] = total_ref[0] + jnp.sum(d)

    @pl.when(b == pl.num_programs(0) - 1)
    def _finish():
        k = jnp.where(best_ref[0] >= 0, idx_ref[0] + 1, 0)
        o_ref[0] = k.astype(jnp.int32)


def screen_scan_kernel_call(
    c: jax.Array, lam: jax.Array, *, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """k for pre-padded inputs (length divisible by ``block``)."""
    (p,) = c.shape
    assert p % block == 0, (p, block)
    return pl.pallas_call(
        _screen_kernel,
        grid=(p // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((block,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(c, lam)[0]
