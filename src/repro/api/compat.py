"""Legacy-kwarg deprecation plumbing for the PR-1..3 entry points.

The old string knobs (``engine=``, ``pad=``, ``working_set=``,
``cv_path(stratify=..., selection=...)``) keep working — the shims in
:mod:`repro.core.path` / :mod:`repro.core.engine` translate them into
(:class:`~repro.api.specs.Problem`, :class:`~repro.api.specs.PathSpec`,
:class:`~repro.api.specs.SolverPolicy`) triples — but each one warns
exactly ONCE per process per (function, kwarg) pair.  Python's default
warning filters dedupe per call site, which hides repeat offenders in
loops and spams distinct ones; one warning per knob is the contract the
shim tests pin (``tests/test_api.py``).
"""

from __future__ import annotations

import threading
import warnings

__all__ = ["warn_legacy", "reset_legacy_warnings", "UNSET"]

# sentinel distinguishing "caller never passed this kwarg" from an explicit
# legacy value (the legacy defaults themselves must not warn)
UNSET = object()

_WARNED: set[tuple[str, str]] = set()
_LOCK = threading.Lock()


def warn_legacy(func: str, kwarg: str, replacement: str) -> None:
    """Emit one DeprecationWarning per (func, kwarg) per process."""
    key = (func, kwarg)
    with _LOCK:
        if key in _WARNED:
            return
        _WARNED.add(key)
    warnings.warn(
        f"{func}({kwarg}=...) is deprecated; express it as {replacement} and "
        f"call repro.api.slope_path (see docs/MIGRATION.md)",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_legacy_warnings() -> None:
    """Forget which legacy kwargs already warned (test isolation hook)."""
    with _LOCK:
        _WARNED.clear()
