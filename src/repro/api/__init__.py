"""repro.api — the declarative front door for SLOPE path fitting.

One spec triple describes any fit this repo can run::

    from repro.api import Problem, PathSpec, LambdaSpec, SolverPolicy, slope_path

    problem = Problem(X, y, family=ols)            # data + family (+ weights)
    spec = PathSpec(lam=LambdaSpec("bh", q=0.1))   # penalty + σ grid + CV
    policy = SolverPolicy()                        # backend="auto" → planned

    print(plan_execution(problem, spec, policy).explain())  # why each choice
    res = slope_path(problem, spec, policy)        # PathResult / Batched / Cv

The planner (:mod:`repro.api.plan`) resolves ``"auto"`` knobs into an
explicit :class:`ExecutionPlan`; :class:`SlopE` wraps the same machinery in
estimator-style ``fit``/``predict``/``coef_``.  The legacy entry points
(``repro.core.fit_path`` / ``fit_path_batched`` / ``cv_path`` and
``PathService.submit(X, y, ...)``) are thin shims over this layer — old
kwargs keep working bit-identically and warn once per knob (see
``docs/MIGRATION.md`` for the mapping).
"""

from ..resample import ResamplePlan
from .estimator import SlopE
from .fit import default_async_service, default_service, slope_path
from .plan import ExecutionPlan, plan_execution
from .specs import (
    LambdaSpec,
    PathSpec,
    Problem,
    SolverPolicy,
    ValidationError,
    as_lambda_spec,
    find_nonfinite,
    shared_canonicalizer,
)

__all__ = [
    "Problem",
    "LambdaSpec",
    "PathSpec",
    "SolverPolicy",
    "ResamplePlan",
    "ValidationError",
    "ExecutionPlan",
    "plan_execution",
    "slope_path",
    "SlopE",
    "as_lambda_spec",
    "default_service",
    "default_async_service",
    "find_nonfinite",
    "shared_canonicalizer",
]
