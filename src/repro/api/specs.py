"""Declarative specs for the one-front-door SLOPE API.

Four immutable, pytree-registered dataclasses describe a fit completely:

* :class:`Problem` — the data: ``X``, ``y``, GLM family, optional sample
  weights.  ``X`` may be ``(n, p)`` (one problem) or ``(B, n, p)`` (a batch
  of same-shape problems).
* :class:`LambdaSpec` — the penalty *sequence*: a named recipe
  (``bh`` / ``gaussian`` / ``oscar`` / ``lasso``) with its parameter, or an
  explicit array.  Named specs resolve through one process-wide memoised
  :class:`~repro.serve.batcher.LambdaCanonicalizer` (absorbed from the
  serve layer), so equal specs map to the same immutable bytes everywhere —
  direct calls and served requests build byte-equal operands.
* :class:`PathSpec` — the path: λ spec, grid length/ratio or explicit σ
  grid, early stopping, and the CV block (folds / stratify / selection).
* :class:`SolverPolicy` — *how* to execute: backend (``"auto"`` resolves
  through :func:`repro.api.plan.plan_execution`), compact working-set
  sizing, canonical-bucket padding, screening mode and solver tolerances.

Everything here is declarative — no array math happens until
:func:`repro.api.fit.slope_path` executes a resolved
:class:`~repro.api.plan.ExecutionPlan`.  The pytree registration makes the
specs legal jit/static carriers: array-valued fields (``X``, ``y``,
``weights``, explicit λ values, explicit σ grids) are leaves, everything
else is auxiliary data.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax

from ..core.losses import Family, ols
from ..core.solver import (
    DEFAULT_KKT_TOL,
    DEFAULT_MAX_REFITS,
    DEFAULT_PATH_MAX_ITER,
    DEFAULT_PATH_TOL,
    DEFAULT_WS_TIERS,
)
from ..resample.plans import ResamplePlan
from ..serve.batcher import LambdaCanonicalizer, lambda_kinds

__all__ = [
    "Problem",
    "LambdaSpec",
    "PathSpec",
    "SolverPolicy",
    "ValidationError",
    "as_lambda_spec",
    "apply_weights",
    "check_weights",
    "find_nonfinite",
    "shared_canonicalizer",
]

_NAMED_KINDS = lambda_kinds()

# the ONE process-wide named-λ memo table: LambdaSpec.resolve() and the
# PathService default both canonicalize through this instance, so a named
# sequence is generated once and shared byte-for-byte by every consumer
_SHARED_CANONICALIZER = LambdaCanonicalizer()


def shared_canonicalizer() -> LambdaCanonicalizer:
    """The process-wide named-λ-sequence memo shared by specs and serving."""
    return _SHARED_CANONICALIZER


def _shape_of(x) -> tuple | None:
    s = getattr(x, "shape", None)
    return None if s is None else tuple(s)


class ValidationError(ValueError):
    """Structured admission-time rejection: non-finite operands.

    ``issues`` is a tuple of ``(name, count, first_index)`` triples — one
    per offending array — so callers can report *which* operand is
    poisoned and where, instead of parsing a message string.  Raised
    host-side under ``validate="strict"`` (the default) before any device
    work is scheduled; ``validate="quarantine"`` admits the request and
    lets the engine's in-graph health word flag it instead.
    """

    def __init__(self, issues):
        self.issues = tuple(issues)
        parts = ", ".join(
            f"{name}: {count} non-finite value(s), first at flat index {idx}"
            for name, count, idx in self.issues)
        super().__init__(f"non-finite input rejected ({parts}); pass "
                         f"validate='quarantine' to admit and flag in-graph, "
                         f"or validate='off' to skip host-side checks")


def find_nonfinite(**arrays) -> tuple[tuple[str, int, int], ...]:
    """Scan named arrays for NaN/Inf: ``(name, count, first_flat_index)``
    per offender, empty when all finite.  ``None`` values are skipped."""
    issues = []
    for name, arr in arrays.items():
        if arr is None:
            continue
        a = np.asarray(arr)
        if not np.issubdtype(a.dtype, np.number):
            continue
        bad = ~np.isfinite(a)
        n = int(bad.sum())
        if n:
            issues.append((name, n, int(np.flatnonzero(bad.reshape(-1))[0])))
    return tuple(issues)


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """One fit problem (or a same-shape batch of them), family included.

    ``weights`` are per-row sample weights (OLS only — they fold into the
    quadratic loss exactly as row scaling by √w; other families have no
    such reduction and raise at execution time).
    """

    X: Any
    y: Any
    family: Family = ols
    weights: Any = None

    def __post_init__(self):
        for f in ("X", "y", "weights"):  # legacy entry points accept lists
            v = getattr(self, f)
            if isinstance(v, (list, tuple)):
                object.__setattr__(self, f, np.asarray(v))
        xs, ys = _shape_of(self.X), _shape_of(self.y)
        if xs is None or ys is None:  # pytree unflatten mid-transform
            return
        if len(xs) not in (2, 3):
            raise ValueError(f"X must be (n, p) or (B, n, p), got {xs}")
        lead = len(xs) - 1
        if tuple(ys[:lead]) != xs[:lead]:
            raise ValueError(
                f"y must be ({', '.join(str(d) for d in xs[:lead])}[, ...]) "
                f"matching X {xs}, got {ys}")
        ws = _shape_of(self.weights)
        if ws is not None and tuple(ws) != (xs[-2],):
            raise ValueError(
                f"weights must be one value per row ({xs[-2]},), got {ws}")

    def check_finite(self) -> None:
        """Raise :class:`ValidationError` if X/y/weights hold NaN/Inf."""
        issues = find_nonfinite(X=self.X, y=self.y, weights=self.weights)
        if issues:
            raise ValidationError(issues)

    @property
    def batched(self) -> bool:
        return len(_shape_of(self.X)) == 3

    @property
    def batch(self) -> int:
        xs = _shape_of(self.X)
        return xs[0] if len(xs) == 3 else 1

    @property
    def n(self) -> int:
        return _shape_of(self.X)[-2]

    @property
    def p(self) -> int:
        return _shape_of(self.X)[-1]


def check_weights(problem: Problem) -> np.ndarray:
    """Validate ``problem.weights`` and return them as an (n,) array.

    The ONE admission gate every weighted execution route shares — the
    √w-scaling host path, the device per-member row-weight path, and
    weighted resampling — so they reject identically: OLS only (no exact
    reduction exists for the other GLM losses), strictly positive.
    """
    X = np.asarray(problem.X)
    if problem.family.name != "ols":
        raise ValueError(
            "sample weights are currently supported for the OLS family only "
            f"(got {problem.family.name!r}); no exact row-scaling reduction "
            "exists for the other GLM losses")
    w = np.asarray(problem.weights, dtype=X.dtype)
    if (w <= 0).any():
        raise ValueError("sample weights must be strictly positive")
    return w


def apply_weights(problem: Problem):
    """Materialise ``problem.weights`` into transformed ``(X, y)`` arrays.

    OLS only: ``0.5·Σ wᵢ(xᵢβ − yᵢ)²`` is exactly the unweighted loss on
    ``(√w·X, √w·y)``, so the whole path stack (screening, KKT, deviances)
    applies unchanged to the scaled data.  Returns ``(X, y)`` untouched when
    no weights are set.  This is the *host/batched* weighting route; the
    device engines instead thread ``check_weights`` output through the
    replicate row-weight path (no X copy — see ``repro.resample``).
    """
    X = np.asarray(problem.X)
    y = np.asarray(problem.y)
    if problem.weights is None:
        return X, y
    sw = np.sqrt(check_weights(problem))
    return (X * sw.reshape((1,) * (X.ndim - 2) + (-1, 1)),
            y * sw.reshape((1,) * (y.ndim - 1) + (-1,)))


@dataclasses.dataclass(frozen=True, eq=False)
class LambdaSpec:
    """A penalty sequence by name (+ parameter) or by explicit values.

    ``kind`` is one of ``"bh"`` / ``"gaussian"`` / ``"oscar"`` /
    ``"lasso"`` / ``"explicit"``; ``q`` parameterizes the named recipes
    (ignored by ``lasso``); ``values`` holds the array for ``"explicit"``.
    """

    kind: str = "bh"
    q: float = 0.1
    values: Any = None

    def __post_init__(self):
        if self.kind not in _NAMED_KINDS + ("explicit",):
            raise ValueError(
                f"unknown λ sequence {self.kind!r}; choose from "
                f"{sorted(_NAMED_KINDS)} or 'explicit'")
        if self.kind == "explicit" and self.values is None:
            raise ValueError("LambdaSpec(kind='explicit') needs values")

    @classmethod
    def explicit(cls, values) -> "LambdaSpec":
        return cls(kind="explicit", values=values)

    def resolve(self, size: int, *, n: int | None = None,
                canonicalizer: LambdaCanonicalizer | None = None) -> np.ndarray:
        """The concrete ``(size,)`` sequence (size = p·m coefficients)."""
        if self.kind == "explicit":
            lam = np.asarray(self.values)
            # (size,) shared sequence, or a per-problem (B, size) stack for
            # batched problems (the serve layer's co-batching convention)
            if lam.ndim not in (1, 2) or lam.shape[-1] != size:
                raise ValueError(
                    f"explicit λ must have p·m = {size} entries per problem, "
                    f"got shape {lam.shape}")
            return lam
        canon = canonicalizer if canonicalizer is not None else _SHARED_CANONICALIZER
        return canon.get(self.kind, self.q, size, n=n)


def as_lambda_spec(lam) -> LambdaSpec:
    """Coerce ``lam`` to a :class:`LambdaSpec`: specs pass through, strings
    name a recipe at its default parameter, arrays become explicit specs."""
    if isinstance(lam, LambdaSpec):
        return lam
    if isinstance(lam, str):
        return LambdaSpec(kind=lam)
    return LambdaSpec.explicit(lam)


@dataclasses.dataclass(frozen=True, eq=False)
class PathSpec:
    """What path to fit: penalty, σ grid, early stop, the CV block, and the
    resampling block (``resample`` is a
    :class:`~repro.resample.ResamplePlan`: the path is then fit B times
    against the ONE shared design with per-member row weights — bootstrap /
    permutation / subsample replicates, see ``repro.resample``)."""

    lam: Any = LambdaSpec()
    path_length: int = 100
    sigma_ratio: float | None = None
    sigmas: Any = None
    early_stop: bool = True
    cv_folds: int | None = None
    stratify: Any = "auto"
    selection: str = "min"
    resample: ResamplePlan | None = None

    def __post_init__(self):
        object.__setattr__(self, "lam", as_lambda_spec(self.lam))
        if self.selection not in ("min", "1se"):
            raise ValueError(
                f"selection must be 'min' or '1se', got {self.selection!r}")
        if self.cv_folds is not None and self.cv_folds < 2:
            raise ValueError(f"cv_folds must be ≥ 2, got {self.cv_folds}")
        if self.resample is not None:
            if not isinstance(self.resample, ResamplePlan):
                raise ValueError(
                    f"resample must be a repro.resample.ResamplePlan, got "
                    f"{type(self.resample).__name__}")
            if self.cv_folds is not None:
                raise ValueError(
                    "resample and cv_folds are mutually exclusive: fold "
                    "geometry and replicate weighting both own the batch "
                    "axis — run them as separate fits")


_BACKENDS = ("auto", "host", "masked", "compact", "serve")
_SCREENINGS = ("strong", "previous", "none")


@dataclasses.dataclass(frozen=True, eq=False)
class SolverPolicy:
    """How to execute a path: backend, compact sizing, padding, tolerances.

    ``backend="auto"`` defers the host/masked/compact choice to the planner
    (:func:`repro.api.plan.plan_execution`); ``"serve"`` routes through a
    :class:`repro.serve.PathService`.  ``working_set`` controls the compact
    engine: ``None`` forbids compaction, an int pins the W bucket, and
    ``"auto"`` lets the planner size it (grow-on-overflow registry
    included).  ``ws_tiers`` controls the compact engine's second tier at
    2·W (``"auto"``: two tiers whenever 2·W < p; ``1``: single-tier; ``2``:
    demand the second tier) — a member whose screened set outgrows W but
    fits 2·W is served by the wider gather instead of dragging the whole
    batch into the masked fallback.  ``pad="auto"`` resolves to
    canonical-bucket padding exactly when serving (direct uniform batches
    keep their native shapes).

    ``deadline_ms`` / ``priority`` are serving SLO knobs: a latency budget
    (tightens the serving layer's flush deadline; measured by its
    telemetry) and a queue-ordering rank (higher first, FIFO within a
    rank).  Setting either routes ``backend="auto"`` through the serving
    layer — only a service can enforce them — and pinning a non-serve
    backend alongside them is a planning error.  ``solve_timeout_ms`` is
    the serving watchdog budget for this request's device dispatches: a
    chunk/batch call exceeding it is abandoned and the request's cohort
    recovers through the service's retry/bisection path (sync services
    accept but only the async dispatcher enforces mid-flight).

    ``validate`` is the admission-validation policy for non-finite
    operands: ``"strict"`` (default) rejects NaN/Inf in X/y/λ host-side
    with :class:`ValidationError` before any device work; ``"quarantine"``
    admits the request and relies on the engine's in-graph health word to
    flag the member (``PathHealth`` / ``PathResponse.health``); ``"off"``
    skips the host-side scan (the in-graph detector stays on regardless).

    ``telemetry`` selects solver introspection: ``"off"`` (default) skips
    it entirely, ``"summary"`` attaches per-member aggregates and
    ``"steps"`` the full per-σ-step diagnostics as a
    :class:`repro.obs.PathTrace` on ``BatchedPathResult.path_trace``.
    Built host-side from arrays the fit already transfers — it never
    changes the compiled program or the coefficients.
    """

    backend: str = "auto"
    working_set: int | str | None = "auto"
    ws_tiers: int | str = DEFAULT_WS_TIERS
    pad: str | None = "auto"
    screening: str = "strong"
    solver_tol: float = DEFAULT_PATH_TOL
    max_iter: int = DEFAULT_PATH_MAX_ITER
    kkt_tol: float = DEFAULT_KKT_TOL
    max_refits: int = DEFAULT_MAX_REFITS
    verbose: bool = False
    deadline_ms: float | None = None
    priority: int = 0
    validate: str = "strict"
    telemetry: str = "off"
    solve_timeout_ms: float | None = None

    def __post_init__(self):
        if self.validate not in ("strict", "quarantine", "off"):
            raise ValueError(
                f"validate must be 'strict', 'quarantine' or 'off', "
                f"got {self.validate!r}")
        if self.telemetry not in ("off", "summary", "steps"):
            raise ValueError(
                f"telemetry must be 'off', 'summary' or 'steps', "
                f"got {self.telemetry!r}")
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"backend must be one of {_BACKENDS}, got {self.backend!r}")
        if self.screening not in _SCREENINGS:
            raise ValueError(f"unknown screening mode {self.screening!r}")
        ws = self.working_set
        if not (ws is None or ws == "auto"
                or (isinstance(ws, int) and not isinstance(ws, bool))):
            raise ValueError(
                f"working_set must be None, an int or 'auto', got {ws!r}")
        if self.ws_tiers not in ("auto", 1, 2) or isinstance(self.ws_tiers,
                                                            bool):
            raise ValueError(
                f"ws_tiers must be 'auto', 1 or 2, got {self.ws_tiers!r}")
        if self.pad not in (None, "auto", "bucket"):
            raise ValueError(
                f"pad must be None, 'auto' or 'bucket', got {self.pad!r}")
        if self.deadline_ms is not None and not self.deadline_ms > 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {self.deadline_ms!r}")
        if isinstance(self.priority, bool) or not isinstance(self.priority,
                                                             int):
            raise ValueError(
                f"priority must be an int, got {self.priority!r}")
        if (self.solve_timeout_ms is not None
                and not self.solve_timeout_ms > 0):
            raise ValueError(
                f"solve_timeout_ms must be > 0, "
                f"got {self.solve_timeout_ms!r}")


def _register(cls, leaf_fields: tuple[str, ...]):
    """Register a spec dataclass as a pytree: array-valued fields are
    leaves, everything else rides along as auxiliary (static) data."""
    aux_fields = tuple(f.name for f in dataclasses.fields(cls)
                       if f.name not in leaf_fields)

    def flatten(obj):
        return (tuple(getattr(obj, f) for f in leaf_fields),
                tuple(getattr(obj, f) for f in aux_fields))

    def unflatten(aux, children):
        kw = dict(zip(leaf_fields, children))
        kw.update(zip(aux_fields, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)


_register(Problem, ("X", "y", "weights"))
_register(LambdaSpec, ("values",))
_register(PathSpec, ("lam", "sigmas"))
_register(SolverPolicy, ())
