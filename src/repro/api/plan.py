"""The backend planner: resolve ``"auto"`` specs into an explicit plan.

:func:`plan_execution` inspects the problem shape (n vs p, batch size),
the path spec (CV fold geometry), the device kind and the shared
working-set :class:`~repro.serve.buckets.BucketRegistry`, and resolves a
(:class:`~repro.api.specs.Problem`, :class:`~repro.api.specs.PathSpec`,
:class:`~repro.api.specs.SolverPolicy`) triple into an immutable
:class:`ExecutionPlan`: which backend runs (host gathered / device masked /
device compact / served), at what working-set bucket, with what padding,
and — crucially — *why*, as a human-readable :meth:`ExecutionPlan.explain`
report.  The decision rules encode the repo's measured trade-offs
(ROADMAP "when each backend wins"):

* a single unbatched problem → the gathered **host** driver (column
  gathers shrink every matvec; the device scan pays off at B ≥ 2);
* a batch (or CV folds) with n ≳ p → the **masked** device engine
  (screening keeps ≥ p/2, compaction has nothing to cut);
* a batch with p ≫ n (and a W bucket < p) → the **compact** device engine
  (inner solves cost O(n·W), not O(n·p));
* serving → the same masked/compact rule at the canonical bucket shape,
  so plan decisions are identical between direct and served execution of
  the same spec triple.

The planner only *previews* — execution passes the policy's raw knobs to
the engines, which re-resolve through the same registry/rules, so a plan
can never desynchronize from what actually runs.
"""

from __future__ import annotations

import dataclasses

import jax

from ..core.engine import _WS_BUCKETS, _ws_bucket, second_tier_width
from ..serve.buckets import default_policy
from .specs import PathSpec, Problem, SolverPolicy

__all__ = ["ExecutionPlan", "plan_execution"]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One resolved execution choice, with its reasons.

    ``backend`` is ``"host"`` / ``"device"`` / ``"serve"``; ``mode`` the
    concrete engine (``"gathered"`` / ``"masked"`` / ``"compact"``);
    ``working_set`` the previewed compact bucket W (None outside compact
    mode); ``ws_tiers`` the previewed tier widths — ``(W,)`` single-tier or
    ``(W, 2W)`` two-tier (None outside compact mode); ``exec_shape`` the
    padded ``(slots, N, P)`` program shape when ``pad="bucket"`` (slots is
    None for served plans — the slot count is the serving deployment's
    batch bucket).
    """

    backend: str
    mode: str
    batch: int
    n: int
    p: int
    working_set: int | None
    ws_tiers: tuple | None
    pad: str | None
    exec_shape: tuple | None
    screening: str
    device: str
    reasons: tuple[str, ...]

    def summary(self) -> str:
        """Compact one-token summary (CSV/JSON friendly)."""
        s = f"{self.backend}/{self.mode}"
        if self.working_set is not None:
            s += f"-W{self.working_set}"
            if self.ws_tiers is not None and len(self.ws_tiers) == 2:
                s += f"+{self.ws_tiers[1]}"
        if self.exec_shape is not None:
            s += "@" + "x".join("?" if v is None else str(v)
                                for v in self.exec_shape)
        elif self.batch > 1:
            s += f"-B{self.batch}"
        return s

    def explain(self) -> str:
        """Multi-line report of the plan and why each choice was made."""
        head = (f"ExecutionPlan: {self.backend}/{self.mode}"
                f"  B={self.batch}  n={self.n}  p={self.p}"
                + (f"  W={self.working_set}" if self.working_set is not None
                   else "")
                + (f"  tiers={self.ws_tiers}" if self.ws_tiers is not None
                   else "")
                + f"  pad={self.pad}"
                + (f"  exec_shape={self.exec_shape}"
                   if self.exec_shape is not None else "")
                + f"  device={self.device}")
        return "\n".join([head] + [f"  - {r}" for r in self.reasons])


def _preview_ws(working_set, n_key: int, p_key: int, key: tuple,
                reasons: list) -> int:
    """Resolve the compact bucket W exactly as the engine will, and record
    where it came from (explicit / registry growth / default recipe)."""
    grown = key in _WS_BUCKETS
    if isinstance(working_set, int) and not isinstance(working_set, bool):
        W = _ws_bucket(working_set, n_key, p_key, key)
        reasons.append(f"W={W}: explicit working_set={working_set} rounded "
                       f"to a power-of-two bucket capped at p")
        return W
    W = _ws_bucket("auto", n_key, p_key, key)
    if grown:
        reasons.append(f"W={W}: grow-on-overflow registry entry for "
                       f"{key} (a previous same-shape run overflowed)")
    else:
        reasons.append(f"W={W}: auto recipe min(2^⌈log₂ max(2n, 64)⌉, p) — "
                       f"the screened set tracks the active set, which p ≫ n "
                       f"keeps well under n")
    return W


def plan_execution(problem: Problem, path: PathSpec | None = None,
                   policy: SolverPolicy | None = None) -> ExecutionPlan:
    """Resolve the spec triple into an explicit, introspectable plan."""
    path = path if path is not None else PathSpec()
    policy = policy if policy is not None else SolverPolicy()
    family = problem.family
    m = family.n_classes
    n, p = problem.n, problem.p
    batched = problem.batched
    B = problem.batch
    device = jax.default_backend()
    reasons: list[str] = []

    n_fit = n
    if path.cv_folds:
        if batched:
            raise ValueError("CV takes a single (n, p) problem, not a batch")
        if policy.backend == "host":
            raise ValueError(
                "cross-validation runs all folds as ONE batched device "
                "program; backend='host' cannot execute cv_folds — use "
                "'auto', 'masked', 'compact' or 'serve'")
        B, batched = path.cv_folds, True
        n_fit = n - n // path.cv_folds
        reasons.append(
            f"{path.cv_folds}-fold CV: {B} equal-shape training designs of "
            f"{n_fit}×{p} batch into one compiled program")

    rs = path.resample
    if rs is not None:
        if batched:
            raise ValueError(
                "resampling takes a single (n, p) problem — the replicate "
                "axis IS the batch axis (B members share one design)")
        if policy.backend == "host":
            raise ValueError(
                "resampling runs all replicates as ONE weight-fused device "
                "program against the shared design; backend='host' cannot "
                "execute a ResamplePlan — use 'auto', 'masked', 'compact' "
                "or 'serve'")
        B, batched = rs.n_replicates, True
        reasons.append(
            f"{rs.kind} resampling: B={B} replicates share ONE {n}×{p} "
            f"design via per-member row weights (O(n·p + B·n) memory, "
            f"no (B, n, p) materialization)")

    serve = policy.backend == "serve"

    # -- SLO knobs route through the serving layer --------------------------
    slo = policy.deadline_ms is not None or policy.priority != 0
    if slo and policy.backend not in ("auto", "serve"):
        raise ValueError(
            f"deadline_ms/priority are serving SLO knobs — only a service "
            f"(timer-driven flush, priority queues) can enforce them; they "
            f"cannot be honoured with backend={policy.backend!r}")
    if slo and policy.backend == "auto":
        serve = True
        reasons.append(
            "backend='serve': deadline_ms/priority set — SLOs are enforced "
            "by the serving layer (timer-driven deadline flush, priority "
            "admission queues)")

    # -- padding & canonical execution shape --------------------------------
    pad = policy.pad
    if pad == "auto":
        pad = "bucket" if serve else None
        reasons.append(
            "pad='bucket': served requests run at canonical bucket shapes "
            "so heterogeneous traffic shares compiled programs" if serve else
            "pad=None: direct execution keeps native shapes (canonical "
            "buckets pay off for heterogeneous served streams)")
    if serve and pad != "bucket":
        raise ValueError(
            "the serving layer always executes at canonical bucket shapes; "
            "SolverPolicy(pad=None) cannot be honoured with "
            "backend='serve' — use pad='auto' or 'bucket'")
    if rs is not None and not serve and pad == "bucket":
        raise ValueError(
            "direct replicate execution runs at the shared design's native "
            "shape (the weights are O(B·n) — there is nothing to bucket); "
            "pad='bucket' with a ResamplePlan requires backend='serve'")
    exec_shape = None
    n_key, p_key = n_fit, p
    if pad == "bucket":
        pol = default_policy()
        N, P = pol.shape_bucket(n_fit, p, family.name)
        slots = None if serve else pol.batch_bucket(B)
        exec_shape = (slots, N, P)
        n_key, p_key = N, P
        reasons.append(
            f"canonical execution shape rows×cols = {N}×{P} "
            f"(power-of-two buckets, inert zero padding; rows padded for "
            f"OLS only)")

    # -- backend ------------------------------------------------------------
    if policy.backend == "host":
        if batched:
            raise ValueError(
                "backend='host' takes a single (n, p) problem; the gathered "
                "host driver cannot run a (B, n, p) batch — use 'masked', "
                "'compact' or 'auto'")
        backend, mode = "host", "gathered"
        reasons.append("policy pinned the gathered host driver")
    elif policy.backend in ("masked", "compact"):
        backend, mode = "device", policy.backend
        reasons.append(f"policy pinned the {policy.backend} device engine")
    elif not serve and not batched:
        backend, mode = "host", "gathered"
        reasons.append(
            "single unbatched problem: gathered host sub-problems beat "
            "masked full-width device solves (the device scan pays off for "
            "batches, CV folds and served streams)")
    else:
        backend = "serve" if serve else "device"
        mode = None  # resolved below

    # -- masked vs compact --------------------------------------------------
    if mode is None:
        ws = policy.working_set
        if ws is None:
            mode = "masked"
            reasons.append("working_set=None forbids compaction: masked "
                           "full-width engine")
        elif isinstance(ws, int) and not isinstance(ws, bool):
            mode = "compact"
            reasons.append(f"working_set={ws} pins the compact engine")
        elif policy.screening == "none":
            mode = "masked"
            reasons.append("screening='none' keeps all p predictors in "
                           "every working set — nothing to compact")
        elif p >= 2 * n_fit:
            key = (n_key, p_key, m, family.name, policy.screening)
            probe: list[str] = []
            W = _preview_ws("auto", n_key, p_key, key, probe)
            if W < p_key:
                mode = "compact"
                reasons.append(
                    f"p={p} ≫ n={n_fit} (p ≥ 2n): compact working-set "
                    f"engine — inner solves cost O(n·W) instead of O(n·p)")
                reasons.extend(probe)
            else:
                mode = "masked"
                reasons.append(
                    f"p={p} ≥ 2n but the auto W bucket ({W}) already spans "
                    f"p: compaction would cut nothing — masked engine")
        else:
            mode = "masked"
            reasons.append(
                f"n={n_fit} ≳ p={p} (p < 2n): screening keeps ≥ p/2 of the "
                f"predictors, compaction cuts nothing — masked full-width "
                f"engine")

    # -- working-set preview for pinned-compact plans ------------------------
    working_set = None
    ws_tiers = None
    if mode == "compact":
        key = (n_key, p_key, m, family.name, policy.screening)
        ws_probe: list[str] = []
        working_set = _preview_ws(policy.working_set, n_key, p_key, key,
                                  ws_probe)
        # avoid duplicating the auto-recipe reason added by the heuristic
        if not any(r.startswith("W=") for r in reasons):
            reasons.extend(ws_probe)
        # the second tier derives from the already-previewed W (the same
        # recipe the engine applies after its own registry read) — a single
        # registry lookup, so the previewed pair is internally consistent
        # even if a concurrent run grows the shared registry mid-plan
        W2 = second_tier_width(working_set, policy.ws_tiers, p_key)
        ws_tiers = (working_set,) if W2 is None else (working_set, W2)
        if W2 is None:
            reasons.append(
                "single-tier compact: ws_tiers=1 pinned it" if
                policy.ws_tiers == 1 else
                f"single-tier compact: a 2W tier ({2 * working_set}) would "
                f"span p={p_key} — the masked fallback IS the top tier")
        else:
            reasons.append(
                f"two-tier compact W={working_set}+{W2}: a member whose "
                f"screened set outgrows W is served at 2W; the batch-wide "
                f"masked fallback fires only beyond {W2}")

    if backend == "host" and pad == "bucket":
        raise ValueError("pad='bucket' requires a device or serve backend "
                         "(the host driver gathers sub-problems; it has no "
                         "use for canonical padded shapes)")

    reasons.append(f"jax default backend: {device}")
    return ExecutionPlan(
        backend=backend, mode=mode, batch=B, n=n_fit, p=p,
        working_set=working_set, ws_tiers=ws_tiers, pad=pad,
        exec_shape=exec_shape, screening=policy.screening, device=device,
        reasons=tuple(reasons),
    )
