"""``SlopE`` — an estimator-style wrapper over the declarative front door.

The familiar fit/predict shape (R's ``SLOPE(x, y, ...)``, scikit-learn's
``Estimator.fit``) on top of :func:`repro.api.fit.slope_path`::

    est = SlopE(family=logistic, lam=LambdaSpec("bh", q=0.1))
    est.fit(X, y)            # K-fold CV (default 5) picks σ, then refits
    est.predict(X_new)       # family-appropriate predictions
    est.coef_                # (p,) or (p, m) at the selected σ

With ``cv=None`` no model selection happens: the full path is fitted and
``coef_`` is taken at the last (least-regularized) grid point — pass
``cv=K`` (or a ``PathSpec`` with ``cv_folds``) for a principled choice.
All heavy lifting — planning, backends, screening — is the front door's;
the estimator only selects and stores.
"""

from __future__ import annotations

import numpy as np

from .fit import slope_path
from .specs import LambdaSpec, PathSpec, Problem, SolverPolicy, as_lambda_spec

__all__ = ["SlopE"]


class SlopE:
    """SLOPE path estimator: CV-select σ, refit, predict.

    Parameters mirror the spec dataclasses: ``lam`` takes a
    :class:`~repro.api.specs.LambdaSpec`, a recipe name or an explicit
    array; ``path``/``policy`` override the full specs (a ``path`` with
    ``cv_folds`` set wins over ``cv=``).
    """

    def __init__(self, *, family=None, lam=None, path: PathSpec | None = None,
                 policy: SolverPolicy | None = None, cv: int | None = 5,
                 selection: str = "min"):
        from ..core.losses import ols

        self.family = family if family is not None else ols
        self.lam = as_lambda_spec(lam) if lam is not None else LambdaSpec()
        self.path = path
        self.policy = policy if policy is not None else SolverPolicy()
        self.cv = cv
        self.selection = selection

    # -- fitting ------------------------------------------------------------

    def _path_spec(self) -> PathSpec:
        if self.path is not None:
            return self.path
        return PathSpec(lam=self.lam, cv_folds=self.cv,
                        selection=self.selection)

    def fit(self, X, y, *, weights=None) -> "SlopE":
        problem = Problem(X, y, family=self.family, weights=weights)
        if problem.batched:
            raise ValueError("SlopE fits one (n, p) problem; use "
                             "slope_path for batches")
        spec = self._path_spec()
        if spec.cv_folds:
            self.cv_ = slope_path(problem, spec, self.policy)
            # refit the full data on the CV grid; σ index stays aligned
            refit_spec = PathSpec(lam=spec.lam, sigmas=self.cv_.sigmas,
                                  early_stop=False)
            self.path_ = slope_path(problem, refit_spec, self.policy)
            self.sigma_index_ = int(self.cv_.best_index)
            self.sigma_ = float(self.cv_.best_sigma)
        else:
            self.cv_ = None
            self.path_ = slope_path(problem, spec, self.policy)
            self.sigma_index_ = len(self.path_.sigmas) - 1
            self.sigma_ = float(self.path_.sigmas[self.sigma_index_])
        # the plan of the fit coef_ came from; the CV selection run's plan
        # (fold-batched, usually a different backend) is at self.cv_.plan
        self.plan_ = self.path_.plan
        self.coef_ = np.asarray(self.path_.betas[self.sigma_index_])
        return self

    # -- prediction ---------------------------------------------------------

    def _check_fitted(self):
        if not hasattr(self, "coef_"):
            raise ValueError("this SlopE instance is not fitted yet; call "
                             "fit(X, y) first")

    def decision_function(self, X) -> np.ndarray:
        """The linear predictor z = Xβ at the selected σ."""
        self._check_fitted()
        return np.asarray(X) @ self.coef_

    def predict(self, X) -> np.ndarray:
        """Family-appropriate predictions: the mean response for OLS and
        Poisson, hard class labels for logistic/multinomial."""
        z = self.decision_function(X)
        name = self.family.name
        if name == "ols":
            return z
        if name == "poisson":
            return np.exp(z)
        if name == "logistic":
            return (z > 0).astype(np.int64)
        if name == "multinomial":
            return np.argmax(z, axis=-1)
        raise ValueError(f"no prediction rule for family {name!r}")

    def predict_proba(self, X) -> np.ndarray:
        """Class probabilities (logistic and multinomial families)."""
        z = self.decision_function(X)
        if self.family.name == "logistic":
            p1 = 1.0 / (1.0 + np.exp(-z))
            return np.stack([1.0 - p1, p1], axis=-1)
        if self.family.name == "multinomial":
            z = z - z.max(axis=-1, keepdims=True)
            e = np.exp(z)
            return e / e.sum(axis=-1, keepdims=True)
        raise ValueError(
            f"predict_proba is for classifiers, not {self.family.name!r}")
