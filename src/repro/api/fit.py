"""``slope_path`` — the one declarative front door for SLOPE path fitting.

Every way this repo can fit a regularization path — gathered host driver,
masked/compact batched device engines, K-fold CV, canonical-bucket padding,
the micro-batching path service — is reachable from one call::

    from repro.api import Problem, PathSpec, SolverPolicy, slope_path

    res = slope_path(Problem(X, y, family=ols),
                     PathSpec(lam=LambdaSpec("bh", q=0.1), path_length=50),
                     SolverPolicy())          # backend="auto" → planned

``slope_path`` resolves the spec triple through
:func:`repro.api.plan.plan_execution` and dispatches to the SAME private
implementations the legacy entry points (``fit_path``,
``fit_path_batched``, ``cv_path`` — now thin shims over this layer) used,
so planner-selected execution is bit-identical to the equivalent explicit
legacy kwargs.  The resolved :class:`~repro.api.plan.ExecutionPlan` is
attached to every result as ``.plan`` (``res.plan.explain()`` says why).

Returns by spec shape: a :class:`~repro.core.path.PathResult` for one
``(n, p)`` problem, a :class:`~repro.core.engine.BatchedPathResult` for a
``(B, n, p)`` batch, a :class:`~repro.core.engine.CvPathResult` when
``PathSpec.cv_folds`` is set — and, for ``SolverPolicy(backend="serve")``,
the service's :class:`~repro.serve.service.PathResponse` /
:class:`~repro.serve.service.CvResponse` (telemetry included), bit-identical
to the direct padded call by the serve layer's contract.
"""

from __future__ import annotations

import threading

import numpy as np

from .plan import ExecutionPlan, plan_execution
from .specs import (
    PathSpec,
    Problem,
    SolverPolicy,
    ValidationError,
    apply_weights,
    check_weights,
    find_nonfinite,
)

__all__ = ["slope_path", "default_service", "default_async_service"]

_SERVICE_LOCK = threading.Lock()
_DEFAULT_SERVICE = None
_DEFAULT_ASYNC_SERVICE = None


def default_service():
    """The process-wide :class:`~repro.serve.PathService` backing
    ``SolverPolicy(backend="serve")`` calls (created on first use)."""
    global _DEFAULT_SERVICE
    with _SERVICE_LOCK:
        if _DEFAULT_SERVICE is None:
            from ..serve.service import PathService

            _DEFAULT_SERVICE = PathService()
        return _DEFAULT_SERVICE


def default_async_service():
    """The process-wide :class:`~repro.serve.AsyncPathService` backing
    serve calls that carry SLO knobs (``deadline_ms`` / ``priority``).

    Created on first use — the worker thread only exists once someone asks
    for SLO enforcement.  Separate from :func:`default_service` because the
    two enforce different contracts: the sync service flushes on the next
    call, the async one on a timer.
    """
    global _DEFAULT_ASYNC_SERVICE
    with _SERVICE_LOCK:
        if _DEFAULT_ASYNC_SERVICE is None:
            from ..serve.dispatch import AsyncPathService

            _DEFAULT_ASYNC_SERVICE = AsyncPathService()
        return _DEFAULT_ASYNC_SERVICE


def _ws_arg(plan: ExecutionPlan, policy: SolverPolicy):
    """The engine-facing working_set knob for a resolved plan.

    The RAW policy value is passed through (not the plan's previewed W):
    the engines re-resolve "auto" through the same shared registry, which
    keeps grow-on-overflow semantics identical to the legacy entry points.
    """
    if plan.mode != "compact":
        return None
    ws = policy.working_set
    return "auto" if ws is None or ws == "auto" else ws


def slope_path(problem: Problem, path: PathSpec | None = None,
               policy: SolverPolicy | None = None, *,
               plan: ExecutionPlan | None = None):
    """Fit a SLOPE path for a declarative ``(problem, path, policy)`` triple.

    ``plan`` overrides the planner (pass a pre-computed
    :func:`~repro.api.plan.plan_execution` result to skip re-planning);
    otherwise the triple is planned here and the plan is threaded through
    to the executing layer (including the service).  Served responses
    always carry the full σ grid — apply early stopping through
    ``resp.path_result(early_stop=True)``.
    """
    from ..core.engine import _cv_path, _fit_path_batched
    from ..core.path import _fit_path_device, _fit_path_host

    if not isinstance(problem, Problem):
        raise TypeError(f"problem must be a repro.api.Problem, got "
                        f"{type(problem).__name__}")
    path = path if path is not None else PathSpec()
    policy = policy if policy is not None else SolverPolicy()
    pln = plan if plan is not None else plan_execution(problem, path, policy)

    if pln.backend == "serve":
        # the service enforces policy.validate at admission
        return _serve_path(problem, path, policy, pln)

    if path.resample is not None:
        return _resample_path(problem, path, policy, pln)

    # weighted single problems on the device engines ride the replicate
    # row-weight path (B = 1) instead of materialising √w·X — the same
    # code path weighted replicates use (one weighting seam, satellite of
    # the resample subsystem); host/CV/padded routes keep the exact
    # √w-scaling reduction
    rw = None
    if (problem.weights is not None and pln.backend == "device"
            and not path.cv_folds and not problem.batched
            and pln.pad != "bucket"):
        rw = check_weights(problem)
        X, y = np.asarray(problem.X), np.asarray(problem.y)
    else:
        X, y = apply_weights(problem)
    family = problem.family
    n, p, m = problem.n, problem.p, family.n_classes
    lam = path.lam.resolve(p * m, n=n)
    if policy.validate == "strict":
        issues = find_nonfinite(X=X, y=y, lam=lam, sigmas=path.sigmas)
        if issues:
            raise ValidationError(issues)
    # validate="quarantine"/"off": direct device backends still flag sick
    # members in-graph (BatchedPathResult.path_health); the gathered host
    # driver has no in-graph detector, so there "quarantine" degrades to
    # "off" (documented in README failure semantics)
    if getattr(lam, "ndim", 1) == 2 and not problem.batched:
        raise ValueError(
            f"a per-problem (B, p·m) λ stack (got {lam.shape}) needs a "
            f"batched (B, n, p) problem; this Problem is a single (n, p)")

    kw = dict(screening=policy.screening, path_length=path.path_length,
              sigma_ratio=path.sigma_ratio, sigmas=path.sigmas,
              solver_tol=policy.solver_tol, max_iter=policy.max_iter,
              kkt_tol=policy.kkt_tol)

    if path.cv_folds:
        if path.sigmas is not None:
            raise ValueError(
                "PathSpec.sigmas cannot be combined with cv_folds for "
                "direct execution: the CV grid is computed once from the "
                "full data so every fold shares it")
        kw.pop("sigmas")
        res = _cv_path(X, y, lam, family, n_folds=path.cv_folds,
                       max_refits=policy.max_refits,
                       working_set=_ws_arg(pln, policy),
                       ws_tiers=policy.ws_tiers,
                       stratify=path.stratify, selection=path.selection,
                       pad=pln.pad, **kw)
    elif pln.mode == "gathered":
        res = _fit_path_host(X, y, lam, family, early_stop=path.early_stop,
                             verbose=policy.verbose, **kw)
    elif problem.batched:
        res = _fit_path_batched(X, y, lam, family,
                                max_refits=policy.max_refits,
                                working_set=_ws_arg(pln, policy),
                                ws_tiers=policy.ws_tiers,
                                pad=pln.pad, telemetry=policy.telemetry,
                                **kw)
    elif rw is not None:
        # single weighted problem on a device engine: a 1-member replicate
        # batch against the shared design (no √w·X materialisation)
        from ..core.engine import _fit_replicate_batched, null_sigma_grid

        if kw["sigmas"] is None:
            # the σ grid must see the weighted problem — same statistics
            # the √w-scaled host reference derives its grid from
            sw = np.sqrt(rw)
            kw["sigmas"] = null_sigma_grid(
                X * sw[:, None], y * sw, lam, family,
                path_length=path.path_length, sigma_ratio=path.sigma_ratio)
        batched = _fit_replicate_batched(X, y, lam, family, rw[None, :],
                                         max_refits=policy.max_refits,
                                         working_set=_ws_arg(pln, policy),
                                         ws_tiers=policy.ws_tiers,
                                         telemetry=policy.telemetry, **kw)
        res = batched.path_results(early_stop=path.early_stop)[0]
    elif pln.mode == "masked":
        # identical call path to the legacy fit_path(engine="device")
        res = _fit_path_device(X, y, lam, family, early_stop=path.early_stop,
                               max_refits=policy.max_refits, pad=pln.pad,
                               **kw)
    else:  # compact, single problem: batch of one through the device engine
        batched = _fit_path_batched(X[None], y[None], lam, family,
                                    max_refits=policy.max_refits,
                                    working_set=_ws_arg(pln, policy),
                                    ws_tiers=policy.ws_tiers,
                                    pad=pln.pad,
                                    telemetry=policy.telemetry, **kw)
        res = batched.path_results(early_stop=path.early_stop)[0]
    res.plan = pln
    return res


def _resample_path(problem: Problem, path: PathSpec, policy: SolverPolicy,
                   pln: ExecutionPlan):
    """Fit the B-replicate weight-fused batch a :class:`ResamplePlan` asks
    for: one shared (n, p) design, per-member row weights, one compiled
    program.  Returns a :class:`~repro.core.engine.BatchedPathResult` over
    the replicates with ``.plan`` and ``.resample`` attached."""
    from ..core.engine import _fit_replicate_batched, null_sigma_grid
    from ..resample.metrics import RESAMPLE_METRICS

    rs = path.resample
    X = np.asarray(problem.X)
    y = np.asarray(problem.y)
    family = problem.family
    n, p, m = problem.n, problem.p, family.n_classes
    lam = path.lam.resolve(p * m, n=n)
    if getattr(lam, "ndim", 1) != 1:
        raise ValueError(
            "replicates share ONE design, so they share one (p·m,) λ "
            f"sequence; got a per-problem stack of shape {lam.shape}")
    if policy.validate == "strict":
        issues = find_nonfinite(X=X, y=y, lam=lam, sigmas=path.sigmas,
                                weights=problem.weights)
        if issues:
            raise ValidationError(issues)

    W = np.asarray(rs.row_weights(n, dtype=X.dtype))
    if problem.weights is not None:
        # weighted resampling: the member weight is w ⊙ c_b — exactly the
        # weighted loss of the member's resampled rows (OLS-only gate,
        # same messages as every other weighted route)
        W = W * check_weights(problem)[None, :]
    sigmas = path.sigmas
    if sigmas is None:
        sigmas = null_sigma_grid(X, y, lam, family,
                                 path_length=path.path_length,
                                 sigma_ratio=path.sigma_ratio)
    sigmas = np.asarray(sigmas)
    y_fit = np.asarray(rs.permuted_targets(y)) if rs.kind == "permutation" \
        else y

    RESAMPLE_METRICS.set_gauge("replicates_in_flight", rs.n_replicates,
                               kind=rs.kind)
    RESAMPLE_METRICS.inc("replicates", rs.n_replicates, kind=rs.kind,
                         backend=pln.mode)
    try:
        res = _fit_replicate_batched(
            X, y_fit, lam, family, W,
            screening=policy.screening, sigmas=sigmas,
            solver_tol=policy.solver_tol, max_iter=policy.max_iter,
            kkt_tol=policy.kkt_tol, max_refits=policy.max_refits,
            working_set=_ws_arg(pln, policy), ws_tiers=policy.ws_tiers,
            telemetry=policy.telemetry)
    finally:
        RESAMPLE_METRICS.set_gauge("replicates_in_flight", 0, kind=rs.kind)
    res.plan = pln
    res.resample = rs
    return res


def _serve_path(problem: Problem, path: PathSpec, policy: SolverPolicy,
                pln: ExecutionPlan):
    """Route one spec triple through the default PathService and wait.

    Requests carrying SLO knobs go through the async service — its worker
    thread enforces the deadline on a timer and its futures block here —
    plain serve requests keep the synchronous submit/poll round trip.
    """
    if problem.batched:
        raise ValueError(
            "backend='serve' takes single (n, p) problems — submit batch "
            "members individually; the service micro-batches them")
    if policy.deadline_ms is not None or policy.priority != 0:
        from ..serve.dispatch import Rejection

        svc = default_async_service()
        fut = svc.submit(problem=problem, path=path, policy=policy, plan=pln)
        resp = fut.result()
        if isinstance(resp, Rejection):
            raise RuntimeError(
                f"serve request rejected by admission control: {resp.reason} "
                f"(queued={resp.queued}, max_queue={resp.max_queue})")
        resp.plan = pln
        return resp
    svc = default_service()
    rid = svc.submit(problem=problem, path=path, policy=policy, plan=pln)
    resp = svc.poll(rid, flush=True)
    if resp is not None:
        resp.plan = pln  # same introspection surface as direct results
    return resp
