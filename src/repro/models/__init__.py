"""LM substrate: config-driven models covering all assigned architectures."""

from .config import ArchConfig, MoECfg, SSMCfg
from .model import (
    init_params,
    forward,
    lm_loss,
    init_cache,
    decode_step,
    encode,
    stack_pattern,
)
from .layers import set_axis_rules, get_axis_rules, shard

__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg",
    "init_params", "forward", "lm_loss", "init_cache", "decode_step",
    "encode", "stack_pattern", "set_axis_rules", "get_axis_rules", "shard",
]
