"""Model assembly: pattern-based layer stacks, scan-over-layers, CE loss,
and cached decode — one code path for all ten assigned architectures.

A *pattern* is the repeating unit of the stack (one layer for homogeneous
archs; 8 layers for jamba's 1-attn:7-mamba superblock; DeepSeek's dense
layer 0 is an unrolled prologue).  Per-unit params are stacked along a
leading scan axis so the HLO is O(pattern), not O(depth) — essential for
512-partition compiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (
    attention,
    attention_decode,
    cross_attention_decode,
    init_attention,
    init_attn_cache,
    precompute_cross_kv,
)
from .config import ArchConfig
from .layers import (
    dense,
    init_mlp,
    init_rms,
    mlp,
    rms_norm,
    shard,
    sinusoidal_positions,
)
from .moe import init_moe, moe_layer
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_layer

__all__ = [
    "LayerSpec", "stack_pattern", "init_params", "forward",
    "lm_loss", "init_cache", "decode_step", "encode",
]

MOE_AUX_WEIGHT = 0.01

# Analysis-only switch: XLA's cost_analysis counts while-loop bodies ONCE,
# so the dry-run's flop/collective census lowers truncated configs with
# scans unrolled (launch/dryrun.py two-point extrapolation).  Production
# lowering always uses rolled scans (compact HLO, fast 512-way compiles).
_SCAN_UNROLL = False


def set_scan_unroll(v: bool):
    global _SCAN_UNROLL
    _SCAN_UNROLL = v


def _unroll():
    return True if _SCAN_UNROLL else 1


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # 'attn' | 'ssm'
    mlp: str   # 'dense' | 'moe' | 'none' | 'dense_first'


def stack_pattern(cfg: ArchConfig) -> tuple[list[LayerSpec], list[LayerSpec], int]:
    """(prologue unrolled, scanned pattern, n_scan)."""

    def spec(i: int) -> LayerSpec:
        kind = cfg.layer_kind(i)
        if cfg.is_moe_layer(i):
            m = "moe"
        elif cfg.moe is not None and cfg.moe.first_dense and i == 0:
            m = "dense_first"
        elif cfg.family == "ssm":
            m = "none"  # pure mamba2 block: no separate MLP
        else:
            m = "dense"
        return LayerSpec(kind, m)

    if cfg.attn_period:
        pat = [spec(i) for i in range(cfg.attn_period)]
        assert cfg.n_layers % cfg.attn_period == 0
        return [], pat, cfg.n_layers // cfg.attn_period
    if cfg.moe is not None and cfg.moe.first_dense:
        return [spec(0)], [spec(1)], cfg.n_layers - 1
    return [], [spec(0)], cfg.n_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, s: LayerSpec, cross: bool) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": init_rms(cfg.d_model, cfg.pdtype)}
    if s.kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    else:
        p["ssm"] = init_ssm(ks[0], cfg)
    if cross:
        p["norm_x"] = init_rms(cfg.d_model, cfg.pdtype)
        p["cross"] = init_attention(ks[1], cfg, cross=True)
    if s.mlp != "none":
        p["norm2"] = init_rms(cfg.d_model, cfg.pdtype)
        if s.mlp == "moe":
            p["moe"] = init_moe(ks[2], cfg)
        elif s.mlp == "dense_first":
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.moe.d_ff_first_dense,
                                cfg.pdtype, cfg.mlp_act)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype, cfg.mlp_act)
    return p


def _init_unit(key, cfg: ArchConfig, pattern: list[LayerSpec], cross: bool) -> dict:
    ks = jax.random.split(key, len(pattern))
    return {f"l{i}": _init_layer(ks[i], cfg, s, cross) for i, s in enumerate(pattern)}


def init_params(cfg: ArchConfig, key) -> dict:
    prologue, pattern, n_scan = stack_pattern(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.pdtype),
        "final_norm": init_rms(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1], (cfg.vocab, cfg.d_model), jnp.float32)
                          * 0.02).astype(cfg.pdtype)
    for i, s in enumerate(prologue):
        params[f"pro{i}"] = _init_layer(jax.random.fold_in(keys[2], i), cfg, s, cfg.encdec)
    unit_keys = jax.random.split(keys[3], n_scan)
    params["blocks"] = jax.vmap(
        lambda k: _init_unit(k, cfg, pattern, cfg.encdec)
    )(unit_keys)
    if cfg.encdec:
        enc_keys = jax.random.split(keys[4], cfg.n_enc_layers)
        enc_pattern = [LayerSpec("attn", "dense")]
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_unit(k, cfg, enc_pattern, False)
        )(enc_keys)
        params["enc_norm"] = init_rms(cfg.d_model, cfg.pdtype)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer(x, p, cfg: ArchConfig, s: LayerSpec, positions, mesh, aux,
                 *, causal=True, enc_out=None, use_rope=None):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if s.kind == "attn":
        mix = attention(h, p["attn"], cfg, positions, causal=causal, use_rope=use_rope)
    else:
        mix, _ = ssm_layer(h, p["ssm"], cfg)
    x = x + mix
    if enc_out is not None:
        h = rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + attention(h, p["cross"], cfg, positions, kv_x=enc_out)
    if s.mlp != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if s.mlp == "moe":
            y, a = moe_layer(h, p["moe"], cfg, mesh=mesh)
            aux = aux + a
        else:
            y = mlp(h, p["mlp"], cfg.mlp_act)
        x = x + y
    return x, aux


def encode(params, frames, cfg: ArchConfig, mesh=None):
    """Whisper-style encoder over stubbed frame embeddings (B, F, d)."""
    x = frames.astype(cfg.adtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cfg.adtype)[None]
    spec = LayerSpec("attn", "dense")
    positions = jnp.arange(x.shape[1])

    def body(carry, blk):
        h, _ = _apply_layer(carry, blk["l0"], cfg, spec, positions, mesh,
                            jnp.float32(0.0), causal=False, use_rope=False)
        return h, None

    body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_blocks"], unroll=_unroll())
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ArchConfig, *, mesh=None, enc_out=None,
            patch_embeds=None):
    """Token ids (B, S) → logits (B, S, V).  ``enc_out`` feeds cross
    attention (whisper); ``patch_embeds`` (B, Np, d) are spliced in front of
    the token embeddings (llava stub frontend)."""
    prologue, pattern, n_scan = stack_pattern(cfg)
    x = _embed_lookup(params["embed"], tokens, cfg)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(cfg.adtype), x], axis=1)
    if cfg.encdec:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cfg.adtype)[None]
    x = shard(x, "batch", None, None)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux0 = jnp.float32(0.0)

    aux = aux0
    for i, s in enumerate(prologue):
        x, aux = _apply_layer(x, params[f"pro{i}"], cfg, s, positions, mesh, aux,
                              enc_out=enc_out)

    def body(carry, blk):
        h, a = carry
        for i, s in enumerate(pattern):
            h, a = _apply_layer(h, blk[f"l{i}"], cfg, s, positions, mesh, a,
                                enc_out=enc_out)
        h = shard(h, "batch", None, None)
        return (h, a), None

    body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = lax.scan(body, (x, aux), params["blocks"], unroll=_unroll())

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits, aux


def _lm_head(params, x, cfg: ArchConfig):
    """Vocab-parallel head.  Non-divisible vocabs (whisper 51865, granite
    49155, mamba2 50280) are zero-padded to the model-axis multiple at the
    execution layer and masked to −∞ so CE/argmax semantics are exact; the
    padded lanes keep the (B,S,V)-sized tensor sharded through the loss."""
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    V = head.shape[0]
    M = _ambient_model_axis()
    V_eff = ((V + M - 1) // M) * M
    if V_eff != V:
        head = jnp.concatenate(
            [head, jnp.zeros((V_eff - V, head.shape[1]), head.dtype)], axis=0
        )
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = shard(logits, "batch", None, "vocab")
    if V_eff != V:
        lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(lane < V, logits, -1e30)
    return logits


def _ambient_model_axis() -> int:
    from .layers import get_axis_rules

    rules = get_axis_rules()
    if not rules:
        return 1
    return rules.get("pad_to", rules["mesh"].shape.get("model", 1))


def _embed_lookup(table, tokens, cfg: ArchConfig):
    """Token embedding lookup.

    Baseline: plain gather (XLA all-gathers the vocab-sharded table — V·d
    bytes per step).  §Perf knob ``vp_embed``: Megatron vocab-parallel
    lookup under shard_map — each model shard gathers its local vocab
    range, masks, and psums (tokens·d bytes, ≪ V·d for gemma-class vocabs)."""
    from .layers import get_axis_rules

    rules = get_axis_rules()
    V, d = table.shape
    if (not rules or not rules.get("vp_embed")
            or V % rules["mesh"].shape.get("model", 1)):
        return jnp.take(table, tokens, axis=0).astype(cfg.adtype)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules["mesh"]
    M = mesh.shape["model"]
    V_loc = V // M
    baxes = rules["rules"]["batch"]
    bspec = tuple(baxes) if len(baxes) > 1 else (baxes[0] if baxes else None)

    def local(table_loc, tok):
        me = jax.lax.axis_index("model")
        idx = tok - me * V_loc
        ok = (idx >= 0) & (idx < V_loc)
        out = jnp.take(table_loc, jnp.clip(idx, 0, V_loc - 1), axis=0)
        out = jnp.where(ok[..., None], out.astype(cfg.adtype), 0)
        return jax.lax.psum(out, "model")

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(table, tokens)


def lm_loss(params, batch, cfg: ArchConfig, *, mesh=None):
    """Next-token CE.  batch: {tokens, [frames], [patch_embeds]}."""
    tokens = batch["tokens"]
    enc_out = None
    if cfg.encdec:
        enc_out = encode(params, batch["frames"], cfg, mesh)
    logits, aux = forward(params, tokens, cfg, mesh=mesh, enc_out=enc_out,
                          patch_embeds=batch.get("patch_embeds"))
    n_prefix = 0 if batch.get("patch_embeds") is None else batch["patch_embeds"].shape[1]
    logits = logits[:, n_prefix:]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    loss = ce + MOE_AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg: ArchConfig, s: LayerSpec, B: int, S_ctx: int, dtype,
                      enc_frames: int = 0) -> dict:
    c: dict[str, Any] = {}
    if s.kind == "attn":
        c["kv"] = init_attn_cache(cfg, B, S_ctx, dtype)
    else:
        c["ssm"] = init_ssm_cache(cfg, B, dtype)
    if cfg.encdec:
        K, hd = cfg.n_kv_heads, cfg.hd
        c["cross"] = {
            "k": jnp.zeros((B, enc_frames, K, hd), dtype),
            "v": jnp.zeros((B, enc_frames, K, hd), dtype),
        }
    return c


def init_cache(cfg: ArchConfig, B: int, S_ctx: int, *, dtype=None,
               enc_frames: int = 0) -> dict:
    """Nested decode cache matching the block structure (stacked for scan)."""
    dtype = dtype or cfg.adtype
    prologue, pattern, n_scan = stack_pattern(cfg)
    cache: dict[str, Any] = {}
    for i, s in enumerate(prologue):
        cache[f"pro{i}"] = _init_layer_cache(cfg, s, B, S_ctx, dtype, enc_frames)

    def one_unit(_):
        return {f"l{i}": _init_layer_cache(cfg, s, B, S_ctx, dtype, enc_frames)
                for i, s in enumerate(pattern)}

    cache["blocks"] = jax.vmap(one_unit)(jnp.arange(n_scan))
    return cache


def _decode_layer(x, p, c, cfg: ArchConfig, s: LayerSpec, pos, mesh):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_c = dict(c)
    if s.kind == "attn":
        mix, new_c["kv"] = attention_decode(h, p["attn"], cfg, c["kv"], pos)
    else:
        mix, new_c["ssm"] = ssm_decode(h, p["ssm"], cfg, c["ssm"])
    x = x + mix
    if cfg.encdec:
        h = rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + cross_attention_decode(h, p["cross"], cfg, c["cross"])
    if s.mlp != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if s.mlp == "moe":
            y, _ = moe_layer(h, p["moe"], cfg, mesh=mesh)
        else:
            y = mlp(h, p["mlp"], cfg.mlp_act)
        x = x + y
    return x, new_c


def decode_step(params, cache, token, pos, cfg: ArchConfig, *, mesh=None):
    """One decode step: token (B, 1) int32, scalar pos → (logits (B, V), cache)."""
    prologue, pattern, n_scan = stack_pattern(cfg)
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.adtype)
    if cfg.encdec:
        x = x + _sin_at(pos, cfg.d_model, cfg.adtype)

    new_cache: dict[str, Any] = {}
    for i, s in enumerate(prologue):
        x, new_cache[f"pro{i}"] = _decode_layer(
            x, params[f"pro{i}"], cache[f"pro{i}"], cfg, s, pos, mesh
        )

    def body(carry, xs):
        h = carry
        blk, c = xs
        cs = {}
        for i, s in enumerate(pattern):
            h, cs[f"l{i}"] = _decode_layer(h, blk[f"l{i}"], c[f"l{i}"], cfg, s, pos, mesh)
        return h, cs

    x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]),
                             unroll=_unroll())
    new_cache["blocks"] = new_blocks

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits[:, 0], new_cache


def _sin_at(pos, d, dtype):
    i = jnp.arange(d // 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])[None, None, :].astype(dtype)


def prefill_cross_cache(params, enc_out, cfg: ArchConfig, cache):
    """Fill the decode cache's cross-attention K/V from an encoder pass
    (whisper serving: encoder runs once per request, decode reuses)."""
    if not cfg.encdec:
        return cache
    prologue, pattern, _ = stack_pattern(cfg)
    new_cache = dict(cache)

    def unit_fn(blk):
        return {f"l{i}": precompute_cross_kv(enc_out, blk[f"l{i}"]["cross"], cfg)
                for i, _s in enumerate(pattern)}

    cross = jax.vmap(unit_fn)(params["blocks"])
    nb = {}
    for key, layer_cache in cache["blocks"].items():
        nv = dict(layer_cache)
        if key in cross:
            nv["cross"] = cross[key]
        nb[key] = nv
    new_cache["blocks"] = nb
    for i, _s in enumerate(prologue):
        pc = dict(new_cache[f"pro{i}"])
        pc["cross"] = precompute_cross_kv(enc_out, params[f"pro{i}"]["cross"], cfg)
        new_cache[f"pro{i}"] = pc
    return new_cache
