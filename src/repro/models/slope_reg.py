"""SLOPE as a first-class training feature (DESIGN.md §5).

Proximal-AdamW with a sorted-ℓ1 penalty on designated parameter groups
(default: the embedding/LM-head rows — a vocab-sized multinomial regression,
the paper's §3.2.3 setting).  The σ path follows the paper's
parameterization: σ(0) from the dual-gauge rule evaluated at the first
gradient, geometric decay to σ(0)·ratio across training.

Every ``screen_every`` steps the **strong rule** (surrogate = previous
gradient + λ-gap, Algorithm 2 via the cumsum-argmax closed form) predicts
the active coefficient set; the KKT check (Proposition 1) counts violations.
Screened-out coefficients are exactly zero after the prox, so their
optimizer moments are zeroed too (keeps Adam from resurrecting them and is
the memory win at scale: m/v for inactive rows compress to nothing in
checkpoints).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.lambda_seq import bh_sequence
from repro.core.screening import screen_k
from repro.core.sorted_l1 import prox_sorted_l1

__all__ = ["SlopeRegConfig", "slope_sigma", "apply_slope_prox", "slope_screen_stats"]


@dataclasses.dataclass(frozen=True)
class SlopeRegConfig:
    targets: tuple[str, ...] = ("embed",)
    q: float = 0.1                 # BH parameter
    sigma0: float = 1e-4           # path start (scaled by ‖grad‖ heuristics upstream)
    sigma_ratio: float = 1e-2      # σ(end)/σ(0)
    total_steps: int = 10_000
    screen_every: int = 100


def slope_sigma(step, cfg: SlopeRegConfig):
    frac = jnp.clip(step / max(cfg.total_steps, 1), 0.0, 1.0)
    return cfg.sigma0 * jnp.power(cfg.sigma_ratio, frac)


def _target_leaves(params, targets):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if any(t in pstr for t in targets):
            out.append((pstr, leaf))
    return out


def apply_slope_prox(params, opt_state, step, lr, cfg: SlopeRegConfig):
    """Post-optimizer prox step on target groups + moment zeroing."""
    sigma = slope_sigma(step, cfg)

    def maybe_prox(pstr, leaf, m, v):
        if not any(t in pstr for t in cfg.targets):
            return leaf, m, v
        lam = bh_sequence(leaf.size, cfg.q, dtype=jnp.float32) * sigma * lr
        new = prox_sorted_l1(leaf.astype(jnp.float32), lam).astype(leaf.dtype)
        alive = (new != 0)
        return (new,
                jnp.where(alive, m.astype(jnp.float32), 0.0).astype(m.dtype),
                jnp.where(alive, v.astype(jnp.float32), 0.0).astype(v.dtype))

    flat_p, tdef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(opt_state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(opt_state["v"])[0]
    new_p, new_m, new_v = [], [], []
    for (path, leaf), m, v in zip(flat_p, flat_m, flat_v):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        p2, m2, v2 = maybe_prox(pstr, leaf, m, v)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    treedef = jax.tree_util.tree_structure(params)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {"m": jax.tree_util.tree_unflatten(treedef, new_m),
         "v": jax.tree_util.tree_unflatten(treedef, new_v)},
    )


def slope_screen_stats(params, grads, step, lr, cfg: SlopeRegConfig) -> dict[str, Any]:
    """Strong-rule screen + KKT support check on the target groups.

    Returns per-group: predicted active count (strong rule, next σ),
    certified support-superset size (Proposition 1, current gradient), and
    current nonzero count.  Pure reporting — the prox enforces the sparsity.
    """
    sig_now = slope_sigma(step, cfg)
    sig_next = slope_sigma(step + cfg.screen_every, cfg)
    stats = {}
    gleaves = dict(_target_leaves(grads, cfg.targets))
    for pstr, leaf in _target_leaves(params, cfg.targets):
        g = gleaves[pstr].astype(jnp.float32).ravel()
        lam = bh_sequence(leaf.size, cfg.q, dtype=jnp.float32) * lr
        mag = jnp.sort(jnp.abs(g))[::-1]
        k_strong = screen_k(mag + (sig_now - sig_next) * lam, sig_next * lam)
        k_cert = screen_k(mag, sig_now * lam)
        stats[pstr] = {
            "strong_k": k_strong,
            "superset_k": k_cert,
            "nnz": jnp.sum(leaf != 0),
        }
    return stats
