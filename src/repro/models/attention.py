"""Attention mixers: GQA/MQA full attention, sliding-window (SWA), MLA
(DeepSeek low-rank KV), and cross attention — each with a full-sequence
(train/prefill) form and a single-token cached decode form.

Decode caches:
  full/cross: k, v        (B, S_ctx, K, hd)
  swa:        ring buffer  (B, W, K, hd) + slot positions (B, W)
  mla:        latent ckv   (B, S_ctx, lora) + shared k_rope (B, S_ctx, rope)
              — the paper-faithful MLA memory saving; decode uses the
              absorbed form (scores in latent space, no K/V expansion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import apply_rope, dense, init_dense, rope_cos_sin, shard

__all__ = [
    "init_attention", "attention", "attention_decode",
    "init_attn_cache", "precompute_cross_kv",
]

_NEG = -1e30


def _dus(cache, update, pos, axis: int):
    """dynamic_update_slice at ``pos`` on ``axis`` (index dtypes unified)."""
    idx = [jnp.zeros((), jnp.int32)] * cache.ndim
    idx[axis] = jnp.asarray(pos, jnp.int32)
    return jax.lax.dynamic_update_slice(cache, update.astype(cache.dtype), tuple(idx))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.pdtype
    ks = jax.random.split(key, 8)
    if cfg.attention == "mla" and not cross:
        lo, nope, rope, vd = cfg.mla_kv_lora, cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim
        return {
            "wq": init_dense(ks[0], d, H * (nope + rope), dt),
            "w_dkv": init_dense(ks[1], d, lo, dt),
            "w_krope": init_dense(ks[2], d, rope, dt),
            "kv_norm": jnp.ones((lo,), dt),
            "w_uk": (jax.random.normal(ks[3], (lo, H, nope), jnp.float32)
                     / jnp.sqrt(lo)).astype(dt),
            "w_uv": (jax.random.normal(ks[4], (lo, H, vd), jnp.float32)
                     / jnp.sqrt(lo)).astype(dt),
            "wo": init_dense(ks[5], H * vd, d, dt),
        }
    return {
        "wq": init_dense(ks[0], d, H * hd, dt),
        "wk": init_dense(ks[1], d, K * hd, dt),
        "wv": init_dense(ks[2], d, K * hd, dt),
        "wo": init_dense(ks[3], H * hd, d, dt),
    }


def init_attn_cache(cfg: ArchConfig, B: int, S_ctx: int, dtype) -> dict:
    """Zero decode cache for one layer."""
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((B, S_ctx, cfg.mla_kv_lora), dtype),
            "k_rope": jnp.zeros((B, S_ctx, cfg.mla_qk_rope), dtype),
        }
    K, hd = cfg.n_kv_heads, cfg.hd
    if cfg.attention == "swa":
        W = min(cfg.window or S_ctx, S_ctx)
        return {
            "k": jnp.zeros((B, W, K, hd), dtype),
            "v": jnp.zeros((B, W, K, hd), dtype),
            "slot_pos": jnp.full((W,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((B, S_ctx, K, hd), dtype),
        "v": jnp.zeros((B, S_ctx, K, hd), dtype),
    }


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def _model_axis_size() -> int:
    from .layers import get_axis_rules

    rules = get_axis_rules()
    if not rules:
        return 1
    return rules.get("pad_to", rules["mesh"].shape.get("model", 1))


def _pad_to_mult(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_heads(q: jax.Array, H_eff: int) -> jax.Array:
    """Zero-pad the head dim so it shards over the model axis.  Execution-
    layer only (params stay faithful); padded heads are sliced off after."""
    H = q.shape[2]
    if H_eff == H:
        return q
    pad = jnp.zeros(q.shape[:2] + (H_eff - H,) + q.shape[3:], q.dtype)
    return jnp.concatenate([q, pad], axis=2)


def _kv_index_map(H: int, K: int, H_eff: int) -> jax.Array:
    """q-head → kv-head map covering padded heads (they read kv head 0)."""
    g = max(H // K, 1)
    idx = [min(h // g, K - 1) if h < H else 0 for h in range(H_eff)]
    return jnp.asarray(idx, jnp.int32)


def _expand_kv_padded(k: jax.Array, H: int, H_eff: int) -> jax.Array:
    """k (B,T,K,hd) → (B,T,H_eff,hd) honouring GQA groups + head padding."""
    K = k.shape[-2]
    if H_eff == H and K and H % K == 0:
        return _expand_kv(k, H)
    return jnp.take(k, _kv_index_map(H, K, H_eff), axis=2)


_FLASH = False
_FLASH_CHUNK = 1024


def set_flash(v: bool, chunk: int = 1024):
    """§Perf knob: online-softmax chunked attention — the (S,T) score
    matrix never materializes (flash attention's insight, TPU-adapted: KV
    streams through VMEM-sized chunks, f32 running max/denominator).
    Numerically identical to the dense path up to fp associativity."""
    global _FLASH, _FLASH_CHUNK
    _FLASH = v
    _FLASH_CHUNK = chunk


def _sdpa(q, k, v, mask) -> jax.Array:
    """q (B,S,H,hd), k/v (B,T,H,hd), mask (Sm,T) additive, Sm ∈ {1, S}."""
    T = k.shape[1]
    if _FLASH and T > _FLASH_CHUNK and T % _FLASH_CHUNK == 0 and mask.ndim == 2:
        return _sdpa_flash(q, k, v, mask)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _sdpa_flash(q, k, v, mask) -> jax.Array:
    """Online-softmax attention over KV chunks (O(S·chunk) live scores)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    C = _FLASH_CHUNK
    nc = T // C
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    kc = jnp.moveaxis(k.reshape(B, nc, C, H, hd), 1, 0)   # (nc,B,C,H,hd)
    vc = jnp.moveaxis(v.reshape(B, nc, C, H, hd), 1, 0)
    Sm = mask.shape[0]
    mc = jnp.moveaxis(mask.reshape(Sm, nc, C), 1, 0)      # (nc,Sm,C)

    def step(carry, xs):
        m, den, acc = carry
        k_c, v_c, mk = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_c,
                       preferred_element_type=jnp.float32) * scale
        s = s + mk[None, None]                            # (B,H,S,C)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        w = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        den = den * corr + jnp.sum(w, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", w.astype(q.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, den, acc), None

    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    den0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd), jnp.float32)
    (m, den, acc), _ = jax.lax.scan(step, (m0, den0, a0), (kc, vc, mc))
    out = acc / jnp.maximum(den, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def _expand_kv(k: jax.Array, H: int) -> jax.Array:
    K = k.shape[-2]
    if K == H:
        return k
    return jnp.repeat(k, H // K, axis=-2)


def _causal_mask(S: int, T: int, window: int = 0) -> jax.Array:
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos > qpos
    if window:
        m |= kpos <= qpos - window
    return jnp.where(m, _NEG, 0.0).astype(jnp.float32)


def attention(x, p, cfg: ArchConfig, positions, *, causal: bool = True,
              kv_x: jax.Array | None = None, use_rope: bool | None = None) -> jax.Array:
    """Full-sequence attention.  ``kv_x`` switches to cross attention."""
    B, S, d = x.shape
    H = cfg.n_heads
    if cfg.attention == "mla" and kv_x is None:
        return _mla_attention(x, p, cfg, positions)
    K, hd = cfg.n_kv_heads, cfg.hd
    src = x if kv_x is None else kv_x
    T = src.shape[1]
    q = dense(x, p["wq"]).reshape(B, S, H, hd)
    k = dense(src, p["wk"]).reshape(B, T, K, hd)
    v = dense(src, p["wv"]).reshape(B, T, K, hd)
    rope_on = cfg.use_rope if use_rope is None else use_rope
    if rope_on and kv_x is None:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, jnp.float32)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    H_eff = _pad_to_mult(H, _model_axis_size())
    q = shard(_pad_heads(q, H_eff), "batch", None, "heads", None)
    k = shard(_expand_kv_padded(k, H, H_eff), "batch", None, "heads", None)
    v = shard(_expand_kv_padded(v, H, H_eff), "batch", None, "heads", None)
    if kv_x is not None or not causal:
        mask = jnp.zeros((S, T), jnp.float32)
    else:
        mask = _causal_mask(S, T, cfg.window if cfg.attention == "swa" else 0)
    out = _sdpa(q, k, v, mask)[:, :, :H]
    out = shard(out, "batch", None, None, None)
    return dense(out.reshape(B, S, H * hd), p["wo"])


def _mla_attention(x, p, cfg: ArchConfig, positions) -> jax.Array:
    from .layers import rms_norm

    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope, vd = cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim
    q = dense(x, p["wq"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = rms_norm(dense(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = dense(x, p["w_krope"])[:, :, None, :]  # (B,S,1,rope) shared head
    cos, sin = rope_cos_sin(positions, rope, cfg.rope_theta, jnp.float32)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    k_nope = jnp.einsum("bsl,lhn->bshn", ckv, p["w_uk"].astype(x.dtype),
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsl,lhn->bshn", ckv, p["w_uv"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
    H_eff = _pad_to_mult(H, _model_axis_size())
    q_full = shard(_pad_heads(q_full, H_eff), "batch", None, "heads", None)
    k_full = shard(_expand_kv_padded(k_full, H, H_eff), "batch", None, "heads", None)
    v = _expand_kv_padded(v, H, H_eff)
    out = _sdpa(q_full, k_full, v, _causal_mask(S, S))[:, :, :H]
    return dense(out.reshape(B, S, H * vd), p["wo"])


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------


def attention_decode(x, p, cfg: ArchConfig, cache: dict, pos) -> tuple[jax.Array, dict]:
    """x (B,1,d), scalar ``pos``; returns (y (B,1,d), updated cache)."""
    if cfg.attention == "mla":
        return _mla_decode(x, p, cfg, cache, pos)
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = dense(x, p["wq"]).reshape(B, 1, H, hd)
    k1 = dense(x, p["wk"]).reshape(B, 1, K, hd)
    v1 = dense(x, p["wv"]).reshape(B, 1, K, hd)
    if cfg.use_rope:
        cos, sin = rope_cos_sin(jnp.full((1,), pos, jnp.int32), hd, cfg.rope_theta, jnp.float32)
        q = apply_rope(q, cos, sin)
        k1 = apply_rope(k1, cos, sin)

    if cfg.attention == "swa":
        W = cache["k"].shape[1]
        slot = pos % W
        k = _dus(cache["k"], k1, slot, 1)
        v = _dus(cache["v"], v1, slot, 1)
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], jnp.full((1,), pos, jnp.int32),
            (jnp.asarray(slot, jnp.int32),)
        )
        new_cache = {"k": k, "v": v, "slot_pos": slot_pos}
        valid = (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - (cfg.window or W))
    else:
        k = _dus(cache["k"], k1, pos, 1)
        v = _dus(cache["v"], v1, pos, 1)
        new_cache = {"k": k, "v": v}
        valid = jnp.arange(k.shape[1]) <= pos

    mask = jnp.where(valid, 0.0, _NEG).astype(jnp.float32)[None, :]  # (1,T)
    H_eff = _pad_to_mult(H, _model_axis_size())
    q = _pad_heads(q, H_eff)
    kx = _expand_kv_padded(k.astype(x.dtype), H, H_eff)
    vx = _expand_kv_padded(v.astype(x.dtype), H, H_eff)
    out = _sdpa(q, kx, vx, mask)[:, :, :H]
    y = dense(out.reshape(B, 1, H * hd), p["wo"])
    return y, new_cache


def _mla_decode(x, p, cfg: ArchConfig, cache: dict, pos) -> tuple[jax.Array, dict]:
    from .layers import rms_norm

    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vd, lo = cfg.mla_qk_nope, cfg.mla_qk_rope, cfg.mla_v_dim, cfg.mla_kv_lora
    q = dense(x, p["wq"]).reshape(B, 1, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv1 = rms_norm(dense(x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)  # (B,1,lo)
    kr1 = dense(x, p["w_krope"])[:, :, None, :]
    cos, sin = rope_cos_sin(jnp.full((1,), pos, jnp.int32), rope, cfg.rope_theta, jnp.float32)
    q_rope = apply_rope(q_rope, cos, sin)
    kr1 = apply_rope(kr1, cos, sin)[:, :, 0, :]

    ckv = _dus(cache["ckv"], ckv1, pos, 1)
    krope = _dus(cache["k_rope"], kr1[:, None, :] if kr1.ndim == 2 else kr1, pos, 1)
    new_cache = {"ckv": ckv, "k_rope": krope}

    # absorbed decode: queries projected into the latent space
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["w_uk"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv.astype(x.dtype), preferred_element_type=jnp.float32)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, krope.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    ) / jnp.sqrt(jnp.float32(nope + rope))
    valid = jnp.arange(ckv.shape[1]) <= pos
    scores = scores + jnp.where(valid, 0.0, _NEG)[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    lat = jnp.einsum("bhqs,bsl->bqhl", probs, ckv.astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bqhl,lhv->bqhv", lat, p["w_uv"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y = dense(out.reshape(B, 1, H * vd), p["wo"])
    return y, new_cache


def precompute_cross_kv(enc_out, p, cfg: ArchConfig) -> dict:
    """Cross-attention K/V from encoder output, computed once per request."""
    B, T, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": dense(enc_out, p["wk"]).reshape(B, T, K, hd),
        "v": dense(enc_out, p["wv"]).reshape(B, T, K, hd),
    }


def cross_attention_decode(x, p, cfg: ArchConfig, cross_kv: dict) -> jax.Array:
    """Decoder-side cross attention against precomputed encoder K/V."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = dense(x, p["wq"]).reshape(B, 1, H, hd)
    H_eff = _pad_to_mult(H, _model_axis_size())
    q = _pad_heads(q, H_eff)
    k = _expand_kv_padded(cross_kv["k"].astype(x.dtype), H, H_eff)
    v = _expand_kv_padded(cross_kv["v"].astype(x.dtype), H, H_eff)
    out = _sdpa(q, k, v, jnp.zeros((1, k.shape[1]), jnp.float32))[:, :, :H]
    return dense(out.reshape(B, 1, H * hd), p["wo"])
