"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

Design (DESIGN.md §6): activations entering an MoE layer are replicated
across the ``model`` axis (the TP convention after an all-reduced mixer), so
*no all-to-all is needed for dispatch* — each model shard owns E/M experts,
selects the tokens routed to them with a local gather, runs its experts, and
the combine is a scatter-add followed by the same ``psum`` over ``model``
that a TP FFN would issue anyway.  Dispatch/combine are data movement
(gather/scatter), not einsums against one-hot masks, so HLO FLOPs stay
honest (the classic (tokens × E × C) dispatch einsum inflates compute by
orders of magnitude and would poison the roofline's MODEL/HLO ratio).

Routing is top-k softmax with optional renormalisation; per-expert capacity
C = ceil(T·k/E · capacity_factor) (tokens beyond capacity drop to the
residual path, standard practice).  A load-balancing auxiliary loss is
returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig, MoECfg
from .layers import init_dense, init_mlp, mlp

__all__ = ["init_moe", "moe_layer"]


def init_moe(key, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.pdtype
    ks = jax.random.split(key, 4)
    ekeys = jax.random.split(ks[0], m.n_experts)
    scale = 1.0 / jnp.sqrt(d)

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "wi_gate": (jax.random.normal(k1, (d, m.d_ff_expert), jnp.float32) * scale).astype(dt),
            "wi_up": (jax.random.normal(k2, (d, m.d_ff_expert), jnp.float32) * scale).astype(dt),
            "wo": (jax.random.normal(k3, (m.d_ff_expert, d), jnp.float32) * scale).astype(dt),
        }

    p = {
        "router": init_dense(ks[1], d, m.n_experts, jnp.float32),
        "experts": jax.vmap(one_expert)(ekeys),  # stacked (E, ...) leaves
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[2], d, m.d_ff_shared or m.d_ff_expert * m.n_shared,
                               dt, cfg.mlp_act)
    return p


def _route(x2d, router_w, m: MoECfg):
    """x2d (T, d) → (top-k expert ids (T,k), gates (T,k), router probs (T,E))."""
    logits = jnp.dot(x2d.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    if m.router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_i, top_p, probs


def _expert_ffn(buf, experts, act: str):
    """buf (E_loc, C, d) through per-expert gated MLPs (batched matmul)."""

    def one(xe, pe):
        return mlp(xe, pe, act)

    return jax.vmap(one)(buf, experts)


def _moe_local(x2d, p, m: MoECfg, act: str, e_start, E_loc: int, capacity: int):
    """Dispatch/compute/combine for the experts [e_start, e_start+E_loc).

    Runs identically on every model shard (with different ``e_start``); the
    caller sums the partial outputs (psum over 'model' under shard_map, or
    a plain sum of one shard when unsharded).
    """
    T, d = x2d.shape
    k = m.top_k
    top_i, top_g, probs = _route(x2d, p["router"], m)

    flat_e = top_i.reshape(-1)                      # (T·k,) expert ids
    flat_t = jnp.repeat(jnp.arange(T), k)           # token of each assignment
    flat_g = top_g.reshape(-1).astype(x2d.dtype)

    # rank of each assignment within its expert (stable → earlier tokens win)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=m.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * k) - starts[flat_e[order]]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    local = (flat_e >= e_start) & (flat_e < e_start + E_loc)
    keep = local & (rank < capacity)
    slot = jnp.where(keep, (flat_e - e_start) * capacity + rank, E_loc * capacity)

    buf = jnp.zeros((E_loc * capacity + 1, d), x2d.dtype).at[slot].set(
        jnp.where(keep[:, None], x2d[flat_t], 0.0)
    )[: E_loc * capacity]
    h = _expert_ffn(buf.reshape(E_loc, capacity, d), p["experts"], act)
    h = h.reshape(E_loc * capacity, d)

    gathered = jnp.where(keep[:, None], h[jnp.minimum(slot, E_loc * capacity - 1)], 0.0)
    y = jnp.zeros((T, d), x2d.dtype).at[flat_t].add(gathered * flat_g[:, None])

    # load-balance aux loss (Switch-style): E · Σ_e f_e · P_e
    f = jnp.bincount(flat_e, length=m.n_experts).astype(jnp.float32) / (T * k)
    P = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * P)
    return y, aux


def moe_layer(x, p, cfg: ArchConfig, *, mesh=None) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) → (y, aux_loss).  EP over 'model' when a mesh is given."""
    m = cfg.moe
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)

    if mesh is not None and m.sharding == "ep" and "model" in mesh.shape:
        M = mesh.shape["model"]
        E_pad = ((m.n_experts + M - 1) // M) * M
        E_loc = E_pad // M

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        if (B * S) % max(dp, 1):
            batch_axes = ()  # decode with tiny batches: replicate tokens
            dp = 1
        T_loc = (B * S) // dp
        capacity = max(8, int(T_loc * m.top_k * m.capacity_factor / m.n_experts))

        def body(x_loc, router_w, experts):
            me = jax.lax.axis_index("model")
            pp = {"router": router_w, "experts": experts}
            y, aux = _moe_local(
                x_loc, pp, m, cfg.mlp_act, me * E_loc, E_loc, capacity
            )
            y = jax.lax.psum(y, "model")
            aux = jax.lax.pmean(aux, "model")
            if batch_axes:
                aux = jax.lax.pmean(aux, batch_axes)
            return y, aux

        experts = p["experts"]
        if E_pad != m.n_experts:  # pad expert stack so E divides the axis
            pad = E_pad - m.n_experts
            experts = jax.tree.map(
                lambda w: jnp.concatenate([w, jnp.zeros((pad,) + w.shape[1:], w.dtype)]), experts
            )
        y2d, aux = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(batch_axes if batch_axes else None, None), P(), P("model")),
            out_specs=(P(batch_axes if batch_axes else None, None), P()),
            check_rep=False,
        )(x2d, p["router"], experts)
    else:
        capacity = max(4, int(B * S * m.top_k * m.capacity_factor / m.n_experts))
        y2d, aux = _moe_local(x2d, p, m, cfg.mlp_act, 0, m.n_experts, capacity)

    y = y2d.reshape(B, S, d)
    if m.n_shared:
        y = y + mlp(x, p["shared"], cfg.mlp_act)
    return y, aux
