"""Architecture configuration for the LM substrate.

One frozen dataclass describes every assigned architecture; ``configs/``
instantiates them.  The model code (models/*.py) is driven entirely by this
config — no per-arch model classes.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

__all__ = ["MoECfg", "SSMCfg", "ArchConfig"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek style
    d_ff_shared: int = 0         # width of the shared expert block
    period: int = 1              # MoE every `period`-th layer (jamba: 2)
    offset: int = 0              # first MoE layer index within the period
    first_dense: bool = False    # layer 0 dense (DeepSeek-V2)
    d_ff_first_dense: int = 0
    capacity_factor: float = 1.25
    router_renorm: bool = True   # renormalise top-k gate weights
    sharding: Literal["ep", "tp"] = "ep"   # expert- vs tensor-parallel experts


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 1e-3
    dt_max: float = 1e-1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # attention flavour
    attention: Literal["full", "swa", "mla", "none"] = "full"
    window: int = 0              # SWA window (0 = unused)
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # MLA (DeepSeek-V2)
    mla_kv_lora: int = 0
    mla_qk_nope: int = 128
    mla_qk_rope: int = 64
    mla_v_dim: int = 128

    # MLP
    mlp_act: Literal["swiglu", "geglu", "gelu"] = "swiglu"

    moe: MoECfg | None = None
    ssm: SSMCfg | None = None

    # hybrid interleave (jamba): attention at layer % attn_period == attn_offset
    attn_period: int = 0
    attn_offset: int = 0

    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500       # default encoder length for serve shapes

    # VLM stub frontend
    n_patches: int = 0

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"

    # assignment bookkeeping
    sub_quadratic: bool = False  # eligible for long_500k
    source: str = ""             # provenance note

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    def layer_kind(self, idx: int) -> str:
        """'attn' | 'ssm' for the mixer at layer ``idx`` (decoder stack)."""
        if self.ssm is None:
            return "attn"
        if self.attn_period == 0:
            return "ssm"
        return "attn" if idx % self.attn_period == self.attn_offset else "ssm"

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.first_dense and idx == 0:
            return False
        return idx % self.moe.period == self.moe.offset

    def n_params(self) -> int:
        """Analytic parameter count (embedding + stack), for roofline."""
        d, V = self.d_model, self.vocab
        total = V * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            total += self._mixer_params(i) + self._mlp_params(i) + 2 * d
        if self.encdec:
            n_mats = 2 if self.mlp_act == "gelu" else 3
            for _ in range(self.n_enc_layers):
                total += self._attn_params() + n_mats * self.d_ff * d + 2 * d
            # cross attention in every decoder layer
            total += self.n_layers * self._attn_params()
        return total

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE top-k + shared only)."""
        d = self.d_model
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            total += self._mixer_params(i) + self._mlp_params(i, active=True) + 2 * d
        if self.encdec:
            n_mats = 2 if self.mlp_act == "gelu" else 3
            for _ in range(self.n_enc_layers):
                total += self._attn_params() + n_mats * self.d_ff * d + 2 * d
            total += self.n_layers * self._attn_params()
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            lo, nope, rope = self.mla_kv_lora, self.mla_qk_nope, self.mla_qk_rope
            vd = self.mla_v_dim
            H = self.n_heads
            return (d * H * (nope + rope)          # Wq
                    + d * (lo + rope)              # W_dkv + W_k_rope
                    + lo * H * (nope + vd)         # W_uk, W_uv
                    + H * vd * d)                  # Wo
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        return d * H * hd + 2 * d * K * hd + H * hd * d

    def _mixer_params(self, idx: int) -> int:
        d = self.d_model
        if self.layer_kind(idx) == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            H = s.n_heads(d)
            proj_in = d * (2 * di + 2 * s.ngroups * s.d_state + H)
            conv = (di + 2 * s.ngroups * s.d_state) * s.conv_width
            return proj_in + conv + 2 * H + di + di * d  # A_log, D, norm, out
        return self._attn_params()

    def _mlp_params(self, idx: int, active: bool = False) -> int:
        d = self.d_model
        if self.is_moe_layer(idx):
            m = self.moe
            e = (m.top_k if active else m.n_experts)
            total = e * 3 * d * m.d_ff_expert + d * m.n_experts  # experts + router
            if m.n_shared:
                total += 3 * d * (m.d_ff_shared or m.d_ff_expert * m.n_shared)
            return total
        if self.moe is not None and self.moe.first_dense and idx == 0:
            return 3 * d * self.moe.d_ff_first_dense
        if self.layer_kind(idx) == "ssm" and self.family == "ssm":
            return 0  # pure mamba2 blocks have no separate MLP
        n_mats = 2 if self.mlp_act == "gelu" else 3
        return n_mats * d * self.d_ff

    def reduced(self) -> "ArchConfig":
        """A tiny config of the same family for CPU smoke tests."""
        changes: dict = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab=256,
            window=min(self.window, 32) if self.window else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            param_dtype="float32",
            act_dtype="float32",
        )
        if self.encdec:
            changes.update(n_enc_layers=2, enc_frames=16)
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=32,
                d_ff_shared=32 if self.moe.n_shared else 0,
                d_ff_first_dense=64 if self.moe.first_dense else 0,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, headdim=16, chunk=8
            )
        if self.attention == "mla":
            changes.update(mla_kv_lora=32, mla_qk_nope=16, mla_qk_rope=8, mla_v_dim=16)
        if self.attn_period:
            changes.update(n_layers=self.attn_period)  # one superblock
        return dataclasses.replace(self, **changes)
