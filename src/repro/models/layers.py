"""Shared NN layers: norms, rotary embeddings, MLPs, embedding/head.

All matmuls take ``preferred_element_type=float32`` and cast back to the
activation dtype; norms accumulate in f32.  Sharding hints go through
:func:`shard` which reads the ambient logical-axis rules installed by
``repro.launch.sharding`` (identity when unset, so smoke tests run
annotation-free on one device).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "shard", "set_axis_rules", "get_axis_rules",
    "rms_norm", "dense", "mlp", "init_mlp", "init_rms",
    "rope_cos_sin", "apply_rope", "init_dense",
]

_AXIS_RULES: dict | None = None


def set_axis_rules(rules: dict | None):
    """Install logical-axis → mesh-axis rules (launch/sharding.py)."""
    global _AXIS_RULES
    _AXIS_RULES = rules


def get_axis_rules():
    return _AXIS_RULES


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op if no rules)."""
    if _AXIS_RULES is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _AXIS_RULES["mesh"]
    rules = _AXIS_RULES["rules"]
    spec = []
    for ax, size in zip(logical_axes, x.shape):
        mesh_axes = rules.get(ax) if ax else None
        if mesh_axes is None:
            spec.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        total = 1
        for a in mesh_axes:
            total *= mesh.shape[a]
        spec.append(tuple(mesh_axes) if size % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------


def init_rms(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# §Perf knob: dtype of matmul partial sums.  Baseline f32 — XLA then
# all-reduces f32 partial sums for every row-parallel matmul (2× collective
# bytes).  bf16 matches Megatron practice: MXU still accumulates f32
# internally per shard; only the cross-shard reduction payload narrows.
_REDUCE_DTYPE = jnp.float32


def set_reduce_dtype(dt):
    global _REDUCE_DTYPE
    _REDUCE_DTYPE = dt


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w.astype(x.dtype), preferred_element_type=_REDUCE_DTYPE).astype(x.dtype)


# -- gated MLP ---------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, dtype, act: str = "swiglu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi_up": init_dense(k2, d, d_ff, dtype),
        "wo": init_dense(k3, d_ff, d, dtype),
    }
    if act != "gelu":  # gated variants carry a third matrix
        p["wi_gate"] = init_dense(k1, d, d_ff, dtype)
    return p


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    u = dense(x, p["wi_up"])
    u = shard(u, "batch", None, "ffn") if u.ndim == 3 else u
    if act == "gelu":
        h = jax.nn.gelu(u, approximate=True)
    else:
        g = dense(x, p["wi_gate"])
        g = shard(g, "batch", None, "ffn") if g.ndim == 3 else g
        if act == "swiglu":
            h = jax.nn.silu(g) * u
        elif act == "geglu":
            h = jax.nn.gelu(g, approximate=True) * u
        else:
            raise ValueError(act)
    return dense(h, p["wo"])


# -- rotary embeddings ---------------------------------------------------------


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float, dtype):
    """positions (..., S) → cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(S: int, d: int, dtype) -> jax.Array:
    """Additive sinusoidal position encodings (whisper stub frontend)."""
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)
