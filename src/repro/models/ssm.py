"""Mamba2 (SSD — state-space duality) mixer, TPU-adapted.

Training/prefill uses the chunked dual form: within a chunk of Q tokens the
output is a (masked, decay-weighted) Q×Q attention-like matmul — MXU food —
and across chunks a small (H, P, N) state recurrence runs in a ``lax.scan``.
Decode is the O(1) recurrent update.  Hybrid archs (jamba) reuse this block
in place of Mamba-1's selective scan (same recurrence class; documented
adaptation in DESIGN.md).

Projections are split per segment (z | x | BC | dt) instead of one fused
in_proj so tensor-parallel sharding is clean: x/z (d_inner) and heads shard
over ``model``; B, C (ngroups·d_state) stay replicated.

Shapes: x (B, L, H, P); dt (B, L, H); A (H,); B/C (B, L, G, N); state (B, H, P, N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig, SSMCfg
from .layers import init_dense, dense, rms_norm, shard

__all__ = ["init_ssm", "ssm_layer", "ssm_decode", "init_ssm_cache"]


def init_ssm(key, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d, dt_p = cfg.d_model, cfg.pdtype
    di = s.d_inner(d)
    H = s.n_heads(d)
    gn = s.ngroups * s.d_state
    ks = jax.random.split(key, 8)
    u = jax.random.uniform(ks[5], (H,), jnp.float32, s.dt_min, s.dt_max)
    return {
        "w_z": init_dense(ks[0], d, di, dt_p),
        "w_x": init_dense(ks[1], d, di, dt_p),
        "w_bc": init_dense(ks[2], d, 2 * gn, dt_p),
        "w_dt": init_dense(ks[3], d, H, dt_p),
        "conv_x": (jax.random.normal(ks[4], (di, s.conv_width), jnp.float32) * 0.1).astype(dt_p),
        "conv_bc": (jax.random.normal(ks[6], (2 * gn, s.conv_width), jnp.float32)
                    * 0.1).astype(dt_p),
        "dt_bias": jnp.log(jnp.expm1(u)),  # softplus^-1(u), f32
        "A_log": jnp.log(jax.random.uniform(ks[7], (H,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), dt_p),
        "w_out": init_dense(jax.random.fold_in(key, 9), di, d, dt_p),
    }


def _causal_conv(x, w):
    """Depthwise causal conv; x (B, L, C), w (C, width) — unrolled shifts."""
    width = w.shape[1]
    acc = x * w[:, width - 1].astype(x.dtype)
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        acc = acc + shifted * w[:, width - 1 - i].astype(x.dtype)
    return acc


def _ssd_chunked(x, dt, A, Bm, Cm, s: SSMCfg, init_state=None):
    """Chunked SSD scan.

    x (b,l,H,P) f32, dt (b,l,H) f32 (already softplus'ed), A (H,) f32 (<0),
    Bm/Cm (b,l,G,N) f32.  Returns (y (b,l,H,P), final_state (b,H,P,N)).
    """
    b, slen, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(s.chunk, slen)
    l_orig = slen
    if slen % Q:  # pad the tail chunk; dt=0 ⇒ decay 1, no state contribution
        pad = Q - slen % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        slen = slen + pad
    nc = slen // Q
    rep = H // G

    def c(a, shape):  # reshape to chunks
        return a.reshape((b, nc, Q) + shape)

    xc, dtc = c(x, (H, P)), c(dt, (H,))
    Bc, Cc = c(Bm, (G, N)), c(Cm, (G, N))
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)   # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A  # (b,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)       # within-chunk cumulative decay

    # ---- intra-chunk (dual / attention-like) ----
    # M[i,j] = (C_i·B_j) · exp(cum_i − cum_j) · dt_j   for j ≤ i
    G_ij = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh, preferred_element_type=jnp.float32)
    # decay[b,c,h,i,j] = exp(cum[b,c,i,h] − cum[b,c,j,h])
    decay = jnp.exp(
        cum.transpose(0, 1, 3, 2)[:, :, :, :, None] - cum.transpose(0, 1, 3, 2)[:, :, :, None, :]
    )
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask, G_ij * decay, 0.0) * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xc, preferred_element_type=jnp.float32)

    # ---- chunk states ----
    # S_c = Σ_j exp(cum_last − cum_j) dt_j B_j ⊗ x_j   → (b,nc,H,P,N)
    w_state = jnp.exp(cum[:, :, -1:, :] - cum) * dtc    # (b,nc,Q,H)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", w_state, Bh, xc,
                     preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (b,nc,H)

    # ---- inter-chunk recurrence ----
    def step(carry, inp):
        S_prev = carry                                  # (b,H,P,N)
        S_chunk, dec = inp                              # (b,H,P,N), (b,H)
        S_new = dec[:, :, None, None] * S_prev + S_chunk
        return S_new, S_prev

    S0 = jnp.zeros((b, H, P, N), jnp.float32) if init_state is None else init_state
    S_final, S_prevs = lax.scan(
        step,
        S0,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)          # (b,nc,H,P,N)

    # inter contribution: Y_inter[i] = exp(cum_i) · C_i @ S_prev(chunk)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ch, S_prevs,
                         preferred_element_type=jnp.float32) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, slen, H, P)[:, :l_orig]
    return y, S_final


def ssm_layer(u, p, cfg: ArchConfig, *, init_state=None):
    """Full Mamba2 block: u (B, L, d) → (B, L, d); returns (y, final_state)."""
    s = cfg.ssm
    B_, L, d = u.shape
    di = s.d_inner(d)
    H = s.n_heads(d)

    z = dense(u, p["w_z"])
    x = dense(u, p["w_x"])
    bc = dense(u, p["w_bc"])
    dt_raw = dense(u, p["w_dt"]).astype(jnp.float32)

    x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
    bc = jax.nn.silu(_causal_conv(bc, p["conv_bc"]))
    x = shard(x, "batch", None, "ffn")

    gn = s.ngroups * s.d_state
    Bm = bc[..., :gn].reshape(B_, L, s.ngroups, s.d_state).astype(jnp.float32)
    Cm = bc[..., gn:].reshape(B_, L, s.ngroups, s.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xh = x.reshape(B_, L, H, s.headdim).astype(jnp.float32)
    y, S_final = _ssd_chunked(xh, dt, A, Bm, Cm, s, init_state)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, L, di).astype(u.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return dense(y, p["w_out"]), S_final


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ArchConfig, B: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    gn = s.ngroups * s.d_state
    return {
        "state": jnp.zeros((B, H, s.headdim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((B, s.conv_width - 1, di), dtype),
        "conv_bc": jnp.zeros((B, s.conv_width - 1, 2 * gn), dtype),
    }


def _conv_step(x1, state, w):
    """One causal-conv step; x1 (B,C), state (B,width-1,C), w (C,width)."""
    full = jnp.concatenate([state, x1[:, None, :]], axis=1)  # (B,width,C)
    y = jnp.einsum("bwc,cw->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    return y.astype(x1.dtype), full[:, 1:]


def ssm_decode(u1, p, cfg: ArchConfig, cache: dict):
    """One-token decode: u1 (B, 1, d) → (y (B,1,d), new cache)."""
    s = cfg.ssm
    B_, _, d = u1.shape
    di = s.d_inner(d)
    H = s.n_heads(d)
    u = u1[:, 0]

    z = dense(u, p["w_z"])
    x = dense(u, p["w_x"])
    bc = dense(u, p["w_bc"])
    dt_raw = dense(u, p["w_dt"]).astype(jnp.float32)

    x, conv_x = _conv_step(x, cache["conv_x"], p["conv_x"])
    bc, conv_bc = _conv_step(bc, cache["conv_bc"], p["conv_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)

    gn = s.ngroups * s.d_state
    Bm = bc[:, :gn].reshape(B_, s.ngroups, s.d_state).astype(jnp.float32)
    Cm = bc[:, gn:].reshape(B_, s.ngroups, s.d_state).astype(jnp.float32)
    rep = H // s.ngroups
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])          # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B_, H, s.headdim).astype(jnp.float32)

    S = cache["state"]
    S = jnp.exp(dt * A)[:, :, None, None] * S + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh, preferred_element_type=jnp.float32
    )
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch, preferred_element_type=jnp.float32)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B_, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["w_out"])[:, None, :]
    return out, {"state": S, "conv_x": conv_x, "conv_bc": conv_bc}
