"""Solver introspection: structured per-fit telemetry (`PathTrace`).

The device engines already compute, in-graph, everything the paper's
screening argument needs to be *watched* in production — screened-set size
per σ-step, KKT violations caught by the safeguard, compact-tier occupancy
and fallback steps, health-bit transitions.  This module packages those
already-host-transferred arrays (one transfer per fit, off the hot path)
into a :class:`PathTrace` attached to
:class:`repro.core.engine.BatchedPathResult` when
``SolverPolicy(telemetry="summary"|"steps")`` asks for it.

``"summary"`` keeps only per-member aggregates (O(B) memory);
``"steps"`` additionally retains the raw (B, L) per-step arrays.
NumPy + stdlib only — built host-side after the engine returns, so it can
never perturb compiled programs or bit-identity.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PathTrace", "TELEMETRY_MODES"]

TELEMETRY_MODES = ("off", "summary", "steps")


def _health_transitions(health: np.ndarray) -> np.ndarray:
    """Per-member count of σ-steps where the sticky health word changed."""
    h = np.asarray(health)
    if h.ndim != 2 or h.shape[1] < 2:
        return np.zeros(h.shape[0] if h.ndim else 1, np.int32)
    return (h[:, 1:] != h[:, :-1]).sum(axis=1).astype(np.int32)


@dataclasses.dataclass
class PathTrace:
    """Per-fit solver diagnostics (leading axis = batch member).

    Summary fields are always present; the per-step ``(B, L)`` arrays are
    retained only under ``mode="steps"`` (None otherwise).
    """

    mode: str                      # "summary" | "steps"
    n_members: int
    n_steps: int
    p: int                         # native column count (occupancy basis)
    working_set: int | None        # compact W (None: masked engine)
    working_set_top: int | None
    # -- per-member aggregates (always populated) --
    screened_mean: np.ndarray      # (B,) mean |screened| over the path
    screened_peak: np.ndarray      # (B,) peak |screened|
    screened_occupancy: np.ndarray  # (B,) screened_mean / p
    total_violations: np.ndarray   # (B,) KKT violations repaired
    violation_steps: np.ndarray    # (B,) steps with ≥ 1 violation
    total_refits: np.ndarray       # (B,)
    total_solver_iters: np.ndarray  # (B,)
    fallback_steps: np.ndarray     # (B,) masked-fallback steps (0 if masked)
    tier_steps: np.ndarray         # (B, 3) steps served at tier 0/1/2
    health_transitions: np.ndarray  # (B,) health-word change count
    quarantined: np.ndarray        # (B,) bool, final health word nonzero
    # -- per-step arrays (mode == "steps" only) --
    sigmas: np.ndarray | None = None
    n_screened: np.ndarray | None = None
    n_active: np.ndarray | None = None
    n_violations: np.ndarray | None = None
    refits: np.ndarray | None = None
    solver_iters: np.ndarray | None = None
    health: np.ndarray | None = None
    ws_size: np.ndarray | None = None
    ws_tier: np.ndarray | None = None
    compact_fallback: np.ndarray | None = None

    @classmethod
    def from_arrays(cls, *, mode: str, p: int, sigmas, n_screened, n_active,
                    n_violations, refits, solver_iters, health,
                    working_set=None, working_set_top=None, ws_size=None,
                    ws_tier=None, compact_fallback=None) -> "PathTrace":
        if mode not in ("summary", "steps"):
            raise ValueError(
                f"telemetry mode must be 'summary' or 'steps', got {mode!r}")
        scr = np.asarray(n_screened)
        viol = np.asarray(n_violations)
        hlth = np.asarray(health)
        B, L = scr.shape
        fb = (np.zeros((B, L), bool) if compact_fallback is None
              else np.asarray(compact_fallback).astype(bool))
        tier = (np.full((B, L), 1, np.int32) if ws_tier is None
                else np.asarray(ws_tier))
        tier_steps = np.stack(
            [(tier == t).sum(axis=1) for t in (0, 1, 2)], axis=1
        ).astype(np.int32)
        tr = cls(
            mode=mode, n_members=B, n_steps=L, p=int(p),
            working_set=working_set, working_set_top=working_set_top,
            screened_mean=scr.mean(axis=1),
            screened_peak=scr.max(axis=1).astype(np.int32),
            screened_occupancy=scr.mean(axis=1) / max(int(p), 1),
            total_violations=viol.sum(axis=1).astype(np.int64),
            violation_steps=(viol > 0).sum(axis=1).astype(np.int32),
            total_refits=np.asarray(refits).sum(axis=1).astype(np.int64),
            total_solver_iters=np.asarray(solver_iters).sum(axis=1)
                                 .astype(np.int64),
            fallback_steps=fb.sum(axis=1).astype(np.int32),
            tier_steps=tier_steps,
            health_transitions=_health_transitions(hlth),
            quarantined=hlth[:, -1].astype(bool),
        )
        if mode == "steps":
            tr.sigmas = np.asarray(sigmas)
            tr.n_screened = scr
            tr.n_active = np.asarray(n_active)
            tr.n_violations = viol
            tr.refits = np.asarray(refits)
            tr.solver_iters = np.asarray(solver_iters)
            tr.health = hlth
            tr.ws_size = None if ws_size is None else np.asarray(ws_size)
            tr.ws_tier = None if ws_tier is None else np.asarray(ws_tier)
            tr.compact_fallback = (None if compact_fallback is None
                                   else np.asarray(compact_fallback))
        return tr

    # -- views --------------------------------------------------------------

    def member(self, b: int) -> dict:
        """One member's aggregates as a JSON-safe dict."""
        out = {
            "member": int(b),
            "screened_mean": float(self.screened_mean[b]),
            "screened_peak": int(self.screened_peak[b]),
            "screened_occupancy": float(self.screened_occupancy[b]),
            "total_violations": int(self.total_violations[b]),
            "violation_steps": int(self.violation_steps[b]),
            "total_refits": int(self.total_refits[b]),
            "total_solver_iters": int(self.total_solver_iters[b]),
            "fallback_steps": int(self.fallback_steps[b]),
            "tier_steps": [int(t) for t in self.tier_steps[b]],
            "health_transitions": int(self.health_transitions[b]),
            "quarantined": bool(self.quarantined[b]),
        }
        return out

    def summary(self) -> dict:
        """Batch-level aggregates — what the metrics exporters embed."""
        return {
            "mode": self.mode,
            "members": self.n_members,
            "steps": self.n_steps,
            "p": self.p,
            "working_set": self.working_set,
            "working_set_top": self.working_set_top,
            "screened_occupancy_mean": float(self.screened_occupancy.mean()),
            "screened_peak_max": int(self.screened_peak.max()),
            "total_violations": int(self.total_violations.sum()),
            "violation_steps": int(self.violation_steps.sum()),
            "fallback_steps": int(self.fallback_steps.sum()),
            "tier_steps": [int(t) for t in self.tier_steps.sum(axis=0)],
            "health_transitions": int(self.health_transitions.sum()),
            "quarantined": int(self.quarantined.sum()),
        }

    def render(self, b: int = 0) -> str:
        """Per-step table for one member (requires ``mode="steps"``)."""
        if self.mode != "steps":
            rows = [f"PathTrace[{self.mode}] member {b}:"]
            rows += [f"  {k}: {v}" for k, v in self.member(b).items()
                     if k != "member"]
            return "\n".join(rows)
        head = f"{'step':>4} {'sigma':>10} {'|screen|':>8} {'|active|':>8} " \
               f"{'viol':>5} {'refit':>5} {'iters':>6} {'tier':>4}"
        lines = [f"PathTrace member {b} (p={self.p}):", head]
        for s in range(self.n_steps):
            tier = "-" if self.ws_tier is None else int(self.ws_tier[b, s])
            lines.append(
                f"{s:>4} {float(self.sigmas[b, s]):>10.4g} "
                f"{int(self.n_screened[b, s]):>8} "
                f"{int(self.n_active[b, s]):>8} "
                f"{int(self.n_violations[b, s]):>5} "
                f"{int(self.refits[b, s]):>5} "
                f"{int(self.solver_iters[b, s]):>6} {tier:>4}")
        return "\n".join(lines)
