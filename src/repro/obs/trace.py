"""Request tracing: gap-free span timelines for served path fits.

A :class:`Trace` is a per-request timeline built from **cursor-based**
spans: :meth:`Trace.mark` closes a span from the trace's internal cursor to
the given end time and advances the cursor, so consecutive top-level spans
are contiguous *by construction* — the admit → queue → flush → compile →
execute → harvest → deliver chain can have no gaps, which is what lets a
trace account for every microsecond of a request's latency budget.

Out-of-band events (a retry attempt, a bisection split, a slot recycle)
ride as **child spans** via :meth:`Trace.child`: they carry a ``parent``
span name, never move the cursor, and so annotate the timeline without
perturbing its contiguity.

Span vocabulary used by the serving stack (see the README "Observability"
section and ``examples/serve_paths.py`` for a rendered timeline):

========== ==========================================================
``admit``   validation, λ/σ canonicalization, queue insertion
``queue``   waiting in the micro-batcher for fill/deadline
``flush``   batch take + host-side padding (attrs: trigger, slots)
``compile`` program-cache fetch (attrs: ``hit``, ``program``)
``execute`` the compiled whole-grid device program
``init``    async: slot insertion + prefill (attr: ``recycled``)
``chunk``   async: one ``step_chunk``-step compiled slice (attr: round)
``harvest`` unpadding + response assembly
``deliver`` future/poll-table handoff (always the last span)
``retry``/``bisect``/``poisoned`` recovery events (children of the
            span named by their ``parent`` attr / cursor position)
========== ==========================================================

Threading contract: one request's trace is only ever mutated by the thread
currently driving that request (submit thread through admission, dispatcher
thread afterwards — handoff sequenced by the service lock), so spans need
no lock of their own.  stdlib-only module.
"""

from __future__ import annotations

import dataclasses
import time

__all__ = ["Span", "Trace"]


@dataclasses.dataclass
class Span:
    """One named interval; ``parent`` is set on child (event) spans."""

    name: str
    t0: float
    t1: float
    parent: str | None = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "duration_ms": round(self.duration_s * 1e3, 4),
                "parent": self.parent, "attrs": dict(self.attrs)}


class Trace:
    """One request's span timeline (see module docstring)."""

    __slots__ = ("rid", "t0", "cursor", "spans")

    def __init__(self, rid: int | None = None, t0: float | None = None):
        self.rid = rid
        self.t0 = time.perf_counter() if t0 is None else float(t0)
        self.cursor = self.t0
        self.spans: list[Span] = []

    # -- construction -------------------------------------------------------

    def mark(self, name: str, t_end: float | None = None, **attrs) -> Span:
        """Close a top-level span from the cursor to ``t_end`` (now when
        omitted) and advance the cursor — contiguity by construction."""
        t_end = time.perf_counter() if t_end is None else float(t_end)
        t_end = max(t_end, self.cursor)  # clock monotonicity guard
        span = Span(name=name, t0=self.cursor, t1=t_end, attrs=attrs)
        self.spans.append(span)
        self.cursor = t_end
        return span

    def child(self, name: str, t0: float | None = None,
              t1: float | None = None, *, parent: str | None = None,
              **attrs) -> Span:
        """Attach a child/event span without moving the cursor.  ``parent``
        defaults to the most recent top-level span's name."""
        if parent is None:
            parent = self.spans[-1].name if self.spans else "admit"
        t0 = time.perf_counter() if t0 is None else float(t0)
        t1 = t0 if t1 is None else float(t1)
        span = Span(name=name, t0=t0, t1=t1, parent=parent, attrs=attrs)
        self.spans.append(span)
        return span

    # -- introspection ------------------------------------------------------

    def top(self) -> list[Span]:
        """Top-level (cursor-advancing) spans in timeline order."""
        return [s for s in self.spans if s.parent is None]

    def children(self) -> list[Span]:
        return [s for s in self.spans if s.parent is not None]

    def span_names(self) -> list[str]:
        return [s.name for s in self.top()]

    @property
    def total_s(self) -> float:
        return self.cursor - self.t0

    def contiguous(self) -> bool:
        """True when the top-level chain covers admit→deliver with no gaps
        (each span starts exactly where the previous one ended)."""
        tops = self.top()
        if not tops:
            return False
        if tops[0].t0 != self.t0:
            return False
        return all(b.t0 == a.t1 for a, b in zip(tops, tops[1:]))

    def well_parented(self) -> bool:
        """Every child span names a parent that appears earlier in the
        span list — the ordering invariant the async stress test pins."""
        seen: set[str] = set()
        for s in self.spans:
            if s.parent is None:
                seen.add(s.name)
            elif s.parent not in seen:
                return False
        return True

    # -- export -------------------------------------------------------------

    def to_events(self, **extra) -> list[dict]:
        """JSON-safe event list (relative times) for the JSONL exporter."""
        return [
            {"rid": self.rid, **extra, **s.to_dict(),
             "t0": round(s.t0 - self.t0, 6), "t1": round(s.t1 - self.t0, 6)}
            for s in self.spans
        ]

    def render(self, width: int = 40) -> str:
        """Human-readable timeline (the example prints this)."""
        total = max(self.total_s, 1e-12)
        lines = [f"trace rid={self.rid}  total={total * 1e3:.3f} ms"]
        for s in self.spans:
            off = int((s.t0 - self.t0) / total * width)
            bar = max(1, int(s.duration_s / total * width))
            bar = min(bar, width - min(off, width - 1))
            indent = "  " if s.parent is not None else ""
            gutter = " " * min(off, width - 1) + "#" * bar
            attrs = (" " + ",".join(f"{k}={v}" for k, v in s.attrs.items())
                     if s.attrs else "")
            name = s.name if s.parent is None else f"{s.name}<{s.parent}"
            lines.append(f"  {indent}{name:<18}{gutter:<{width + 2}}"
                         f"{s.duration_s * 1e3:9.3f} ms{attrs}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Trace(rid={self.rid}, spans={self.span_names()}, "
                f"total_ms={self.total_s * 1e3:.3f})")
