"""`repro.obs` — observability: metrics registry, request tracing, solver
introspection, exporters and profiler hooks.

Layering contract: importing this package touches **stdlib + NumPy only**
(:mod:`repro.serve.buckets` routes its counters here while ``repro.core``
is still initialising, and the kernels module feeds dispatch telemetry in
at import time).  jax is reached only lazily, inside
:mod:`repro.obs.profile` helpers.
"""

from .export import prometheus_text, registry_events, trace_events, \
    write_jsonl
from .introspect import TELEMETRY_MODES, PathTrace
from .profile import annotate, capture
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, Trace

__all__ = [
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "Trace",
    "Span",
    "PathTrace",
    "TELEMETRY_MODES",
    "registry_events",
    "trace_events",
    "write_jsonl",
    "prometheus_text",
    "annotate",
    "capture",
]
