"""Unified metrics registry: counters, gauges, bounded histograms.

Every counter the serving stack used to hand-roll — the service's
submitted/completed/flush tallies, the program cache's hit/miss/build
accounting, the bucket registry's counters, the compact-GEMV dispatch
telemetry — routes through one :class:`MetricsRegistry` per component, so
``stats()`` dicts become read-through views that cannot drift from the
numbers actually incremented, and one snapshot/export path serves them all.

Design constraints:

* **stdlib + NumPy only.**  :mod:`repro.serve.buckets` is imported while
  ``repro.core`` is still initialising, and it routes its counters here —
  so this module (and the whole ``repro.obs`` package at import time) must
  not import jax or any ``repro`` sibling.
* **One lock per registry.**  All mutation goes through registry methods
  under a single ``RLock``; increments are exact under concurrency (the
  thread test in ``tests/test_obs.py`` pins this).  Components that already
  serialize on their own lock pay one cheap re-entrant acquire.
* **Bounded histograms.**  Every distribution is a fixed-window deque
  (default 4096 samples — the one eviction policy, replacing the three
  ad-hoc deques PR 3/6 grew): percentiles are over the recent window,
  ``total`` counts every observation ever made.

Series are labeled: ``reg.inc("flush", trigger="fill")`` and
``reg.inc("flush", trigger="deadline")`` are distinct monotonic counters
under one name, which is how per-plan batch counts and the user/internal
latency split are kept apart without inventing key-name schemas.
"""

from __future__ import annotations

import threading
from collections import deque

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

DEFAULT_WINDOW = 4096


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, key: tuple) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def _inc(self, n=1):
        self.value += n
        return self.value


class Gauge:
    """Last-written value (occupancy, queue depth, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def _set(self, v):
        self.value = v


class Histogram:
    """Bounded sample window with percentile/mean summaries.

    ``observe`` appends to a ``maxlen``-bounded deque (oldest evicted);
    ``total`` is the monotonic count of every observation, ``retained``
    the window size the percentiles are computed over.
    """

    __slots__ = ("_window", "total")

    def __init__(self, maxlen: int = DEFAULT_WINDOW):
        self._window: deque = deque(maxlen=maxlen)
        self.total = 0

    def _observe(self, v: float):
        self._window.append(float(v))
        self.total += 1

    @property
    def retained(self) -> int:
        return len(self._window)

    @property
    def maxlen(self) -> int:
        return self._window.maxlen

    def values(self) -> np.ndarray:
        return np.asarray(self._window, dtype=float)

    def percentile(self, q: float) -> float:
        vals = self.values()
        return float(np.percentile(vals, q)) if vals.size else 0.0

    def mean(self) -> float:
        vals = self.values()
        return float(vals.mean()) if vals.size else 0.0

    def summary(self) -> dict:
        """JSON-safe p50/p95/p99 + mean over the retained window."""
        vals = self.values()
        if not vals.size:
            return {"count": self.total, "retained": 0, "mean": 0.0,
                    "p50": 0.0, "p95": 0.0, "p99": 0.0}
        p50, p95, p99 = np.percentile(vals, [50, 95, 99])
        return {"count": self.total, "retained": int(vals.size),
                "mean": float(vals.mean()), "p50": float(p50),
                "p95": float(p95), "p99": float(p99)}


class MetricsRegistry:
    """Thread-safe named/labeled counters, gauges and histograms.

    One instance per component (each :class:`~repro.serve.PathService`,
    :class:`~repro.serve.ProgramCache`, :class:`~repro.serve.BucketRegistry`
    owns its own — shared instances would alias the per-service exact-count
    assertions the serve tests make).  ``snapshot()`` is the JSON-safe
    export every dump/exporter reads.
    """

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._lock = threading.RLock()
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._hists: dict[tuple, Histogram] = {}

    # -- counters -----------------------------------------------------------

    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
            return c

    def inc(self, name: str, n=1, **labels):
        """Increment (and create on first use) a counter; returns the new
        value.  ``n`` may be a float (e.g. accumulated build seconds)."""
        with self._lock:
            return self.counter(name, **labels)._inc(n)

    def value(self, name: str, default=0, **labels):
        """Current counter value (``default`` when never incremented)."""
        key = (name, _label_key(labels))
        with self._lock:
            c = self._counters.get(key)
            return default if c is None else c.value

    def label_values(self, name: str, label: str) -> dict:
        """``{label value → counter value}`` across one name's series —
        how ``stats()["plans"]`` reconstructs its per-plan dict."""
        with self._lock:
            out = {}
            for (n, lk), c in self._counters.items():
                if n != name:
                    continue
                for k, v in lk:
                    if k == label:
                        out[v] = c.value
            return out

    # -- gauges -------------------------------------------------------------

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge()
            return g

    def set_gauge(self, name: str, value, **labels) -> None:
        with self._lock:
            self.gauge(name, **labels)._set(value)

    # -- histograms ---------------------------------------------------------

    def histogram(self, name: str, maxlen: int = DEFAULT_WINDOW,
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram(maxlen=maxlen)
            return h

    def observe(self, name: str, value: float, maxlen: int = DEFAULT_WINDOW,
                **labels) -> None:
        with self._lock:
            self.histogram(name, maxlen=maxlen, **labels)._observe(value)

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-safe state dump: ``{"counters": {...}, "gauges": {...},
        "histograms": {series: summary}}`` with Prometheus-style
        ``name{label=value}`` series keys."""
        with self._lock:
            return {
                "namespace": self.namespace,
                "counters": {_series_name(n, lk): c.value
                             for (n, lk), c in self._counters.items()},
                "gauges": {_series_name(n, lk): g.value
                           for (n, lk), g in self._gauges.items()},
                "histograms": {_series_name(n, lk): h.summary()
                               for (n, lk), h in self._hists.items()},
            }
