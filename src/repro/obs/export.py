"""Exporters: JSONL event streams and Prometheus-style text dumps.

Two render paths over the same sources (:class:`MetricsRegistry` snapshots
and :class:`Trace` timelines):

* :func:`write_jsonl` / :func:`registry_events` / :func:`trace_events` —
  newline-delimited JSON, the machine-readable artifact CI uploads next to
  ``BENCH_ci.json`` (``benchmarks/run.py --metrics``).
* :func:`prometheus_text` — the conventional ``# TYPE``-annotated text
  exposition (counters/gauges as-is, histograms as quantile series), for
  scraping or eyeballing (``examples/serve_paths.py`` prints one).

stdlib-only; safe to import anywhere in the layering.
"""

from __future__ import annotations

import json

__all__ = ["registry_events", "trace_events", "write_jsonl",
           "prometheus_text"]


def registry_events(registry, **extra) -> list[dict]:
    """Flatten a registry snapshot into one-event-per-series dicts."""
    snap = registry.snapshot()
    ns = snap["namespace"]
    events = []
    for series, value in snap["counters"].items():
        events.append({"kind": "counter", "namespace": ns, "series": series,
                       "value": value, **extra})
    for series, value in snap["gauges"].items():
        events.append({"kind": "gauge", "namespace": ns, "series": series,
                       "value": value, **extra})
    for series, summary in snap["histograms"].items():
        events.append({"kind": "histogram", "namespace": ns,
                       "series": series, **summary, **extra})
    return events


def trace_events(trace, **extra) -> list[dict]:
    """One event per span (relative times) — see :meth:`Trace.to_events`."""
    return trace.to_events(**extra)


def write_jsonl(path: str, events, *, append: bool = False) -> int:
    """Write events (dicts) as JSON Lines; returns the count written."""
    n = 0
    with open(path, "a" if append else "w") as fh:
        for ev in events:
            fh.write(json.dumps(ev, sort_keys=True, default=str) + "\n")
            n += 1
    return n


def _prom_name(namespace: str, series: str) -> str:
    # series already carries {label=value} suffixes; prefix the namespace
    # and swap the dots/dashes Prometheus identifiers forbid
    base, brace, labels = series.partition("{")
    safe = "".join(c if c.isalnum() or c == "_" else "_" for c in base)
    ns = "".join(c if c.isalnum() or c == "_" else "_" for c in namespace)
    if brace:
        kv = ",".join(f'{k}="{v}"'
                      for k, _, v in (part.partition("=") for part in
                                      labels.rstrip("}").split(",")))
        return f"{ns}_{safe}{{{kv}}}"
    return f"{ns}_{safe}"


def prometheus_text(registry) -> str:
    """Prometheus text exposition of one registry's current state."""
    snap = registry.snapshot()
    ns = snap["namespace"]
    lines = []
    typed = set()  # one ``# TYPE`` line per metric name, not per series

    def _type_line(base: str, kind: str):
        if base not in typed:
            typed.add(base)
            lines.append(f"# TYPE {base} {kind}")

    for series, value in sorted(snap["counters"].items()):
        name = _prom_name(ns, series)
        _type_line(name.split("{")[0], "counter")
        lines.append(f"{name} {value}")
    for series, value in sorted(snap["gauges"].items()):
        name = _prom_name(ns, series)
        _type_line(name.split("{")[0], "gauge")
        lines.append(f"{name} {value}")
    for series, summary in sorted(snap["histograms"].items()):
        name = _prom_name(ns, series)
        base, brace, labels = name.partition("{")
        labels = labels.rstrip("}")
        _type_line(base, "summary")
        for q_label, q_key in (("0.5", "p50"), ("0.95", "p95"),
                               ("0.99", "p99")):
            extra = f'quantile="{q_label}"'
            inner = f"{labels},{extra}" if labels else extra
            lines.append(f"{base}{{{inner}}} {summary[q_key]}")
        suffix = f"{{{labels}}}" if labels else ""
        lines.append(f"{base}_count{suffix} {summary['count']}")
    return "\n".join(lines) + ("\n" if lines else "")
