"""JAX profiler hooks: trace annotations and optional xplane capture.

Thin, lazily-importing wrappers so the rest of ``repro.obs`` stays
importable before (or without) jax:

* :func:`annotate` — a context manager emitting a
  ``jax.profiler.TraceAnnotation`` around a host-side region (the program
  cache wraps ``lower().compile()`` with it, the services wrap batch
  execution), so compile-vs-execute attribution shows up in xplane/perfetto
  captures the same way MaxText's ``profiler=xplane`` runs do.
* :func:`capture` — start/stop a ``jax.profiler`` trace writing an xplane
  dump under a directory (ROADMAP item 3's real-TPU perf pass reads these).

Both degrade to no-ops when jax (or its profiler) is unavailable, so the
observability layer never becomes an import-order or dependency hazard.
"""

from __future__ import annotations

import contextlib

__all__ = ["annotate", "capture"]


def _profiler():
    try:
        import jax.profiler as prof
        return prof
    except Exception:  # pragma: no cover - jax always present in this repo
        return None


@contextlib.contextmanager
def annotate(name: str, **attrs):
    """``with annotate("compile/ols/B8..."):`` — named profiler region.

    Shows up as a host TraceAnnotation in xplane captures; a no-op (empty
    context) when the profiler is unavailable.
    """
    prof = _profiler()
    if prof is None:
        yield
        return
    with prof.TraceAnnotation(name, **attrs):
        yield


@contextlib.contextmanager
def capture(log_dir: str, *, create_perfetto_link: bool = False):
    """``with capture("/tmp/xplane"):`` — record an xplane profile of the
    enclosed region (compile + execute annotations included)."""
    prof = _profiler()
    if prof is None:
        yield
        return
    prof.start_trace(log_dir, create_perfetto_link=create_perfetto_link)
    try:
        yield
    finally:
        prof.stop_trace()
