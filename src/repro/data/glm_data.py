"""GLM data generators matching the paper's simulation setups (§3.2).

* equicorrelated design: Σ_ij = ρ (i≠j), 1 on the diagonal — generated via
  the factor trick  X = √ρ·z·1ᵀ + √(1−ρ)·E  (O(np), no p×p Cholesky).
* AR chain (§3.2.3): X_1 ~ N(0, I); X_j ~ N(ρ·X_{j−1}, I).
* response generators for OLS / logistic / Poisson / multinomial exactly as
  specified in the paper's text.
Predictors are normalised to  x̄_j = 0, ‖x_j‖₂ = 1 and y is centred for OLS
(paper §3.1) unless ``normalize=False``.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "equicorrelated_design", "ar_chain_design", "normalize_design",
    "make_regression", "make_classification", "make_poisson", "make_multinomial",
]


def normalize_design(X: np.ndarray) -> np.ndarray:
    X = X - X.mean(axis=0, keepdims=True)
    norms = np.linalg.norm(X, axis=0, keepdims=True)
    norms[norms == 0] = 1.0
    return X / norms


def equicorrelated_design(n: int, p: int, rho: float, rng) -> np.ndarray:
    z = rng.normal(size=(n, 1))
    E = rng.normal(size=(n, p))
    return np.sqrt(rho) * z + np.sqrt(1.0 - rho) * E


def ar_chain_design(n: int, p: int, rho: float, rng) -> np.ndarray:
    X = np.empty((n, p))
    X[:, 0] = rng.normal(size=n)
    for j in range(1, p):
        X[:, j] = rho * X[:, j - 1] + rng.normal(size=n)
    return X


def _design(n, p, rho, rng, kind):
    X = (ar_chain_design if kind == "ar" else equicorrelated_design)(n, p, rho, rng)
    return normalize_design(X)


def make_regression(n, p, k, rho=0.0, seed=0, design="equi", beta_kind="pm2",
                    noise=1.0):
    """y = Xβ + ε.  β: first k entries ±2 (paper §3.2.1 variant) or N(0,1)."""
    rng = np.random.default_rng(seed)
    X = _design(n, p, rho, rng, design)
    beta = np.zeros(p)
    if beta_kind == "pm2":
        beta[:k] = rng.choice([-2.0, 2.0], size=k)
    elif beta_kind == "normal":
        beta[:k] = rng.normal(size=k)
    else:  # paper §3.2.3: sample without replacement from {1..20}
        beta[:k] = rng.choice(np.arange(1, 21), size=k, replace=False)
    y = X @ beta + noise * rng.normal(size=n)
    y = y - y.mean()
    return X, y, beta


def make_classification(n, p, k, rho=0.0, seed=0, design="ar", noise_var=20.0):
    rng = np.random.default_rng(seed)
    X = _design(n, p, rho, rng, design)
    beta = np.zeros(p)
    beta[:k] = rng.choice(np.arange(1, 21), size=k, replace=False)
    z = X @ beta + np.sqrt(noise_var) * rng.normal(size=n)
    y = (np.sign(z) > 0).astype(np.float64)
    return X, y, beta


def make_poisson(n, p, k, rho=0.0, seed=0, design="ar"):
    rng = np.random.default_rng(seed)
    X = _design(n, p, rho, rng, design)
    beta = np.zeros(p)
    beta[:k] = rng.choice(np.arange(1, 21) / 40.0, size=k, replace=False)
    y = rng.poisson(np.exp(X @ beta)).astype(np.float64)
    return X, y, beta


def make_multinomial(n, p, k, m=3, rho=0.0, seed=0, design="ar"):
    rng = np.random.default_rng(seed)
    X = _design(n, p, rho, rng, design)
    beta = np.zeros((p, m))
    rows = rng.choice(p, size=k, replace=False)
    vals = rng.choice(np.arange(1, 21), size=k, replace=False)
    for r, v in zip(rows, vals):
        beta[r, rng.integers(m)] = v
    Z = X @ beta
    probs = np.exp(Z - Z.max(axis=1, keepdims=True))
    probs /= probs.sum(axis=1, keepdims=True)
    y = np.array([rng.choice(m, p=pr) for pr in probs], dtype=np.int32)
    return X, y, beta
