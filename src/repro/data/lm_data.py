"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host) so a restarted or
re-sharded job regenerates exactly the stream it would have seen — this is
what makes checkpoint/restart exact without persisting data state beyond
the step counter (train/trainer.py).  Hosts draw disjoint sub-streams and
the per-host batch is the host's shard of the global batch.

The token distribution is a Zipf-ish categorical with a deterministic
n-gram flavour (next token depends on the previous one through a fixed
permutation) so models actually have something to learn in the examples.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "lm_batches"]


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed)
        self._perm = rng.permutation(self.vocab)
        ranks = np.arange(1, self.vocab + 1)
        self._base_p = (1.0 / ranks) / np.sum(1.0 / ranks)  # Zipf marginal

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        B, S = self.host_batch, self.seq_len
        first = rng.choice(self.vocab, size=(B,), p=self._base_p)
        noise = rng.choice(self.vocab, size=(B, S), p=self._base_p)
        use_noise = rng.random((B, S)) < 0.25
        tokens = np.empty((B, S), np.int32)
        tokens[:, 0] = first
        for t in range(1, S):
            nxt = self._perm[tokens[:, t - 1]]
            tokens[:, t] = np.where(use_noise[:, t], noise[:, t], nxt)
        return {"tokens": tokens}


def lm_batches(spec: SyntheticLM, start_step: int = 0):
    step = start_step
    while True:
        yield step, spec.batch(step)
        step += 1
