from .lm_data import SyntheticLM, lm_batches
from .glm_data import (
    equicorrelated_design,
    ar_chain_design,
    make_regression,
    make_classification,
    make_poisson,
    make_multinomial,
)

__all__ = [
    "SyntheticLM", "lm_batches",
    "equicorrelated_design", "ar_chain_design", "make_regression",
    "make_classification", "make_poisson", "make_multinomial",
]
