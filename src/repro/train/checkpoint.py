"""Sharded checkpointing with atomic manifests and resharding restore.

Layout:  <dir>/step_<N>/
            manifest.json          step, mesh shape, leaf index, RNG, data pos
            arrays.npz             flattened key-path → array (host values)

Writes go to ``step_<N>.tmp`` and are renamed into place only after fsync —
a preempted writer never corrupts the latest checkpoint.  Restore maps
arrays onto the *current* mesh's shardings (``device_put`` per leaf), so a
job restarted on a different device count (elastic shrink/grow) resumes
transparently.  For multi-host deployments each host would write its own
addressable shards; on this single-host container the npz holds full
arrays — the manifest format already carries the mesh metadata needed for
the per-host extension.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil

import numpy as np

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir, step: int, *, params, opt_state=None, extra=None):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "format": 1,
        "n_leaves": len(arrays),
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune older checkpoints, keep last 3
    steps = sorted(ckpt_dir.glob("step_*"))
    for old in steps[:-3]:
        if old.is_dir() and not str(old).endswith(".tmp"):
            shutil.rmtree(old)
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.glob("step_*"):
        if d.suffix == ".tmp" or not (d / "manifest.json").exists():
            continue  # torn write — ignore
        steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def _unflatten_into(template, arrays, prefix, shardings=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, sh_flat):
        key = prefix + "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)


def restore_checkpoint(ckpt_dir, step: int, *, params_template, opt_template=None,
                       param_shardings=None, opt_shardings=None):
    """Restore onto the current mesh (resharding via device_put)."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")
    params = _unflatten_into(params_template, arrays, "params/", param_shardings)
    opt = None
    if opt_template is not None:
        opt = _unflatten_into(opt_template, arrays, "opt/", opt_shardings)
    return params, opt, manifest
