"""Production train loop: checkpoint/restart, preemption handling,
straggler watchdog, SLOPE-path regularization, metrics logging.

Fault-tolerance model (single-controller JAX):
  * periodic atomic checkpoints (params + optimizer + step) — restart
    resumes from the newest valid manifest and the deterministic data
    pipeline regenerates the exact stream from the step counter;
  * SIGTERM/SIGINT → checkpoint-and-exit (preemption hook);
  * per-step wall-clock watchdog: a step slower than ``straggler_factor`` ×
    the running median is logged as a straggler event; the driver-level
    response (re-dispatch on a spare slice) is a deployment policy — here we
    record and continue, and the elastic mesh helper (launch/mesh.py) covers
    the restart-on-fewer-devices path.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.models import init_params, lm_loss
from repro.models.config import ArchConfig
from repro.models.slope_reg import SlopeRegConfig, apply_slope_prox, slope_screen_stats
from repro.optim import AdamWHyper, adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "runs/ckpt"
    seed: int = 0
    straggler_factor: float = 3.0
    slope: SlopeRegConfig | None = None


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig, *, mesh=None,
                 hyper: AdamWHyper | None = None, global_batch: int = 8,
                 seq_len: int = 64):
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.hyper = hyper or AdamWHyper()
        self.data = SyntheticLM(cfg.vocab, seq_len, global_batch, seed=tc.seed)
        self._stop = False
        self.step_times: list[float] = []
        self.straggler_events: list[dict] = []
        self.metrics_log: list[dict] = []

        def train_step(params, opt_state, batch, step):
            def loss_fn(p):
                return lm_loss(p, batch, cfg, mesh=mesh)

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            lr = cosine_warmup(step, peak=self.hyper.lr, warmup=10, total=tc.steps)
            params, opt_state = adamw_update(params, grads, opt_state, step,
                                             self.hyper, lr=lr)
            if tc.slope is not None:
                params, opt_state = apply_slope_prox(params, opt_state, step, lr,
                                                     tc.slope)
            return params, opt_state, dict(metrics, loss=loss, lr=lr), grads

        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # -- lifecycle -----------------------------------------------------------

    def _install_preemption_hook(self):
        def handler(signum, frame):
            self._stop = True

        self._old = {s: signal.signal(s, handler) for s in (signal.SIGTERM, signal.SIGINT)}

    def _restore_hooks(self):
        for s, h in getattr(self, "_old", {}).items():
            signal.signal(s, h)

    def run(self) -> dict:
        cfg, tc = self.cfg, self.tc
        params = init_params(cfg, jax.random.PRNGKey(tc.seed))
        opt_state = adamw_init(params, self.hyper)
        start = 0
        last = latest_step(tc.ckpt_dir)
        if last is not None:
            params, opt_state, manifest = restore_checkpoint(
                tc.ckpt_dir, last, params_template=params, opt_template=opt_state
            )
            start = manifest["step"] + 1
            print(f"[trainer] resumed from step {last}")

        self._install_preemption_hook()
        try:
            step = start
            while step < tc.steps and not self._stop:
                t0 = time.perf_counter()
                batch = {k: jnp.asarray(v) for k, v in self.data.batch(step).items()}
                params, opt_state, metrics, grads = self.train_step(
                    params, opt_state, batch, jnp.int32(step)
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0

                self.step_times.append(dt)
                med = statistics.median(self.step_times[-50:])
                if len(self.step_times) > 5 and dt > tc.straggler_factor * med:
                    self.straggler_events.append({"step": step, "dt": dt, "median": med})
                    print(f"[trainer] straggler: step {step} took {dt:.2f}s (median {med:.2f}s)")

                if step % tc.log_every == 0 or step == tc.steps - 1:
                    row = {k: float(v) for k, v in metrics.items()}
                    row["step"] = step
                    if tc.slope is not None and step % tc.slope.screen_every == 0:
                        stats = slope_screen_stats(
                            params, grads, step, float(metrics["lr"]), tc.slope
                        )
                        for grp, s in stats.items():
                            row[f"slope/{grp}/strong_k"] = int(s["strong_k"])
                            row[f"slope/{grp}/nnz"] = int(s["nnz"])
                    self.metrics_log.append(row)
                    print(f"[trainer] step {step:5d} loss {row['loss']:.4f}")

                if step % tc.ckpt_every == 0 and step > start:
                    save_checkpoint(tc.ckpt_dir, step, params=params,
                                    opt_state=opt_state)
                step += 1

            final_step = step - 1
            save_checkpoint(tc.ckpt_dir, final_step, params=params, opt_state=opt_state)
            if self._stop:
                print(f"[trainer] preempted at step {final_step}; checkpoint saved")
        finally:
            self._restore_hooks()
        return {
            "final_step": final_step,
            "params": params,
            "metrics": self.metrics_log,
            "stragglers": self.straggler_events,
            "preempted": self._stop,
        }
