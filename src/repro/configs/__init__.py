"""Assigned-architecture configs.  ``get_config(name)`` is the registry used
by --arch flags everywhere (launcher, dry-run, benchmarks, tests)."""

from __future__ import annotations

import importlib

_ARCHS = [
    "jamba_1_5_large_398b",
    "whisper_medium",
    "smollm_360m",
    "starcoder2_15b",
    "gemma_7b",
    "h2o_danube_1_8b",
    "deepseek_v2_lite_16b",
    "granite_moe_3b_a800m",
    "llava_next_mistral_7b",
    "mamba2_1_3b",
]

ALIASES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-medium": "whisper_medium",
    "smollm-360m": "smollm_360m",
    "starcoder2-15b": "starcoder2_15b",
    "gemma-7b": "gemma_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCH_NAMES = list(ALIASES)


def get_config(name: str):
    mod_name = ALIASES.get(name, name)
    if mod_name not in _ARCHS:
        raise ValueError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {n: get_config(n) for n in ARCH_NAMES}
