"""whisper-medium — encoder-decoder audio transformer (backbone only).

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 51865, gelu MLPs, sinusoidal positions (no RoPE).  The conv frame
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
frame embeddings (B, F, d_model).  [arXiv:2212.04356; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    mlp_act="gelu",
    use_rope=False,
    encdec=True,
    n_enc_layers=24,
    enc_frames=1500,
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2212.04356 (Whisper); openai/whisper-medium",
)
