"""h2o-danube-1.8b — llama/mistral-mix dense LM with sliding-window attention.

24L d_model=2560, 32 heads / 8 KV, d_ff 6912, vocab 32000, SWA window 4096.
[arXiv:2401.16818; hf h2oai/h2o-danube-1.8b-base]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    attention="swa",
    window=4096,
    mlp_act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,  # SWA caps the KV cache at the window
    source="arXiv:2401.16818 (H2O-Danube)",
)
