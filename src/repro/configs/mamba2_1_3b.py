"""mamba2-1.3b — attention-free SSM LM (SSD, state-space duality).

48L d_model=2048, d_state 128, expand 2, headdim 64 → 64 SSM heads,
vocab 50280.  [arXiv:2405.21060; unverified]
"""

from repro.models.config import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    attention="none",
    use_rope=False,
    ssm=SSMCfg(d_state=128, expand=2, headdim=64, ngroups=1, conv_width=4, chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.21060 (Mamba-2)",
)
