"""granite-moe-3b-a800m — fine-grained MoE LM.

32L d_model=1536, 24 heads / 8 KV, expert d_ff 512, vocab 49155,
MoE 40 experts top-8 (assignment header; the "32 experts" note refers to
the 1b sibling — DESIGN.md §4).  [hf ibm-granite/granite-3.0-3b-a800m-base]
"""

from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    moe=MoECfg(
        n_experts=40,            # padded to 48 for 16-way EP (3 dummies/shard)
        top_k=8,
        d_ff_expert=512,
        capacity_factor=1.25,
    ),
    mlp_act="swiglu",
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf ibm-granite/granite-3.0-3b-a800m-base",
)
