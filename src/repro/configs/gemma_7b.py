"""gemma-7b — dense LM with GeGLU and head_dim 256.

28L d_model=3072, 16 heads / 16 KV (MHA; the 2b sibling uses MQA),
d_ff 24576, vocab 256000.  [arXiv:2403.08295; hf google/gemma-7b]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256_000,
    mlp_act="geglu",
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2403.08295 (Gemma)",
)
