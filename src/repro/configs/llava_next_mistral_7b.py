"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres vision stub.

32L d_model=4096, 32 heads / 8 KV, d_ff 14336, vocab 32000.  The vision
tower + anyres tiling is a STUB per the assignment: ``input_specs``
supplies 576 precomputed patch embeddings (one base-resolution tile),
spliced ahead of the token stream.  [hf llava-hf/llava-v1.6-mistral-7b-hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    mlp_act="swiglu",
    n_patches=576,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf llava-hf/llava-v1.6-mistral-7b-hf",
)
