"""starcoder2-15b — dense code LM, GQA + RoPE, non-gated gelu MLP.

40L d_model=6144, 48 heads / 4 KV, d_ff 24576, vocab 49152.
[arXiv:2402.19173; hf bigcode/starcoder2-15b]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    mlp_act="gelu",  # starcoder2 uses a standard (non-gated) FFN
    rope_theta=100_000.0,
    tie_embeddings=True,
    sub_quadratic=False,
    source="arXiv:2402.19173 (StarCoder2)",
)
