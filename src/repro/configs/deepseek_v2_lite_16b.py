"""deepseek-v2-lite-16b — MoE LM with Multi-head Latent Attention (MLA).

27L d_model=2048, 16 heads, MLA kv_lora=512 (qk_nope 128 + qk_rope 64,
v 128), vocab 102400.  MoE: 64 routed top-6 + 2 shared experts,
expert d_ff 1408; layer 0 dense (d_ff 10944).  [arXiv:2405.04434; hf]

Spec note: the assignment header says "MoE 64e top-6"; the "160 routed"
parenthetical belongs to full V2 — 64 routed is the Lite config (DESIGN.md §4).
"""

from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102_400,
    attention="mla",
    mla_kv_lora=512,
    mla_qk_nope=128,
    mla_qk_rope=64,
    mla_v_dim=128,
    moe=MoECfg(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        d_ff_shared=2816,        # 2 shared experts × 1408
        first_dense=True,
        d_ff_first_dense=10944,
        capacity_factor=1.25,
    ),
    tie_embeddings=False,
    sub_quadratic=False,
    source="arXiv:2405.04434 (DeepSeek-V2)",
)
