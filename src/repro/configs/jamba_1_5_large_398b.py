"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.

72L d_model=8192, 64 Q heads / 8 KV (GQA), d_ff 24576, vocab 65536,
MoE 16 experts top-2 on every second layer; attention on 1 of every 8
layers.  ≈398 B total / ≈94 B active.  [arXiv:2403.19887; hf]

Adaptation note (DESIGN.md §4): Mamba-1 selective-scan layers are realised
with the SSD chunked recurrence (d_state 16, headdim 64 → 256 SSM heads) —
same state size and recurrence class, TPU-friendly chunk matmuls.
"""

from repro.models.config import ArchConfig, MoECfg, SSMCfg

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    attention="full",
    moe=MoECfg(
        n_experts=16,
        top_k=2,
        d_ff_expert=24576,
        period=2,
        offset=1,
        capacity_factor=1.25,
    ),
    ssm=SSMCfg(d_state=16, expand=2, headdim=64, ngroups=8, conv_width=4, chunk=256),
    attn_period=8,
    attn_offset=4,
    tie_embeddings=False,
    sub_quadratic=True,  # attention in 9/72 layers only; 1.5 targets 256K ctx
    source="arXiv:2403.19887 (Jamba-1.5); hf ai21labs/AI21-Jamba-1.5-Large",
)
