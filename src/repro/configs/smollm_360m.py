"""smollm-360m — llama-architecture small dense LM.

32L d_model=960, 15 heads / 5 KV (GQA 3:1), d_ff 2560, vocab 49152.
[hf:HuggingFaceTB/SmolLM-360M; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    mlp_act="swiglu",
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf HuggingFaceTB/SmolLM-360M",
)
