"""AdamW without external dependencies.

State dtype is configurable: fp32 for ≤20 B-param models, bf16 for
jamba-398b so a single v5e pod's HBM holds params + states (DESIGN.md §6);
all update math runs in f32 regardless.  State sharding (ZeRO-1) is applied
by the caller via jit in_shardings — see launch/sharding.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWHyper", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWHyper:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"


def adamw_init(params, hyper: AdamWHyper = AdamWHyper()):
    dt = jnp.dtype(hyper.state_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, state, step, hyper: AdamWHyper, lr=None):
    """Returns (new_params, new_state).  ``step`` is 0-based; a traced ``lr``
    (schedule value) overrides the static ``hyper.lr``."""
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - jnp.power(hyper.b1, t)
    c2 = 1.0 - jnp.power(hyper.b2, t)
    dt = jnp.dtype(hyper.state_dtype)
    lr = hyper.lr if lr is None else lr

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = hyper.b1 * m.astype(jnp.float32) + (1 - hyper.b1) * g32
        v32 = hyper.b2 * v.astype(jnp.float32) + (1 - hyper.b2) * jnp.square(g32)
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + hyper.eps) + hyper.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}
