from .adamw import adamw_init, adamw_update, AdamWHyper
from .schedules import cosine_warmup

__all__ = ["adamw_init", "adamw_update", "AdamWHyper", "cosine_warmup"]
