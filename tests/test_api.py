"""repro.api — the declarative front door (ISSUE 4).

Contracts under test:

* the public API surface is snapshot-pinned (spec field renames are
  breaking changes and must fail CI);
* the planner selects the compact engine for p ≫ n batches, the masked
  engine for n ≳ p batches, and the gathered host driver for single
  problems — and planner-selected execution is BIT-IDENTICAL to spelling
  the same backend out with the explicit legacy kwargs;
* every legacy call signature from PRs 1–3 still returns bit-identical
  results and warns exactly once per (function, kwarg);
* specs are pytrees; OLS sample weights reduce exactly to row duplication;
* `PathService.submit` accepts the same spec triple and stays bit-identical
  to direct padded execution, with executed plans visible in `stats()`.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax

import repro.api as api
from repro.api import (
    ExecutionPlan,
    LambdaSpec,
    PathSpec,
    Problem,
    SlopE,
    SolverPolicy,
    plan_execution,
    slope_path,
)
from repro.api.compat import reset_legacy_warnings
from repro.core import bh_sequence, cv_path, fit_path, fit_path_batched, logistic, ols
from repro.core.engine import _WS_BUCKETS
from repro.data import make_classification, make_regression

KW = dict(path_length=6, solver_tol=1e-10, max_iter=20000, kkt_tol=1e-4)
POL = dict(solver_tol=1e-10, max_iter=20000, kkt_tol=1e-4)


def _problem(n, p, seed=0, k=4, noise=1.0, rho=0.2):
    X, y, _ = make_regression(n, p, k=k, rho=rho, seed=seed, noise=noise)
    return X, y, np.asarray(bh_sequence(p, q=0.1))


def _batch(B, n, p, *, k=4, rho=0.2, noise=1.0, q=0.1):
    probs = [make_regression(n, p, k=k, rho=rho, seed=s, noise=noise)[:2]
             for s in range(B)]
    return (np.stack([X for X, _ in probs]), np.stack([y for _, y in probs]),
            np.asarray(bh_sequence(p, q=q)))


# ---------------------------------------------------------------------------
# public API surface (CI satellite: accidental breakage must fail fast)
# ---------------------------------------------------------------------------

EXPECTED_ALL = {
    "Problem", "LambdaSpec", "PathSpec", "SolverPolicy", "ExecutionPlan",
    "plan_execution", "slope_path", "SlopE", "as_lambda_spec",
    "default_service", "default_async_service", "shared_canonicalizer",
    "ValidationError", "find_nonfinite", "ResamplePlan",
}

EXPECTED_FIELDS = {
    Problem: ["X", "y", "family", "weights"],
    LambdaSpec: ["kind", "q", "values"],
    PathSpec: ["lam", "path_length", "sigma_ratio", "sigmas", "early_stop",
               "cv_folds", "stratify", "selection", "resample"],
    SolverPolicy: ["backend", "working_set", "ws_tiers", "pad", "screening",
                   "solver_tol", "max_iter", "kkt_tol", "max_refits",
                   "verbose", "deadline_ms", "priority", "validate",
                   "telemetry", "solve_timeout_ms"],
    ExecutionPlan: ["backend", "mode", "batch", "n", "p", "working_set",
                    "ws_tiers", "pad", "exec_shape", "screening", "device",
                    "reasons"],
}


def test_public_api_surface_snapshot():
    assert set(api.__all__) == EXPECTED_ALL
    for cls, fields in EXPECTED_FIELDS.items():
        assert [f.name for f in dataclasses.fields(cls)] == fields, cls


def test_spec_validation_errors():
    X, y, lam = _problem(20, 24)
    with pytest.raises(ValueError):
        Problem(X[0], y)                      # 1-D X
    with pytest.raises(ValueError):
        Problem(X, y[:-1])                    # row mismatch
    with pytest.raises(ValueError):
        Problem(X, y, weights=np.ones(3))     # weight shape
    with pytest.raises(ValueError):
        PathSpec(selection="best")
    with pytest.raises(ValueError):
        PathSpec(cv_folds=1)
    with pytest.raises(ValueError):
        SolverPolicy(backend="gpu")
    with pytest.raises(ValueError):
        SolverPolicy(working_set="big")
    with pytest.raises(ValueError):
        SolverPolicy(pad="always")
    with pytest.raises(ValueError):
        SolverPolicy(screening="weak")
    with pytest.raises(ValueError):
        SolverPolicy(deadline_ms=0.0)
    with pytest.raises(ValueError):
        SolverPolicy(deadline_ms=-5.0)
    with pytest.raises(ValueError):
        SolverPolicy(priority=1.5)
    with pytest.raises(ValueError):
        SolverPolicy(priority=True)
    with pytest.raises(ValueError):
        SolverPolicy(telemetry="verbose")


def test_planner_routes_slo_knobs_to_serve():
    X, y, lam = _problem(20, 24)
    pb = Problem(X, y)
    for pol in (SolverPolicy(deadline_ms=500.0), SolverPolicy(priority=3)):
        pln = plan_execution(pb, PathSpec(lam=lam), pol)
        assert pln.backend == "serve"
        assert any("SLO" in r for r in pln.reasons)
    # pinned non-serve backends cannot honour SLO knobs
    for backend in ("host", "masked", "compact"):
        with pytest.raises(ValueError, match="SLO"):
            plan_execution(pb, PathSpec(lam=lam),
                           SolverPolicy(backend=backend, deadline_ms=100.0))
    # explicit serve + SLO knobs is fine
    pln = plan_execution(pb, PathSpec(lam=lam),
                         SolverPolicy(backend="serve", deadline_ms=100.0))
    assert pln.backend == "serve"


def test_specs_are_pytrees():
    X, y, lam = _problem(20, 24)
    w = np.ones(20)
    pb = Problem(X, y, family=logistic, weights=w)
    leaves, treedef = jax.tree_util.tree_flatten(pb)
    pb2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert pb2.family is logistic
    np.testing.assert_array_equal(pb2.X, X)
    np.testing.assert_array_equal(pb2.weights, w)

    spec = PathSpec(lam=LambdaSpec.explicit(lam), sigmas=np.ones(4))
    doubled = jax.tree_util.tree_map(lambda a: a * 2, spec)
    np.testing.assert_array_equal(np.asarray(doubled.lam.values), 2 * lam)
    np.testing.assert_array_equal(doubled.sigmas, 2 * np.ones(4))
    assert doubled.path_length == spec.path_length  # aux data untouched

    leaves, _ = jax.tree_util.tree_flatten(SolverPolicy())
    assert leaves == []                       # policy is pure static config


# ---------------------------------------------------------------------------
# the planner (ISSUE 4 acceptance)
# ---------------------------------------------------------------------------

def test_planner_compact_for_p_much_greater_than_n():
    Xs, ys, lam = _batch(2, 20, 256, k=3, rho=0.0, noise=0.3, q=0.05)
    _WS_BUCKETS.pop((20, 256, 1, "ols", "strong"), None)
    pln = plan_execution(Problem(Xs, ys), PathSpec(lam=lam))
    assert (pln.backend, pln.mode) == ("device", "compact")
    assert pln.working_set == 64              # min(2^⌈log₂ max(2n,64)⌉, p)
    text = pln.explain()
    assert "compact" in text and "O(n·W)" in text and "W=64" in text


def test_planner_masked_for_n_over_p():
    Xs, ys, lam = _batch(3, 40, 60)           # p < 2n
    pln = plan_execution(Problem(Xs, ys), PathSpec(lam=lam))
    assert (pln.backend, pln.mode) == ("device", "masked")
    assert pln.working_set is None
    assert "masked" in pln.explain()


def test_planner_host_for_single_problem():
    X, y, lam = _problem(30, 40)
    pln = plan_execution(Problem(X, y), PathSpec(lam=lam))
    assert (pln.backend, pln.mode) == ("host", "gathered")
    assert "host" in pln.explain()


def test_planner_cv_uses_fold_geometry():
    X, y, lam = _problem(30, 40)
    pln = plan_execution(Problem(X, y), PathSpec(lam=lam, cv_folds=3))
    assert pln.backend == "device" and pln.batch == 3
    assert pln.n == 20                        # training rows per fold
    with pytest.raises(ValueError):           # CV needs a single problem
        Xs, ys, lam2 = _batch(2, 20, 24)
        plan_execution(Problem(Xs, ys), PathSpec(lam=lam2, cv_folds=3))


def test_planner_rejects_impossible_pins():
    X, y, lam = _problem(20, 24)
    Xs, ys, lam2 = _batch(2, 20, 24)
    with pytest.raises(ValueError, match="cannot execute cv_folds"):
        plan_execution(Problem(X, y), PathSpec(lam=lam, cv_folds=3),
                       SolverPolicy(backend="host"))
    with pytest.raises(ValueError, match="single"):
        plan_execution(Problem(Xs, ys), PathSpec(lam=lam2),
                       SolverPolicy(backend="host"))
    with pytest.raises(ValueError, match="canonical bucket"):
        plan_execution(Problem(X, y), PathSpec(lam=lam),
                       SolverPolicy(backend="serve", pad=None))


def test_legacy_entry_points_accept_plain_lists():
    """PR 1-3 entry points took lists (np.asarray'd internally); the shims
    must keep that working through Problem's coercion."""
    X, y, lam = _problem(15, 12)
    a = fit_path(X.tolist(), y.tolist(), lam.tolist(), ols,
                 early_stop=False, **KW)
    b = fit_path(X, y, lam, ols, early_stop=False, **KW)
    np.testing.assert_array_equal(a.betas, b.betas)


def test_planner_screening_none_stays_masked():
    Xs, ys, lam = _batch(2, 20, 256)
    pln = plan_execution(Problem(Xs, ys), PathSpec(lam=lam),
                         SolverPolicy(screening="none"))
    assert pln.mode == "masked"


def test_planner_agreement_compact_bit_identical():
    """Acceptance: on a p ≫ n batch the planner selects the compact engine
    and its execution is bit-identical to the explicit legacy kwargs for
    the same backend (shallow grid: no overflow, so the registry state the
    two runs see is identical)."""
    Xs, ys, lam = _batch(2, 20, 256, k=3, rho=0.0, noise=0.3, q=0.05)
    key = (20, 256, 1, "ols", "strong")
    spec = PathSpec(lam=lam, path_length=6, sigma_ratio=0.5)

    _WS_BUCKETS.pop(key, None)
    auto = slope_path(Problem(Xs, ys), spec, SolverPolicy(**POL))
    assert auto.plan.mode == "compact" and auto.working_set == 64
    assert not auto.compact_fallback.any()

    _WS_BUCKETS.pop(key, None)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = fit_path_batched(Xs, ys, lam, ols, working_set="auto",
                                  sigma_ratio=0.5, **KW)
    np.testing.assert_array_equal(auto.betas, legacy.betas)
    np.testing.assert_array_equal(auto.n_violations, legacy.n_violations)
    np.testing.assert_array_equal(auto.ws_size, legacy.ws_size)


def test_planner_agreement_masked_bit_identical():
    """Acceptance: on an n ≳ p batch the planner selects the masked engine,
    bit-identical to the legacy default kwargs."""
    Xs, ys, lam = _batch(3, 40, 60)
    auto = slope_path(Problem(Xs, ys), PathSpec(lam=lam, path_length=6),
                      SolverPolicy(**POL))
    assert auto.plan.mode == "masked"
    legacy = fit_path_batched(Xs, ys, lam, ols, **KW)
    np.testing.assert_array_equal(auto.betas, legacy.betas)
    np.testing.assert_array_equal(auto.n_screened, legacy.n_screened)


def test_planner_agreement_host_bit_identical():
    X, y, lam = _problem(30, 40)
    auto = slope_path(Problem(X, y),
                      PathSpec(lam=lam, path_length=6, early_stop=False),
                      SolverPolicy(**POL))
    assert auto.plan.mode == "gathered"
    legacy = fit_path(X, y, lam, ols, early_stop=False, **KW)
    np.testing.assert_array_equal(auto.betas, legacy.betas)


# ---------------------------------------------------------------------------
# deprecation shims (ISSUE 4 satellite): bit-identical, warn exactly once
# ---------------------------------------------------------------------------

def _legacy_warnings(w, kwarg):
    return [x for x in w if issubclass(x.category, DeprecationWarning)
            and f"({kwarg}=...)" in str(x.message)]


def test_legacy_fit_path_engine_pad_warn_once():
    X, y, lam = _problem(20, 24)
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = fit_path(X, y, lam, ols, engine="device", pad="bucket",
                     early_stop=False, **KW)
        b = fit_path(X, y, lam, ols, engine="device", pad="bucket",
                     early_stop=False, **KW)
    assert len(_legacy_warnings(w, "engine")) == 1
    assert len(_legacy_warnings(w, "pad")) == 1
    np.testing.assert_array_equal(a.betas, b.betas)
    spec_res = slope_path(Problem(X, y),
                          PathSpec(lam=lam, path_length=6, early_stop=False),
                          SolverPolicy(backend="masked", pad="bucket", **POL))
    np.testing.assert_array_equal(a.betas, spec_res.betas)


def test_legacy_fit_path_batched_working_set_warns_once():
    Xs, ys, lam = _batch(3, 40, 96)
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = fit_path_batched(Xs, ys, lam, ols, working_set=64, **KW)
        b = fit_path_batched(Xs, ys, lam, ols, working_set=64, **KW)
    assert len(_legacy_warnings(w, "working_set")) == 1
    np.testing.assert_array_equal(a.betas, b.betas)
    spec_res = slope_path(Problem(Xs, ys), PathSpec(lam=lam, path_length=6),
                          SolverPolicy(backend="compact", working_set=64,
                                       **POL))
    np.testing.assert_array_equal(a.betas, spec_res.betas)
    assert spec_res.working_set == 64


def test_legacy_cv_path_stratify_selection_warn_once():
    X, y, _ = make_classification(36, 20, k=3, rho=0.1, seed=14)
    lam = np.asarray(bh_sequence(20, q=0.1))
    kw = dict(path_length=8, solver_tol=1e-9, max_iter=5000)
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        a = cv_path(X, y, lam, logistic, n_folds=3, stratify="auto",
                    selection="1se", **kw)
        b = cv_path(X, y, lam, logistic, n_folds=3, stratify="auto",
                    selection="1se", **kw)
    assert len(_legacy_warnings(w, "stratify")) == 1
    assert len(_legacy_warnings(w, "selection")) == 1
    np.testing.assert_array_equal(a.val_deviance, b.val_deviance)
    assert a.best_index == b.best_index
    spec_res = slope_path(
        Problem(X, y, family=logistic),
        PathSpec(lam=lam, path_length=8, cv_folds=3, stratify="auto",
                 selection="1se"),
        SolverPolicy(backend="masked", solver_tol=1e-9, max_iter=5000))
    np.testing.assert_array_equal(a.val_deviance, spec_res.val_deviance)
    assert a.best_index == spec_res.best_index
    assert a.best_index_1se == spec_res.best_index_1se


def test_legacy_default_calls_do_not_warn():
    X, y, lam = _problem(20, 24)
    Xs, ys, lam2 = _batch(2, 20, 24)
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fit_path(X, y, lam, ols, early_stop=False, **KW)
        fit_path_batched(Xs, ys, lam2, ols, **KW)
        cv_path(X, y, lam, ols, n_folds=3, **KW)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# sample weights (Problem.weights, OLS row-scaling reduction)
# ---------------------------------------------------------------------------

def test_ols_weights_equal_row_duplication():
    n, p = 15, 25
    X, y, _ = make_regression(n, p, k=3, rho=0.0, seed=4, noise=0.3)
    w = np.ones(n)
    w[3] = 2.0
    Xd = np.vstack([X, X[3:4]])
    yd = np.concatenate([y, y[3:4]])
    lam = np.asarray(bh_sequence(p, 0.1))
    sig = 2.0 * np.linspace(1.0, 0.2, 8)   # shared grid: losses are equal
    spec = lambda: PathSpec(lam=lam, sigmas=sig, early_stop=False)  # noqa: E731
    pol = SolverPolicy(solver_tol=1e-12, max_iter=30000)
    a = slope_path(Problem(X, y, weights=w), spec(), pol)
    b = slope_path(Problem(Xd, yd), spec(), pol)
    np.testing.assert_allclose(a.betas, b.betas, atol=1e-10)


def test_weights_rejected_for_non_ols():
    X, y, _ = make_classification(20, 10, k=2, rho=0.0, seed=1)
    with pytest.raises(ValueError, match="OLS"):
        slope_path(Problem(X, y, family=logistic, weights=np.ones(20)),
                   PathSpec(path_length=4))
    with pytest.raises(ValueError, match="positive"):
        slope_path(Problem(X[:, :5], y.astype(float),
                           weights=np.zeros(20)),
                   PathSpec(path_length=4))


# ---------------------------------------------------------------------------
# SlopE estimator
# ---------------------------------------------------------------------------

def test_slope_estimator_cv_fit_predict():
    X, y, _ = make_regression(60, 50, k=4, rho=0.0, seed=2, noise=0.3)
    est = SlopE(lam=LambdaSpec("bh", q=0.1),
                path=PathSpec(lam=LambdaSpec("bh", q=0.1), cv_folds=4,
                              path_length=25),
                policy=SolverPolicy(solver_tol=1e-9, max_iter=5000))
    assert est.fit(X, y) is est
    assert est.coef_.shape == (50,)
    assert 0 < est.sigma_index_ < 25
    assert est.cv_.val_deviance.shape == (4, 25)
    assert est.cv_.plan.batch == 4            # CV selection ran fold-batched
    assert est.plan_ is est.path_.plan        # plan_ describes coef_'s fit
    assert est.plan_.mode == "gathered"       # full-data refit, B=1 → host
    pred = est.predict(X)
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    assert 1 - ss_res / ss_tot > 0.5          # real signal recovered
    with pytest.raises(ValueError):
        est.predict_proba(X)                  # OLS has no classes


def test_slope_estimator_no_cv_and_classifier():
    X, y, _ = make_classification(40, 20, k=3, rho=0.1, seed=3)
    clf = SlopE(family=logistic, cv=None,
                path=PathSpec(path_length=12, early_stop=False),
                policy=SolverPolicy(solver_tol=1e-9, max_iter=5000))
    clf.fit(X, y)
    assert clf.cv_ is None
    assert clf.sigma_index_ == 11             # last grid point without CV
    labels = clf.predict(X)
    assert set(np.unique(labels)) <= {0, 1}
    assert (labels == y).mean() > 0.7         # least-regularized train fit
    proba = clf.predict_proba(X)
    assert proba.shape == (40, 2)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0)
    unfit = SlopE()
    with pytest.raises(ValueError, match="not fitted"):
        unfit.predict(X)


# ---------------------------------------------------------------------------
# specs through the service (plan decisions identical, plans telemetry)
# ---------------------------------------------------------------------------

def test_service_spec_submit_bit_identical_and_plans_exposed():
    from repro.serve import PathService

    X, y, lam = _problem(20, 24)
    # early_stop=False: served responses always carry the full σ grid, so
    # the direct comparator must not truncate post-hoc
    spec = PathSpec(lam=lam, path_length=6, early_stop=False)
    svc = PathService(max_batch=4, max_delay=1000.0)
    rid = svc.submit(problem=Problem(X, y), path=spec,
                     policy=SolverPolicy(**POL))
    resp = svc.poll(rid, flush=True)
    direct = slope_path(Problem(X, y), spec,
                        SolverPolicy(backend="masked", pad="bucket", **POL))
    np.testing.assert_array_equal(resp.betas, direct.betas)
    st = svc.stats()
    assert st["plans"] and all(k.startswith("serve/") for k in st["plans"])
    assert st["ws_buckets"]["capacity"] == 256
    assert "entries" in st["ws_buckets"]      # JSON-safe registry snapshot

    with pytest.raises(ValueError):           # specs and arrays don't mix
        svc.submit(X, y, problem=Problem(X, y))
    with pytest.raises(ValueError):           # the service cannot run host
        svc.submit(problem=Problem(X, y), policy=SolverPolicy(backend="host"))
    with pytest.raises(ValueError):           # one problem per request
        Xs, ys, lam2 = _batch(2, 20, 24)
        svc.submit(problem=Problem(Xs, ys))


def test_slope_path_serve_backend_round_trip():
    X, y, lam = _problem(18, 30, seed=5)
    spec = PathSpec(lam=lam, path_length=6, early_stop=False)
    out = slope_path(Problem(X, y), spec, SolverPolicy(backend="serve", **POL))
    assert out is not None and out.kkt_ok
    assert out.plan.backend == "serve"        # served results carry .plan too
    direct = slope_path(Problem(X, y), spec,
                        SolverPolicy(backend="masked", pad="bucket", **POL))
    np.testing.assert_array_equal(out.betas, direct.betas)


# ---------------------------------------------------------------------------
# benchmarks --only parsing (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_benchmarks_resolve_only():
    from benchmarks.run import resolve_only

    assert resolve_only("kernels") == ["kernels"]
    assert resolve_only(" serve , kernels,serve,,") == ["serve", "kernels"]
    with pytest.raises(ValueError, match="unknown sweep"):
        resolve_only("kernels,typo_sweep")
    with pytest.raises(ValueError, match="no sweeps"):
        resolve_only(" , ")
