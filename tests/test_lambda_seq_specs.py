"""λ sequences (paper §3.1.1) and the dry-run input-spec machinery."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    bh_sequence,
    gaussian_sequence,
    lasso_sequence,
    oscar_sequence,
    path_start_sigma,
    sigma_grid,
)


def test_bh_sequence_shape_and_monotonicity():
    lam = np.asarray(bh_sequence(500, q=0.1))
    assert lam.shape == (500,)
    assert np.all(np.diff(lam) <= 0) and lam[-1] >= 0
    # λ_1 = Φ⁻¹(1 − q/(2p)) — scipy is a [test] extra; the minimal install
    # still runs every other assertion in this module
    norm = pytest.importorskip("scipy.stats").norm

    np.testing.assert_allclose(lam[0], norm.ppf(1 - 0.1 / (2 * 500)), rtol=1e-10)


def test_gaussian_sequence_truncates_when_increasing():
    """Paper §3.1.1: λG is set to the previous value once it increases, and
    for small q/p it reduces to (nearly) the BH sequence start."""
    lam = np.asarray(gaussian_sequence(100, n=50, q=0.1))
    assert np.all(np.diff(lam) <= 1e-12)
    # the adjustment never lifts λ above λ_1
    assert lam.max() == lam[0]


def test_oscar_and_lasso_sequences():
    osc = np.asarray(oscar_sequence(10, q=0.5))
    np.testing.assert_allclose(osc, 0.5 * (10 - np.arange(1, 11)) + 1)
    las = np.asarray(lasso_sequence(7))
    np.testing.assert_allclose(las, np.ones(7))


def test_sigma_grid_paper_ratios():
    g1 = sigma_grid(2.0, length=10, n=50, p=100)   # n < p → ratio 1e-2
    assert g1[0] == 2.0 and np.isclose(g1[-1], 2.0 * 1e-2)
    g2 = sigma_grid(2.0, length=10, n=100, p=50)   # n ≥ p → ratio 1e-4
    assert np.isclose(g2[-1], 2.0 * 1e-4)


def test_path_start_sigma_zeroes_the_first_step(rng):
    """σ(1) is the smallest σ with β̂ = 0 (checked via the dual gauge)."""
    from repro.core import fista, ols
    from repro.data import make_regression

    X, y, _ = make_regression(40, 80, k=5, seed=0)
    lam = np.asarray(bh_sequence(80, 0.1))
    grad0 = X.T @ (0 - y)
    s1 = float(path_start_sigma(jnp.asarray(grad0), jnp.asarray(lam)))
    res = fista(jnp.asarray(X), jnp.asarray(y), jnp.asarray(s1 * lam * 1.0001),
                jnp.zeros(80), ols, max_iter=5000, tol=1e-14)
    assert np.abs(np.asarray(res.beta)).max() < 1e-10


def test_lambda_spec_paths_match_legacy_arrays():
    """ISSUE 4 satellite: bh / gaussian / oscar sequences produce IDENTICAL
    paths through LambdaSpec vs the legacy explicit-array kwargs, on both
    the host and device backends (the spec resolves through the shared
    canonicalizer to the same bytes the legacy recipe functions return)."""
    import warnings

    from repro.api import LambdaSpec, PathSpec, Problem, SolverPolicy, slope_path
    from repro.core import fit_path, ols
    from repro.data import make_regression

    n, p = 25, 30
    X, y, _ = make_regression(n, p, k=3, rho=0.2, seed=7)
    kw = dict(path_length=5, solver_tol=1e-10, max_iter=20000)
    legacy_arrays = {
        ("bh", 0.1): np.asarray(bh_sequence(p, 0.1)),
        ("gaussian", 0.1): np.asarray(gaussian_sequence(p, n=n, q=0.1)),
        ("oscar", 0.05): np.asarray(oscar_sequence(p, 0.05)),
    }
    for (kind, q), lam in legacy_arrays.items():
        spec = PathSpec(lam=LambdaSpec(kind, q=q), path_length=5,
                        early_stop=False)
        resolved = spec.lam.resolve(p, n=n)
        np.testing.assert_array_equal(resolved, lam)

        host_legacy = fit_path(X, y, lam, ols, early_stop=False, **kw)
        host_spec = slope_path(Problem(X, y), spec,
                               SolverPolicy(backend="host",
                                            solver_tol=1e-10,
                                            max_iter=20000))
        np.testing.assert_array_equal(host_legacy.betas, host_spec.betas)

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            dev_legacy = fit_path(X, y, lam, ols, engine="device",
                                  early_stop=False, **kw)
        dev_spec = slope_path(Problem(X, y), spec,
                              SolverPolicy(backend="masked",
                                           solver_tol=1e-10,
                                           max_iter=20000))
        np.testing.assert_array_equal(dev_legacy.betas, dev_spec.betas)


def test_lambda_spec_validation_and_sharing():
    from repro.api import LambdaSpec, shared_canonicalizer

    a = LambdaSpec("bh", q=0.1).resolve(50)
    b = LambdaSpec("bh", q=0.1).resolve(50)
    assert a is b and not a.flags.writeable  # one shared memoised array
    assert shared_canonicalizer().get("bh", 0.1, 50) is a

    import pytest

    with pytest.raises(ValueError):
        LambdaSpec("nope")
    with pytest.raises(ValueError):
        LambdaSpec("explicit")               # explicit needs values
    with pytest.raises(ValueError):
        LambdaSpec.explicit(np.ones(7)).resolve(9)
    lam2 = LambdaSpec.explicit(np.ones((3, 9))).resolve(9)  # (B, p·m) stack
    assert lam2.shape == (3, 9)

    # ... but a per-problem stack needs a batched (B, n, p) problem
    from repro.api import PathSpec, Problem, slope_path
    from repro.data import make_regression

    X, y, _ = make_regression(12, 9, k=2, seed=0)
    with pytest.raises(ValueError, match="batched"):
        slope_path(Problem(X, y),
                   PathSpec(lam=LambdaSpec.explicit(np.ones((3, 9))),
                            path_length=4))


def test_input_specs_cover_all_cells():
    from repro.configs import ARCH_NAMES, get_config
    from repro.launch.specs import SHAPES, input_specs, skip_reason

    n_skip = 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                n_skip += 1
                continue
            spec = input_specs(cfg, shape)
            if spec["kind"] in ("train", "prefill"):
                toks = spec["batch"]["tokens"]
                assert toks.shape[0] == SHAPES[shape].global_batch
                total = toks.shape[1] + (cfg.n_patches or 0)
                assert total == SHAPES[shape].seq_len
                if cfg.encdec:
                    assert "frames" in spec["batch"]
            else:
                assert spec["token"].shape == (SHAPES[shape].global_batch, 1)
                assert len(jax.tree.leaves(spec["cache"])) > 0
    # exactly the 7 full-attention archs skip long_500k
    assert n_skip == 7


import jax  # noqa: E402  (used in the spec test above)
