"""Sorted-ℓ1 norm + prox: oracle comparisons and subdifferential certificates."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: fall back to seeded random fuzzing
    from _hypothesis_fallback import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import (
    dual_sorted_l1_gauge,
    in_subdifferential,
    isotonic_decreasing,
    prox_sorted_l1,
    sorted_l1_norm,
)


def numpy_pava_prox(v, lam):
    """Stack-based FastProxSL1 reference in pure NumPy (float64)."""
    v = np.asarray(v, float)
    lam = np.asarray(lam, float)
    sign = np.sign(v)
    mag = np.abs(v)
    order = np.argsort(-mag)
    w = mag[order] - lam
    stack = []
    for s in w:
        stack.append([s, 1])
        while len(stack) > 1 and stack[-1][0] * stack[-2][1] >= stack[-2][0] * stack[-1][1]:
            b = stack.pop()
            stack[-1][0] += b[0]
            stack[-1][1] += b[1]
    x = np.concatenate([[b[0] / b[1]] * int(b[1]) for b in stack])
    x = np.maximum(x, 0)
    out = np.zeros_like(v)
    out[order] = x
    return sign * out


@st.composite
def prox_case(draw):
    # allow_subnormal=False: XLA flushes denormals to zero (FTZ), which is
    # a hardware semantic, not a prox property
    p = draw(st.integers(1, 64))
    v = draw(st.lists(st.floats(-10, 10, allow_nan=False, allow_subnormal=False),
                      min_size=p, max_size=p))
    raw = draw(st.lists(st.floats(0, 5, allow_nan=False, allow_subnormal=False),
                        min_size=p, max_size=p))
    lam = np.sort(np.asarray(raw))[::-1]
    return np.asarray(v), lam


def _pad_prox_case(v, lam, width=64):
    """Zero-pad (v, λ) to a fixed width so every drawn case shares ONE jit
    shape (a fresh compile per random size turns the property test into a
    compile benchmark).  Exact: padded v entries are 0 with λ = 0, so they
    sort to the tail, pool only into non-positive blocks, and emit 0."""
    pad = width - len(v)
    return (np.concatenate([v, np.zeros(pad)]),
            np.concatenate([lam, np.zeros(pad)]))


@settings(max_examples=200, deadline=None)
@given(prox_case())
def test_prox_matches_numpy_pava(case):
    v, lam = case
    p = len(v)
    vp, lamp = _pad_prox_case(v, lam)
    got = np.asarray(prox_sorted_l1(jnp.asarray(vp), jnp.asarray(lamp)))[:p]
    want = numpy_pava_prox(v, lam)
    np.testing.assert_allclose(got, want, atol=1e-10)


@settings(max_examples=100, deadline=None)
@given(prox_case())
def test_prox_optimality_certificate(case):
    """v − prox(v) ∈ ∂J(prox(v); λ)  — Theorem 1 as a prox certificate."""
    v, lam = case
    p = len(v)
    vp, lamp = _pad_prox_case(v, lam)
    x = np.asarray(prox_sorted_l1(jnp.asarray(vp), jnp.asarray(lamp)))[:p]
    assert in_subdifferential(v - x, x, lam, atol=1e-8)


def test_prox_is_projection_when_lam_zero(rng):
    v = rng.normal(size=50)
    lam = np.zeros(50)
    np.testing.assert_allclose(np.asarray(prox_sorted_l1(jnp.asarray(v), jnp.asarray(lam))), v)


def test_prox_shrinks_toward_zero(rng):
    v = rng.normal(size=100) * 3
    lam = np.sort(np.abs(rng.normal(size=100)))[::-1]
    x = np.asarray(prox_sorted_l1(jnp.asarray(v), jnp.asarray(lam)))
    assert np.all(np.abs(x) <= np.abs(v) + 1e-12)
    assert np.all(np.sign(x[x != 0]) == np.sign(v[x != 0]))


def test_isotonic_decreasing_is_monotone(rng):
    # sizes from a fixed palette: each new length recompiles the lax loop,
    # so free-form random sizes turn this into a compile-time benchmark
    for p in (1, 2, 17, 200) * 8:
        y = rng.normal(size=p)
        x = np.asarray(isotonic_decreasing(jnp.asarray(y)))
        assert np.all(np.diff(x) <= 1e-12)


def test_isotonic_parallel_and_minimax_match_stack(rng):
    """The engine's sweep-merging form and the minimax form are exact."""
    from repro.core import isotonic_decreasing_parallel
    from repro.core.sorted_l1 import isotonic_decreasing_minimax

    iso_par = jax.jit(isotonic_decreasing_parallel)
    iso_mm = jax.jit(isotonic_decreasing_minimax)
    for trial in range(24):
        p = (1, 2, 17, 200)[trial % 4]
        kind = rng.integers(0, 3)
        if kind == 0:
            y = np.sort(rng.normal(size=p))          # fully violating
        elif kind == 1:
            y = rng.integers(-3, 3, size=p).astype(float)  # heavy ties
        else:
            y = rng.normal(size=p) * 3
        want = np.asarray(isotonic_decreasing(jnp.asarray(y)))
        np.testing.assert_allclose(np.asarray(iso_par(jnp.asarray(y))), want,
                                   atol=1e-10)
        if p == 200:  # minimax builds p×p intermediates; one shape suffices
            np.testing.assert_allclose(np.asarray(iso_mm(jnp.asarray(y))),
                                       want, atol=1e-10)


def test_norm_properties(rng):
    p = 64
    lam = np.sort(np.abs(rng.normal(size=p)))[::-1]
    a = rng.normal(size=p)
    b = rng.normal(size=p)
    Ja = float(sorted_l1_norm(jnp.asarray(a), jnp.asarray(lam)))
    Jb = float(sorted_l1_norm(jnp.asarray(b), jnp.asarray(lam)))
    Jab = float(sorted_l1_norm(jnp.asarray(a + b), jnp.asarray(lam)))
    assert Jab <= Ja + Jb + 1e-9  # triangle inequality
    J2a = float(sorted_l1_norm(jnp.asarray(2 * a), jnp.asarray(lam)))
    np.testing.assert_allclose(J2a, 2 * Ja, rtol=1e-10)


def test_dual_gauge_certifies_zero_solution(rng):
    """gauge(g/σ) ≤ 1 ⇔ g ∈ ∂J(0; σλ): σ(1) is the smallest σ giving β̂=0."""
    p = 40
    lam = np.sort(np.abs(rng.normal(size=p)))[::-1] + 0.1
    g = rng.normal(size=p)
    sigma = float(dual_sorted_l1_gauge(jnp.asarray(g), jnp.asarray(lam)))
    assert in_subdifferential(g, np.zeros(p), sigma * lam * (1 + 1e-9))
    assert not in_subdifferential(g, np.zeros(p), sigma * lam * (1 - 1e-6))
