"""Distributed pieces.  Multi-device cases run in a subprocess so the forced
host-device count never leaks into the main test process (smoke tests must
see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

# subprocess-per-case with forced 8-device hosts: scheduled tier only
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_gradient_and_screen_match_dense():
    print(_run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.distributed import (sharded_linear_predictor,
            sharded_gradient, distributed_strong_rule)
        from repro.core import strong_rule, bh_sequence
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("feat",))
        rng = np.random.default_rng(0)
        n, p = 40, 512
        X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        beta = jnp.asarray(rng.normal(size=p) * (rng.random(p) < 0.05), jnp.float32)
        y = jnp.asarray(rng.normal(size=n), jnp.float32)

        z = sharded_linear_predictor(mesh, "feat")(X, beta)
        np.testing.assert_allclose(np.asarray(z), np.asarray(X @ beta), rtol=2e-5, atol=2e-5)

        r = z - y
        g = sharded_gradient(mesh, "feat")(X, r)
        np.testing.assert_allclose(np.asarray(g), np.asarray(X.T @ r), rtol=2e-5, atol=2e-5)

        # pick a λ scale where the rule keeps a nontrivial small set
        for scale in (2.0, 5.0, 10.0, 20.0):
            lam = jnp.asarray(np.asarray(bh_sequence(p, 0.05)) * scale, jnp.float32)
            k_ref, order = strong_rule(g, lam, 0.9 * lam)
            if 0 < int(k_ref) < 200:
                break
        assert 0 < int(k_ref) < 200, int(k_ref)

        cap = 2  # deliberately small: exercises the uncertain-retry protocol
        lam_full = np.asarray(lam)
        while True:
            capD = min(cap * 8, p)
            gap = (0.1 * lam)[:capD]
            lam_cap = (0.9 * lam)[:capD]
            gap_tail = jnp.float32((0.1 * lam_full)[capD:].max() if capD < p else 0.0)
            k, thr, keep, uncertain = distributed_strong_rule(
                mesh, "feat", cap=cap, p_total=p)(
                g, gap, lam_cap, jnp.float32(0.9 * lam_full[-1]), gap_tail)
            if not bool(uncertain) or capD >= p:
                break
            cap *= 2
        assert int(k) == int(k_ref), (int(k), int(k_ref), cap)
        kept_ref = set(np.asarray(order[:int(k_ref)]).tolist())
        kept_got = set(np.nonzero(np.asarray(keep))[0].tolist())
        assert kept_ref <= kept_got  # threshold mask ⊇ exact set (ties keep extra)
        print("distributed OK")
    """))


def test_mini_dryrun_cell_compiles():
    out = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses, json
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.steps import make_train_step, hyper_for
        from repro.models import init_params
        from repro.optim import adamw_init
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        sh.install(mesh)
        cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                                  d_model=64, n_heads=6, n_kv_heads=2, head_dim=16,
                                  d_ff=128, vocab=250)  # non-divisible heads+vocab
        params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        p_sh = sh.param_sharding(params, mesh)
        hyper = hyper_for(cfg)
        opt = jax.eval_shape(lambda: adamw_init(params, hyper))
        o_sh = sh.opt_sharding(params, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        b_sh = sh.batch_sharding(batch, mesh)
        fn = jax.jit(make_train_step(cfg, mesh, hyper),
                     in_shardings=(p_sh, o_sh, b_sh, None),
                     out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        with mesh:
            compiled = fn.lower(params, opt, batch, jnp.int32(0)).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax < 0.5 returns one dict per device
            ca = ca[0]
        assert ca.get("flops", 0) > 0
        print("mini dryrun OK", ca.get("flops"))
    """)
    assert "mini dryrun OK" in out


def test_sharded_train_step_matches_single_device():
    """The distributed train step must be numerically equivalent to the
    single-device one (same params after one step, up to f32 tolerance)."""
    print(_run_sub("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.steps import make_train_step
        from repro.models import init_params
        from repro.optim import adamw_init, AdamWHyper
        cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=2)
        hyper = AdamWHyper(lr=1e-2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, hyper)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)}

        # single device
        p1, o1, m1 = jax.jit(make_train_step(cfg, None, hyper))(params, opt, batch, jnp.int32(0))

        # 8-device mesh
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        sh.install(mesh)
        p_sh = sh.param_sharding(params, mesh)
        o_sh = sh.opt_sharding(params, mesh)
        b_sh = sh.batch_sharding(batch, mesh)
        fn = jax.jit(make_train_step(cfg, mesh, hyper),
                     in_shardings=(p_sh, o_sh, b_sh, None))
        with mesh:
            p2, o2, m2 = fn(params, opt, batch, jnp.int32(0))
        sh.install(None)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-4)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-4, d
        print("parity OK", d)
    """))
