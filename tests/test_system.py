"""End-to-end behaviour tests for the paper's system.

1. The headline claim: fitting a SLOPE path with the strong screening rule
   returns the SAME estimates as fitting without it (screening is exact up
   to the KKT guard), while solving far smaller subproblems.
2. The violation guard: when violations happen the refit loop repairs them.
3. SLOPE-path LM training end-to-end (the at-scale integration).
"""

import numpy as np
import pytest


from repro.core import bh_sequence, fit_path, ols, get_family
from repro.data import make_classification, make_regression


def test_screening_preserves_path_estimates_and_shrinks_subproblems():
    n, p = 80, 1000
    X, y, beta_true = make_regression(n, p, k=10, rho=0.2, seed=0)
    lam = np.asarray(bh_sequence(p, q=0.05))
    kw = dict(path_length=25, solver_tol=1e-11, max_iter=20000)
    scr = fit_path(X, y, lam, ols, screening="strong", **kw)
    ref = fit_path(X, y, lam, ols, screening="none", **kw)

    L = min(len(scr.betas), len(ref.betas))
    np.testing.assert_allclose(scr.betas[:L], ref.betas[:L], atol=5e-3)
    # screened sets are a strict minority of p on most of the path
    # (q=0.05 at p=1000 keeps ~1/3; harder screening needs smaller q — the
    # p≫n benchmarks use q=n/(10p) and reach <10 %)
    fracs = [s.n_screened / p for s in scr.steps[1:]]
    assert np.median(fracs) < 0.45, np.median(fracs)
    # and a bounded multiple of the active size (paper Table 2: 1.5–4×)
    eff = [s.n_screened / max(s.n_active, 1) for s in scr.steps[1:] if s.n_active > 5]
    assert np.median(eff) < 25


def test_violation_guard_repairs_kkt_failures():
    """Even with a coarse path (large σ gaps → more violations), the final
    estimates still match the unscreened fit — the KKT loop guards the rule."""
    n, p = 60, 300
    X, y, _ = make_regression(n, p, k=8, rho=0.6, seed=4)
    lam = np.asarray(bh_sequence(p, q=0.1))
    kw = dict(path_length=6, solver_tol=1e-11, max_iter=20000)  # coarse path
    scr = fit_path(X, y, lam, ols, screening="strong", **kw)
    ref = fit_path(X, y, lam, ols, screening="none", **kw)
    L = min(len(scr.betas), len(ref.betas))
    np.testing.assert_allclose(scr.betas[:L], ref.betas[:L], atol=5e-3)


def test_logistic_path_with_screening():
    n, p = 60, 400
    X, y, _ = make_classification(n, p, k=5, rho=0.3, seed=2)
    fam = get_family("logistic")
    lam = np.asarray(bh_sequence(p, q=0.1))
    r = fit_path(X, y, lam, fam, screening="strong", path_length=12,
                 solver_tol=1e-10, max_iter=10000)
    assert np.isfinite(r.betas).all()
    assert r.steps[-1].n_active > 0
    assert r.steps[-1].deviance < r.steps[0].deviance


@pytest.mark.slow
def test_lm_slope_training_end_to_end(tmp_path):
    import dataclasses

    from repro.configs import get_config
    from repro.models.slope_reg import SlopeRegConfig
    from repro.optim import AdamWHyper
    from repro.train import TrainConfig, Trainer

    cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=2,
                              vocab=128)
    slope = SlopeRegConfig(targets=("embed",), sigma0=1e-2, total_steps=20,
                           screen_every=10)
    tc = TrainConfig(steps=20, ckpt_every=10, ckpt_dir=str(tmp_path / "ck"),
                     log_every=5, slope=slope)
    out = Trainer(cfg, tc, hyper=AdamWHyper(lr=3e-3), global_batch=4,
                  seq_len=16).run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]
    assert not out["preempted"]
