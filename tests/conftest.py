import jax
import pytest

# GLM correctness tests need f64; models/kernels request explicit dtypes so
# this only changes defaults.  Smoke tests intentionally see 1 CPU device —
# do NOT set xla_force_host_platform_device_count here (dry-run only).
# (Property tests bound their own cost: explicit @settings cap example
# counts, and drawn cases are padded to fixed jit shapes — a hypothesis CI
# profile would be ignored anyway, since explicit @settings take precedence.)
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.default_rng(42)
