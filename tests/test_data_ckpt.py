"""Data-pipeline determinism + checkpoint format invariants."""

import numpy as np

import jax.numpy as jnp

from repro.data import SyntheticLM, make_regression
from repro.train import latest_step, restore_checkpoint, save_checkpoint


def test_lm_data_deterministic_across_restarts():
    a = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=7)
    b = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=7)
    for step in (0, 3, 10_000):
        np.testing.assert_array_equal(a.batch(step)["tokens"], b.batch(step)["tokens"])


def test_lm_data_host_sharding_partitions_global_batch():
    h0 = SyntheticLM(vocab=50, seq_len=8, global_batch=8, seed=1, n_hosts=2, host_id=0)
    h1 = SyntheticLM(vocab=50, seq_len=8, global_batch=8, seed=1, n_hosts=2, host_id=1)
    assert h0.batch(5)["tokens"].shape == (4, 8)
    # hosts draw disjoint sub-streams
    assert not np.array_equal(h0.batch(5)["tokens"], h1.batch(5)["tokens"])


def test_checkpoint_roundtrip_and_pruning(tmp_path):
    params = {"w": jnp.arange(6.0).reshape(2, 3), "nest": {"b": jnp.ones(4)}}
    opt = {"m": {"w": jnp.zeros((2, 3)), "nest": {"b": jnp.zeros(4)}},
           "v": {"w": jnp.ones((2, 3)), "nest": {"b": jnp.ones(4)}}}
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, params=params, opt_state=opt)
    assert latest_step(tmp_path) == 5
    # pruned to the last 3
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 3

    p2, o2, manifest = restore_checkpoint(tmp_path, 5, params_template=params,
                                          opt_template=opt)
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    np.testing.assert_array_equal(np.asarray(o2["v"]["nest"]["b"]), np.ones(4))
    assert manifest["step"] == 5


def test_checkpoint_ignores_torn_writes(tmp_path):
    params = {"w": jnp.ones(3)}
    save_checkpoint(tmp_path, 1, params=params)
    # simulate a torn write at step 2
    (tmp_path / "step_00000002").mkdir()
    assert latest_step(tmp_path) == 1


def test_glm_design_normalisation():
    X, y, beta = make_regression(50, 120, k=10, rho=0.4, seed=0)
    np.testing.assert_allclose(X.mean(axis=0), 0, atol=1e-12)
    np.testing.assert_allclose(np.linalg.norm(X, axis=0), 1, atol=1e-12)
    assert abs(y.mean()) < 1e-12
