"""Chaos suite: fault injection against the serving stack (ISSUE 7).

Every recovery path is exercised under a deterministic
:class:`repro.serve.FaultPlan` (fixed spec windows, seeded corruption —
replayable in CI):

1. **Acceptance scenario.**  One poison request in a cohort of 8: exactly
   that future gets the exception; the other 7 complete and are
   **bit-identical** (maxdiff == 0) to an unfaulted run of the same 8
   problems.
2. **Transient faults** are absorbed by retry-with-backoff: every request
   completes, no exception escapes, telemetry counts the retries.
3. **Cohort scoping**: a failing serve never touches futures outside its
   cohort.
4. **Close-mid-fault**: a fault raised during the close-time drain fails
   the undelivered futures instead of leaving them pending forever.
5. **Sync rejection unification**: bounded sync queues raise
   ``RejectionError`` — a ``QueueFull`` subclass carrying the structured
   ``Rejection``.
"""

import numpy as np
import pytest

from repro.core import ols
from repro.serve import (
    AsyncPathService,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    PathService,
    ProgramCache,
    QueueFull,
    Rejection,
    RejectionError,
)

L = 6
KW = dict(path_length=L, solver_tol=1e-10, max_iter=20000)


@pytest.fixture(scope="module")
def shared_cache():
    return ProgramCache(capacity=16)


def _problem(n, p, seed=0, k=4):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[:k] = rng.normal(size=k) * 2.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    return X, y


PROBLEMS = [_problem(18 + 2 * i, 22 + i, seed=40 + i) for i in range(8)]


def _asvc(shared_cache, *, faults=None, **kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_delay", 0.005)
    kw.setdefault("step_chunk", 3)
    kw.setdefault("retry_backoff", 0.001)
    return AsyncPathService(cache=shared_cache, faults=faults, **kw)


def _serve_all(svc, problems):
    futs = [svc.submit(X, y, family=ols, **KW) for X, y in problems]
    return futs


def _reference(shared_cache):
    """The unfaulted run every chaos scenario is compared against."""
    svc = _asvc(shared_cache)
    try:
        futs = _serve_all(svc, PROBLEMS)
        return [f.result(timeout=180) for f in futs]
    finally:
        svc.close()


@pytest.fixture(scope="module")
def reference(shared_cache):
    resps = _reference(shared_cache)
    assert not any(isinstance(r, Rejection) for r in resps)
    return resps


# ---------------------------------------------------------------------------
# 1. the acceptance scenario: poison one of 8, innocents bitwise-identical
# ---------------------------------------------------------------------------

def test_poison_request_isolated_cohort_of_8(shared_cache, reference):
    poison = 3
    # the fault keys on the poison request's rid: every serve whose
    # in-flight cohort contains it crashes, so retries fail and bisection
    # must walk the cohort down to the single poisoned request
    plan = FaultPlan([FaultSpec(site="worker", kind="error", rid=poison,
                                times=10_000, message="poisoned request")])
    svc = _asvc(shared_cache, faults=plan, retry_limit=1, tracing=True)
    try:
        futs = _serve_all(svc, PROBLEMS)
        assert futs[poison].rid == poison
        with pytest.raises(InjectedFault) as ei:
            futs[poison].result(timeout=180)
        got = [f.result(timeout=180) for i, f in enumerate(futs)
               if i != poison]
        stats = svc.stats()
    finally:
        svc.close()
    # exactly one request failed; 7/8 availability
    assert stats["poisoned"] == 1
    assert stats["retries"] >= 1
    assert stats["bisections"] >= 1
    assert stats["completed"] == 7
    # the poisoned request's timeline rides on the exception: the recovery
    # history (retry + bisection child spans) ends at a "poisoned" mark
    ptr = ei.value.trace
    assert ptr is not None and ptr.rid == poison
    child_names = [s.name for s in ptr.children()]
    assert "retry" in child_names
    assert "bisect" in child_names
    assert ptr.span_names()[-1] == "poisoned"
    assert ptr.well_parented()
    # innocents: maxdiff == 0 against the unfaulted run — tracing observes,
    # never perturbs — and each carries a gap-free admit→deliver timeline
    want = [r for i, r in enumerate(reference) if i != poison]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.betas, w.betas)
        np.testing.assert_array_equal(g.deviance, w.deviance)
        np.testing.assert_array_equal(g.sigmas, w.sigmas)
        assert g.trace is not None and g.trace.contiguous()
        assert g.trace.span_names()[0] == "admit"
        assert g.trace.span_names()[-1] == "deliver"


# ---------------------------------------------------------------------------
# 2. transient faults are absorbed by retry + backoff
# ---------------------------------------------------------------------------

def test_transient_worker_fault_retried(shared_cache, reference):
    plan = FaultPlan([FaultSpec(site="worker", kind="error", times=1)])
    svc = _asvc(shared_cache, faults=plan, retry_limit=2)
    try:
        futs = _serve_all(svc, PROBLEMS)
        got = [f.result(timeout=180) for f in futs]
        stats = svc.stats()
    finally:
        svc.close()
    assert not any(isinstance(r, Rejection) for r in got)
    assert stats["poisoned"] == 0
    assert stats["retries"] >= 1
    assert plan.stats()["fired"] == 1
    for g, w in zip(got, reference):
        np.testing.assert_array_equal(g.betas, w.betas)


def test_transient_compile_fault_retried(shared_cache, reference):
    plan = FaultPlan([FaultSpec(site="compile", kind="error", times=1)])
    svc = _asvc(shared_cache, faults=plan, retry_limit=2)
    try:
        futs = _serve_all(svc, PROBLEMS)
        got = [f.result(timeout=180) for f in futs]
        stats = svc.stats()
    finally:
        svc.close()
    assert stats["poisoned"] == 0
    for g, w in zip(got, reference):
        np.testing.assert_array_equal(g.betas, w.betas)


# ---------------------------------------------------------------------------
# 3. failure stays cohort-scoped: delivered neighbours are untouched
# ---------------------------------------------------------------------------

def test_failure_does_not_touch_other_futures(shared_cache):
    # first serve (requests 0..7) is clean; a later poisoned request must
    # not disturb anything already delivered or queued outside its cohort
    plan = FaultPlan([FaultSpec(site="worker", kind="error", rid=8,
                                times=10_000)])
    svc = _asvc(shared_cache, faults=plan, retry_limit=0)
    try:
        futs = _serve_all(svc, PROBLEMS)
        first = [f.result(timeout=180) for f in futs]
        assert not any(isinstance(r, Rejection) for r in first)
        bad = svc.submit(*PROBLEMS[0], family=ols, **KW)
        assert bad.rid == 8
        with pytest.raises(InjectedFault):
            bad.result(timeout=180)
        after = svc.submit(*PROBLEMS[1], family=ols, **KW)
        ok = after.result(timeout=180)
        stats = svc.stats()
    finally:
        svc.close()
    assert not isinstance(ok, Rejection)
    np.testing.assert_array_equal(ok.betas, first[1].betas)
    assert stats["poisoned"] == 1
    assert stats["worker_alive"]  # the dispatcher survived every fault


# ---------------------------------------------------------------------------
# 4. close() mid-fault: no future is left permanently pending
# ---------------------------------------------------------------------------

def test_close_mid_fault_resolves_all_futures(shared_cache):
    plan = FaultPlan([FaultSpec(site="compile", kind="error",
                                times=10_000)])
    svc = _asvc(shared_cache, faults=plan, autostart=False, retry_limit=0)
    futs = _serve_all(svc, PROBLEMS[:3])
    svc.close(flush=True)  # the drain hits the persistent fault
    for f in futs:
        assert f.done()
        with pytest.raises((InjectedFault, RuntimeError)):
            f.result(timeout=0)
    assert svc.stats()["inflight"] == 0


def test_close_clean_leaves_nothing_pending(shared_cache):
    svc = _asvc(shared_cache, autostart=False)
    futs = _serve_all(svc, PROBLEMS[:2])
    svc.close(flush=True)
    for f in futs:
        resp = f.result(timeout=0)
        assert not isinstance(resp, Rejection)
    assert svc.stats()["inflight"] == 0


# ---------------------------------------------------------------------------
# 5. sync rejection unification + async Rejection parity
# ---------------------------------------------------------------------------

def test_sync_bounded_queue_raises_rejection_error(shared_cache):
    svc = PathService(cache=shared_cache, max_batch=8, max_delay=60.0,
                      max_queue=1)
    X, y = PROBLEMS[0]
    svc.submit(X, y, family=ols, **KW)
    with pytest.raises(QueueFull) as ei:  # deprecated alias still catches
        svc.submit(X, y, family=ols, **KW)
    err = ei.value
    assert isinstance(err, RejectionError)
    rej = err.rejection
    assert isinstance(rej, Rejection)
    assert rej.max_queue == 1 and rej.queued == 1 and rej.rid == 1
    assert svc.stats()["rejected"] == 1


def test_sync_unbounded_queue_never_rejects(shared_cache):
    svc = PathService(cache=shared_cache, max_batch=8, max_delay=60.0)
    X, y = PROBLEMS[0]
    for _ in range(4):
        svc.submit(X, y, family=ols, **KW)
    assert svc.stats()["rejected"] == 0


def test_nan_injection_at_admit_quarantines_not_crashes(shared_cache):
    # kind="nan" is the poison-request injector: the request is admitted
    # with a corrupted X and must come back as a FLAGGED response (in-graph
    # quarantine) while its cohort completes normally
    plan = FaultPlan([FaultSpec(site="admit", kind="nan", rid=2)], seed=3)
    svc = _asvc(shared_cache, faults=plan)
    try:
        futs = _serve_all(svc, PROBLEMS)
        got = [f.result(timeout=180) for f in futs]
        stats = svc.stats()
    finally:
        svc.close()
    assert got[2].quarantined
    assert not any(r.quarantined for i, r in enumerate(got) if i != 2)
    assert stats["poisoned"] == 0  # a flagged result, not an exception
    assert ("admit", "nan", 2) in plan.events


# ---------------------------------------------------------------------------
# 6. FaultPlan mechanics
# ---------------------------------------------------------------------------

def test_fault_spec_windows_and_determinism():
    plan = FaultPlan([FaultSpec(site="worker", times=2, after=1)])
    plan.fire("worker")  # occurrence 0: before the window
    for _ in range(2):   # occurrences 1, 2: inside
        with pytest.raises(InjectedFault):
            plan.fire("worker")
    plan.fire("worker")  # occurrence 3: expired
    plan.fire("compile")  # other sites don't advance this spec
    assert plan.stats()["fired"] == 2

    a = FaultPlan([FaultSpec(site="admit", kind="nan")], seed=9)
    b = FaultPlan([FaultSpec(site="admit", kind="nan")], seed=9)
    x = np.ones((6, 6))
    xa, xb = a.corrupt("admit", 5, x), b.corrupt("admit", 5, x)
    np.testing.assert_array_equal(xa, xb)  # seeded → replayable
    assert np.isnan(xa).any() and not np.isnan(x).any()


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="worker", kind="nuke")
    with pytest.raises(ValueError):
        FaultSpec(site="worker", times=0)
    plan = FaultPlan()
    assert not plan.active()
    plan.fire("worker")  # inert plan: no-op everywhere
    assert plan.corrupt("admit", 0, np.ones(3)) is not None
