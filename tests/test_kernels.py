"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: fall back to seeded random fuzzing
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import prox_sorted_l1
from repro.core.screening import algorithm_2_oracle
from repro.kernels import (
    compact_gemv_stats,
    prox_pool,
    prox_sorted_l1_kernel,
    screen_scan,
    slope_gradient,
    slope_gradient_compact,
    slope_gradient_masked,
    slope_loss_residual,
    slope_loss_residual_compact,
    slope_residual,
    slope_residual_compact,
    slope_residual_masked,
)
from repro.kernels import ref as R

SHAPES = [(7, 13, 1), (64, 128, 3), (33, 257, 4), (256, 512, 1), (129, 1025, 2)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_xt_matmul_kernel(shape, dtype, rng):
    n, p, m = shape
    X = jnp.asarray(rng.normal(size=(n, p)), dtype)
    Rm = jnp.asarray(rng.normal(size=(n, m)), dtype)
    got = np.asarray(slope_gradient(X, Rm), np.float32)
    want = np.asarray(R.xt_matmul_ref(X, Rm), np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("family", ["none", "ols", "logistic", "poisson", "multinomial"])
def test_xb_residual_kernel(shape, family, rng):
    n, p, m = shape
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(p, m)) / np.sqrt(p), jnp.float32)
    Y = jnp.asarray(rng.integers(0, 2, size=(n, m)), jnp.float32)
    got = np.asarray(slope_residual(X, B, Y, family=family))
    want = np.asarray(R.xb_residual_ref(X, B, Y, family))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_xt_matmul_masked_kernel(shape, rng):
    """Mask-aware gradient GEMV: block-skip must not change the result, and
    masked columns' gradient rows must be exactly 0."""
    n, p, m = shape
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    Rm = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    # sparse mask leaves whole (bn × bp) blocks dead — the skip path
    mask = np.zeros(p, bool)
    mask[rng.choice(p, size=max(1, p // 8), replace=False)] = True
    got = np.asarray(slope_gradient_masked(X, Rm, jnp.asarray(mask)))
    want = np.asarray(R.xt_matmul_masked_ref(X, Rm, jnp.asarray(mask)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    assert (got[~mask] == 0.0).all()
    # all-masked and all-alive extremes
    dead = np.asarray(slope_gradient_masked(X, Rm, jnp.zeros(p, bool)))
    assert (dead == 0.0).all()
    alive = np.asarray(slope_gradient_masked(X, Rm, jnp.ones(p, bool)))
    np.testing.assert_allclose(alive, np.asarray(slope_gradient(X, Rm)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("family", ["none", "ols", "logistic", "multinomial"])
def test_xb_residual_masked_kernel(shape, family, rng):
    n, p, m = shape
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(p, m)) / np.sqrt(p), jnp.float32)
    Y = jnp.asarray(rng.integers(0, 2, size=(n, m)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=p).astype(bool))
    got = np.asarray(slope_residual_masked(X, B, Y, mask, family=family))
    want = np.asarray(R.xb_residual_masked_ref(X, B, Y, mask, family))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("family", ["ols", "logistic", "poisson", "multinomial"])
def test_fused_loss_residual_kernel(shape, family, rng):
    """One X pass must reproduce the separate loss + residual oracles."""
    from repro.core import get_family

    n, p, m = shape
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(p, m)) / np.sqrt(p), jnp.float32)
    Y = jnp.asarray(rng.integers(0, 2, size=(n, m)), jnp.float32)
    loss, r = slope_loss_residual(X, B, Y, family=family)
    want_r, want_rows = R.xb_loss_residual_ref(X, B, Y, family)
    np.testing.assert_allclose(np.asarray(r), np.asarray(want_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(float(loss), float(jnp.sum(want_rows)),
                               rtol=2e-4, atol=2e-4)
    if family != "multinomial" and m == 1:
        # cross-check against the Family value/residual pair the solver uses
        fam = get_family(family)
        z = X @ B[:, 0]
        np.testing.assert_allclose(float(loss),
                                   float(fam.value(z, Y[:, 0])),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block-compacted GEMVs (ISSUE 5): live-block grid remap via scalar prefetch
# ---------------------------------------------------------------------------

def _block_mask(p: int, bp: int, pattern: str, rng) -> np.ndarray:
    """Column mask whose per-block liveness follows ``pattern`` (blocks of
    width ``bp``): all live, every other block live, or all dead.  Live
    blocks keep a random sparse interior so the in-block mask multiply is
    exercised too."""
    n_blocks = (p + bp - 1) // bp
    mask = np.zeros(p, bool)
    live = {"all_live": range(n_blocks),
            "half_live": range(0, n_blocks, 2),
            "all_dead": ()}[pattern]
    for b in live:
        lo, hi = b * bp, min((b + 1) * bp, p)
        cols = rng.choice(np.arange(lo, hi), size=max(1, (hi - lo) // 4),
                          replace=False)
        mask[cols] = True
    return mask


@pytest.mark.parametrize("pattern", ["all_live", "half_live", "all_dead"])
def test_compact_gemv_patterns(pattern, rng):
    """Compact == masked == oracle at every block-liveness pattern, and the
    remapped grid covers exactly the live blocks (dead-block DMA cannot
    happen when the grid never visits the block)."""
    n, p, m = 24, 512, 2
    bp = 128
    n_blocks = p // bp
    expect_live = {"all_live": n_blocks, "half_live": n_blocks // 2,
                   "all_dead": 0}[pattern]
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    Rm = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(p, m)) / np.sqrt(p), jnp.float32)
    Y = jnp.asarray(rng.integers(0, 2, size=(n, m)), jnp.float32)
    mask = jnp.asarray(_block_mask(p, bp, pattern, rng))

    got = np.asarray(slope_gradient_compact(X, Rm, mask, bp=bp))
    st = compact_gemv_stats("gradient")
    assert (st.blocks_total, st.blocks_live) == (n_blocks, expect_live)
    assert st.grid[0] == st.blocks_live  # the remapped grid == live blocks
    np.testing.assert_array_equal(
        got, np.asarray(slope_gradient_masked(X, Rm, mask, bp=bp)))
    want = np.asarray(R.xt_matmul_compact_ref(X, Rm, mask))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    assert (got[~np.asarray(mask)] == 0.0).all()

    for family in ("ols", "logistic"):
        got_r = np.asarray(slope_residual_compact(X, B, Y, mask,
                                                  family=family, bp=bp))
        st = compact_gemv_stats("residual")
        assert st.blocks_live == expect_live
        assert st.grid[1] == st.blocks_live
        np.testing.assert_array_equal(
            got_r, np.asarray(slope_residual_masked(X, B, Y, mask,
                                                    family=family, bp=bp)))
        want_r = np.asarray(R.xb_residual_compact_ref(X, B, Y, mask, family))
        np.testing.assert_allclose(got_r, want_r, rtol=3e-5, atol=3e-5)

    loss, r = slope_loss_residual_compact(X, B, Y, mask, family="logistic",
                                          bp=bp)
    st = compact_gemv_stats("loss_residual")
    assert st.blocks_live == expect_live and st.grid[1] == st.blocks_live
    want_r, want_rows = R.xb_loss_residual_compact_ref(X, B, Y, mask,
                                                       "logistic")
    np.testing.assert_allclose(np.asarray(r), np.asarray(want_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(float(loss), float(jnp.sum(want_rows)),
                               rtol=2e-4, atol=2e-4)


def test_compact_gemv_odd_shapes_and_1d(rng):
    """Padding/squeeze parity with the masked wrappers at non-block shapes."""
    n, p = 33, 257
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    r = jnp.asarray(rng.normal(size=n), jnp.float32)
    b = jnp.asarray(rng.normal(size=p) / np.sqrt(p), jnp.float32)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    mask = np.zeros(p, bool)
    mask[rng.choice(p, size=9, replace=False)] = True
    mj = jnp.asarray(mask)
    g = slope_gradient_compact(X, r, mj)
    assert g.shape == (p,)
    np.testing.assert_array_equal(
        np.asarray(g), np.asarray(slope_gradient_masked(X, r, mj)))
    z = slope_residual_compact(X, b, y, mj, family="ols")
    assert z.shape == (n,)
    np.testing.assert_array_equal(
        np.asarray(z),
        np.asarray(slope_residual_masked(X, b, y, mj, family="ols")))


def test_compact_gemv_traced_mask_degrades_to_masked(rng):
    """Under jit the mask is a tracer — no static live list exists, so the
    compact wrappers must fall back to the (semantically identical) masked
    kernels instead of failing."""
    import jax

    n, p = 16, 256
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=p).astype(bool))

    @jax.jit
    def traced(m):
        return slope_gradient_compact(X, r, m)

    np.testing.assert_allclose(
        np.asarray(traced(mask)),
        np.asarray(slope_gradient_masked(X, r, mask)), rtol=2e-5, atol=2e-5)


def test_gemv_1d_paths(rng):
    X = jnp.asarray(rng.normal(size=(50, 70)), jnp.float32)
    r = jnp.asarray(rng.normal(size=50), jnp.float32)
    b = jnp.asarray(rng.normal(size=70) / 8, jnp.float32)
    y = jnp.asarray(rng.normal(size=50), jnp.float32)
    g = slope_gradient(X, r)
    assert g.shape == (70,)
    np.testing.assert_allclose(np.asarray(g), np.asarray(X).T @ np.asarray(r),
                               rtol=2e-5, atol=2e-5)
    z = slope_residual(X, b, y, family="ols")
    assert z.shape == (50,)
    np.testing.assert_allclose(np.asarray(z), np.asarray(X) @ np.asarray(b) - np.asarray(y),
                               rtol=2e-5, atol=2e-5)


@st.composite
def screen_case(draw):
    """Dyadic-grid inputs (multiples of 1/64, bounded): every partial sum is
    exact in f32, so block-wise (kernel), parallel-prefix (ref) and
    sequential (Algorithm 2) summation orders all agree exactly — the tests
    check the algorithms, not float association on constructed ties."""
    p = draw(st.integers(1, 600))
    c = draw(st.lists(st.integers(-320, 320), min_size=p, max_size=p))
    raw = draw(st.lists(st.integers(0, 256), min_size=p, max_size=p))
    lam = np.sort(np.asarray(raw, np.float32))[::-1] / 64.0
    return np.asarray(c, np.float32) / 64.0, lam


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(screen_case())
def test_screen_kernel_matches_f32_ref(case):
    c, lam = case
    k_ref = int(R.screen_scan_ref(jnp.asarray(c), jnp.asarray(lam)))
    k_kernel = int(screen_scan(jnp.asarray(c), jnp.asarray(lam), block=128))
    assert k_ref == k_kernel


def test_screen_kernel_fixed_cases(rng):
    """Fast-tier screen-kernel coverage: one compile, deterministic data."""
    p = 256
    lam = np.sort(np.abs(rng.normal(size=p)).astype(np.float32))[::-1].copy()
    for scale in (0.1, 1.0, 3.0):
        c = (rng.normal(size=p) * scale).astype(np.float32)
        k_ref = algorithm_2_oracle(c, lam)
        k_kernel = int(screen_scan(jnp.asarray(c), jnp.asarray(lam), block=128))
        assert k_ref == k_kernel


@pytest.mark.slow
def test_screen_kernel_matches_algorithm_2_random(rng):
    """Kernel vs the paper's Algorithm 2 on generic (non-adversarial) data.

    Slow tier: 200 interpret-mode pallas calls across ~200 distinct padded
    shapes recompile per shape."""
    for trial in range(200):
        p = int(rng.integers(1, 2000))
        c = (rng.normal(size=p) * 3).astype(np.float32)
        lam = np.sort(np.abs(rng.normal(size=p)).astype(np.float32))[::-1].copy()
        k1 = algorithm_2_oracle(c, lam)
        k2 = int(screen_scan(jnp.asarray(c), jnp.asarray(lam), block=256))
        assert k1 == k2, (trial, p, k1, k2)


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 400), st.integers(0, 2**31 - 1))
def test_prox_kernel_matches_core(p, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=p) * 3, jnp.float32)
    lam = jnp.asarray(np.sort(np.abs(rng.normal(size=p)))[::-1], jnp.float32)
    got = np.asarray(prox_sorted_l1_kernel(v, lam))
    want = np.asarray(prox_sorted_l1(v, lam))
    np.testing.assert_allclose(got, want, atol=3e-6)


def test_prox_pool_monotone_output(rng):
    for trial in range(12):
        p = (1, 7, 120, 500)[trial % 4]
        w = jnp.asarray(np.sort(rng.normal(size=p))[::-1] + rng.normal(size=p) * 0.3,
                        jnp.float32)
        out = np.asarray(prox_pool(w))
        assert np.all(np.diff(out) <= 1e-5)
        assert np.all(out >= 0)
