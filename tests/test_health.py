"""Non-finite propagation and in-graph quarantine (ISSUE 7 tentpole 1+3).

The contracts under test:

1. **In-graph quarantine.**  A NaN/Inf in one batch member's X, y or λ is
   detected inside the scan, the member's health word goes sticky-nonzero,
   its coefficients are zeroed placeholders, and — crucially — the batch
   neither stalls (the poisoned solve is blanked, so FISTA's NaN-blind
   stop criteria are never exercised on NaN data) nor contaminates: the
   innocent members' arrays are **bit-identical** to the same batch with a
   clean member in the sick slot (vmap lanes are independent; quarantine
   must keep them so).
2. **Admission validation.**  ``validate="strict"`` (the default) rejects
   non-finite operands host-side with a structured
   :class:`~repro.api.ValidationError` on every front door (``slope_path``
   all backends, ``PathService.submit``); ``"quarantine"`` admits and the
   response comes back flagged; ``"off"`` skips the host scan.
3. **Serve parity.**  A quarantined request resolves as a *flagged
   response*, not an exception, and a clean co-batched neighbour's betas
   equal a solo serve of the same request at tolerance 0.
"""

import numpy as np
import pytest

from repro.api import (
    LambdaSpec,
    PathSpec,
    Problem,
    SolverPolicy,
    ValidationError,
    find_nonfinite,
    slope_path,
)
from repro.core import bh_sequence, ols
from repro.core.engine import (
    HEALTH_NONFINITE_INPUT,
    PathHealth,
    health_causes,
)
from repro.serve import PathService, ProgramCache

KW = dict(path_length=6, solver_tol=1e-10, max_iter=20000, kkt_tol=1e-4)


def _problems(B=3, n=24, p=16, seed0=0):
    rng = np.random.default_rng(seed0)
    Xs = rng.normal(size=(B, n, p))
    beta = np.zeros(p)
    beta[:4] = 2.0
    ys = Xs @ beta + 0.1 * rng.normal(size=(B, n))
    return Xs, ys


def _fit(Xs, ys, lam, *, backend, validate="quarantine", working_set=None):
    return slope_path(
        Problem(Xs, ys, family=ols),
        PathSpec(lam=LambdaSpec.explicit(lam), path_length=KW["path_length"],
                 early_stop=False),
        SolverPolicy(backend=backend, working_set=working_set,
                     validate=validate, solver_tol=KW["solver_tol"],
                     max_iter=KW["max_iter"], kkt_tol=KW["kkt_tol"],
                     pad=None))


def _poison(arr, kind):
    bad = np.array(arr, copy=True)
    flat = bad.reshape(-1)
    flat[3] = np.nan if kind == "nan" else np.inf
    return bad


# ---------------------------------------------------------------------------
# find_nonfinite / ValidationError / Problem.check_finite
# ---------------------------------------------------------------------------

def test_find_nonfinite_reports_name_count_index():
    x = np.zeros((2, 3))
    x[1, 1] = np.inf
    issues = find_nonfinite(X=x, y=np.ones(3), skip=None)
    assert issues == (("X", 1, 4),)
    assert find_nonfinite(X=np.ones(4)) == ()


def test_validation_error_is_structured_valueerror():
    err = ValidationError((("X", 2, 7),))
    assert isinstance(err, ValueError)
    assert err.issues == (("X", 2, 7),)
    assert "X" in str(err) and "quarantine" in str(err)


def test_problem_check_finite():
    Xs, ys = _problems(B=1)
    Problem(Xs[0], ys[0]).check_finite()
    with pytest.raises(ValidationError) as ei:
        Problem(_poison(Xs[0], "nan"), ys[0]).check_finite()
    assert ei.value.issues[0][0] == "X"


# ---------------------------------------------------------------------------
# strict rejection on every direct backend (host included)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["host", "masked"])
def test_strict_rejects_nonfinite_direct(backend):
    Xs, ys = _problems(B=1)
    lam = np.asarray(bh_sequence(Xs.shape[-1], q=0.1))
    X1, y1 = Xs[0], ys[0]
    if backend == "masked":
        X1, y1 = Xs, ys  # batched problem → the batched device engine
    with pytest.raises(ValidationError):
        _fit(_poison(X1, "nan"), y1, lam, backend=backend,
             validate="strict")
    with pytest.raises(ValidationError):
        _fit(X1, _poison(y1, "inf"), lam, backend=backend,
             validate="strict")
    with pytest.raises(ValidationError):
        _fit(X1, y1, _poison(lam, "nan"), backend=backend,
             validate="strict")


# ---------------------------------------------------------------------------
# in-graph quarantine: masked and compact engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("working_set", [None, 8],
                         ids=["masked", "compact"])
@pytest.mark.parametrize("target", ["X", "y", "lam"])
def test_quarantine_flags_sick_member_only(working_set, target):
    Xs, ys = _problems()
    lam = np.asarray(bh_sequence(Xs.shape[-1], q=0.1))
    backend = "masked" if working_set is None else "compact"

    clean = _fit(Xs, ys, lam, backend=backend, working_set=working_set)
    assert clean.path_health is not None
    assert not clean.path_health.quarantined.any()

    Xb, yb, lamb = Xs, ys, lam
    if target == "X":
        Xb = Xs.copy()
        Xb[1] = _poison(Xs[1], "nan")
    elif target == "y":
        yb = ys.copy()
        yb[1] = _poison(ys[1], "nan")
    else:
        # λ is shared across the batch: poisoning it sickens EVERY member
        lamb = _poison(lam, "nan")

    with pytest.warns(RuntimeWarning, match="quarantined"):
        res = _fit(Xb, yb, lamb, backend=backend, working_set=working_set)

    ph = res.path_health
    assert isinstance(ph, PathHealth)
    if target == "lam":
        assert ph.quarantined.all()
        assert all(ph.causes(b) for b in range(3))
        return
    np.testing.assert_array_equal(ph.quarantined, [False, True, False])
    assert ph.first_bad_step[1] >= 0
    assert "nonfinite" in "".join(ph.causes(1))
    # the sick member's path is a zeroed placeholder, finite throughout
    assert np.isfinite(res.betas[1]).all()
    assert (res.betas[1][ph.first_bad_step[1]:] == 0).all()
    # innocents: bit-identical to the all-clean batch, slot for slot
    for b in (0, 2):
        np.testing.assert_array_equal(res.betas[b], clean.betas[b])
        np.testing.assert_array_equal(res.deviance[b], clean.deviance[b])


def test_health_causes_names():
    assert health_causes(0) == ()
    assert "nonfinite_input" in health_causes(HEALTH_NONFINITE_INPUT)
    assert health_causes(7) == ("nonfinite_input", "nonfinite_state",
                                "diverged")


# ---------------------------------------------------------------------------
# serve: strict rejects, quarantine flags, neighbours stay bit-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shared_cache():
    return ProgramCache(capacity=8)


def _svc(shared_cache, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_delay", 60.0)
    return PathService(cache=shared_cache, **kw)


def test_serve_strict_rejects(shared_cache):
    Xs, ys = _problems(B=1)
    svc = _svc(shared_cache)
    with pytest.raises(ValidationError):
        svc.submit(_poison(Xs[0], "nan"), ys[0], family=ols, **KW)
    assert svc.stats()["validation_rejected"] == 1
    assert svc.stats()["submitted"] == 0  # rejected before admission


def test_serve_quarantine_flags_and_isolates(shared_cache):
    Xs, ys = _problems(B=2, seed0=7)
    svc = _svc(shared_cache)
    # reference: the clean request served solo (same compiled program and
    # padded slot count, so co-batching must reproduce it bitwise)
    rid_solo = svc.submit(Xs[0], ys[0], family=ols, **KW)
    solo = svc.poll(rid_solo, flush=True)

    svc2 = _svc(shared_cache)
    rid_ok = svc2.submit(Xs[0], ys[0], family=ols, **KW)
    rid_bad = svc2.submit(_poison(Xs[1], "nan"), ys[1], family=ols,
                          validate="quarantine", **KW)
    ok = svc2.poll(rid_ok, flush=True)
    bad = svc2.poll(rid_bad)

    assert not ok.quarantined and ok.health_causes == ()
    assert bad.quarantined
    assert "nonfinite" in "".join(bad.health_causes)
    assert np.isfinite(bad.betas).all()
    # a sick neighbour changes NOTHING for the clean request
    np.testing.assert_array_equal(ok.betas, solo.betas)
    np.testing.assert_array_equal(ok.deviance, solo.deviance)
    # path_result() round-trips the health word
    pr = bad.path_result(early_stop=False)
    assert pr is not None


def test_serve_validate_off_skips_host_scan(shared_cache):
    Xs, ys = _problems(B=1, seed0=11)
    svc = _svc(shared_cache)
    rid = svc.submit(_poison(Xs[0], "nan"), ys[0], family=ols,
                     validate="off", **KW)
    resp = svc.poll(rid, flush=True)
    assert resp.quarantined  # the in-graph detector is always on
    assert svc.stats()["validation_rejected"] == 0
