"""repro.serve — shape-bucketed path service (ISSUE 3).

The contract under test: a request padded into a bucket and served through
`PathService` returns BIT-IDENTICAL coefficients to an unpadded direct
`fit_path_batched(..., pad="bucket")` call (tolerance 0, masked and compact
backends, including an all-zero-column edge case), because both resolve
execution shapes through the same policy and batch slots are bitwise
member-invariant.  Around that: registry/batcher/cache unit behavior,
padding semantics vs the native-shape engine, CV-through-the-service
equivalence with `cv_path`, and the telemetry surface.
"""

import threading

import numpy as np
import pytest

from repro.core import (
    bh_sequence,
    cv_path,
    fit_path,
    fit_path_batched,
    logistic,
    ols,
)
from repro.core.engine import _WS_BUCKETS, cv_fold_indices
from repro.data import make_classification, make_regression
from repro.serve import (
    BucketRegistry,
    LambdaCanonicalizer,
    MicroBatcher,
    PathService,
    ProgramCache,
    ProgramSpec,
    ShapeBucketPolicy,
    next_pow2,
    pad_batch,
)

# small problems + short dense paths: every compiled program in this module
# is shape (32, 32) or (32, 64) so the AOT builds stay countable and the
# jit cache carries the direct-call arms across tests
KW = dict(path_length=6, solver_tol=1e-10, max_iter=20000, kkt_tol=1e-4)
SVC_KW = dict(path_length=6, solver_tol=1e-10, max_iter=20000)


@pytest.fixture(scope="module")
def shared_cache():
    """One ProgramCache for every service in this module — AOT builds are
    seconds each, so tests share residency like a real deployment would."""
    return ProgramCache(capacity=16)


def _svc(shared_cache, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 1000.0)  # flush explicitly unless testing it
    return PathService(cache=shared_cache, **kw)


def _problem(n, p, seed=0, k=4):
    X, y, _ = make_regression(n, p, k=k, rho=0.2, seed=seed)
    return X, y, np.asarray(bh_sequence(p, q=0.1))


# ---------------------------------------------------------------------------
# buckets: policy, registry, padding
# ---------------------------------------------------------------------------

def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 8, 9, 1000)] == \
        [1, 1, 2, 4, 8, 16, 1024]


def test_shape_policy_buckets():
    pol = ShapeBucketPolicy()
    assert pol.shape_bucket(20, 24, "ols") == (32, 32)
    assert pol.shape_bucket(16, 100, "ols") == (16, 128)
    # non-OLS families keep their exact row count (zero rows change the
    # loss for logistic/Poisson/multinomial)
    assert pol.shape_bucket(20, 24, "logistic") == (20, 32)
    assert pol.batch_bucket(1) == 2   # B=1 programs are not bitwise
    assert pol.batch_bucket(5) == 8   # member-invariant with B>=2 ones


def test_bucket_registry_mapping_and_stats():
    reg = BucketRegistry(name="t", capacity=3)
    reg["a"] = 64
    assert "a" in reg and reg["a"] == 64
    assert reg.get("missing") is None
    with pytest.raises(KeyError):
        reg["missing"]
    reg["b"], reg["c"] = 128, 256
    reg.get("a")                      # refresh a's recency
    reg["d"] = 512                    # evicts b (LRU)
    assert "b" not in reg and "a" in reg and len(reg) == 3
    st = reg.stats()
    assert st["evictions"] == 1 and st["updates"] == 4
    assert st["hits"] >= 2 and st["misses"] >= 2
    assert st["entries"] == {"a": 64, "c": 256, "d": 512}
    assert reg.pop("a") == 64 and reg.pop("a", "gone") == "gone"


def test_bucket_registry_grow_monotonic_capped():
    """The grow-on-overflow write path (satellite regression): growth is
    monotonic (a racing smaller grower can never shrink a learned bucket),
    idempotent, and capped at the native column count — a bucket wider
    than p is wasted compaction."""
    reg = BucketRegistry(name="g", capacity=4)
    assert reg.grow("k", 48, cap=256)
    assert reg["k"] == 48
    assert not reg.grow("k", 32, cap=256)   # smaller: no shrink
    assert reg["k"] == 48
    assert not reg.grow("k", 48, cap=256)   # idempotent re-apply
    assert reg.grow("k", 4096, cap=256)     # capped at native p
    assert reg["k"] == 256
    assert not reg.grow("k", 4096, cap=256)


def test_bucket_registry_grow_concurrent_idempotent():
    """Racing growers converge on the maximum, never a last-writer value."""
    reg = BucketRegistry(capacity=8)

    def hammer(v):
        for _ in range(200):
            reg.grow("x", v, cap=1024)

    threads = [threading.Thread(target=hammer, args=(v,))
               for v in (64, 256, 128, 32)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert reg["x"] == 256


def test_grow_ws_bucket_caps_at_native_p():
    """The engine-level growth helper honours the native-p cap and the
    monotonic registry semantics."""
    from repro.core.engine import _WS_BUCKETS, grow_ws_bucket

    key = ("grow-cap-test",)
    _WS_BUCKETS.pop(key, None)
    # peak demand 1500 → next_pow2 = 2048 would overshoot native p = 1500
    assert grow_ws_bucket(key, np.array([1500]), np.array([True]), 64, 1500)
    assert _WS_BUCKETS[key] == 1500
    # a later, smaller overflow must not shrink the learned bucket
    assert not grow_ws_bucket(key, np.array([700]), np.array([True]), 64,
                              1500)
    assert _WS_BUCKETS[key] == 1500
    # no overflow, or an already-maximal W: no write
    assert not grow_ws_bucket(key, np.array([90]), np.array([False]), 64,
                              1500)
    assert not grow_ws_bucket(key, np.array([1500]), np.array([True]), 1500,
                              1500)
    _WS_BUCKETS.pop(key, None)


def test_grow_ws_bucket_two_tier_learns_half_peak():
    """A two-tier run only needs the HALF-peak bucket — its 2W tier covers
    the rest — where single-tier growth stores the full next-pow2 peak."""
    from repro.core.engine import _WS_BUCKETS, grow_ws_bucket

    key = ("grow-half-peak-test",)
    _WS_BUCKETS.pop(key, None)
    assert grow_ws_bucket(key, np.array([42]), np.array([True]), 16, 2048,
                          two_tier=True)
    assert _WS_BUCKETS[key] == 32        # next_pow2(42) / 2
    _WS_BUCKETS.pop(key, None)
    assert grow_ws_bucket(key, np.array([42]), np.array([True]), 16, 2048)
    assert _WS_BUCKETS[key] == 64        # single tier: the full pow2 peak
    # at the cap the halved bucket would get no 2× tier and overflow again
    # — keep the full width there
    _WS_BUCKETS.pop(key, None)
    assert grow_ws_bucket(key, np.array([256]), np.array([True]), 64, 256,
                          two_tier=True)
    assert _WS_BUCKETS[key] == 256
    _WS_BUCKETS.pop(key, None)


def test_bucket_registry_thread_safety():
    reg = BucketRegistry(capacity=64)

    def hammer(t):
        for i in range(200):
            reg[(t, i % 32)] = i
            reg.get((t, (i + 1) % 32))

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(reg) <= 64
    assert reg.stats()["updates"] == 8 * 200


def test_pad_batch_layout():
    X, y, lam = _problem(20, 24)
    sig = np.linspace(1.0, 0.1, 6)
    pb = pad_batch([(X, y, lam, sig)], n_rows=32, n_cols=32, n_slots=4)
    assert pb.shape == (4, 32, 32)
    assert pb.p_valid.tolist() == [24, 0, 0, 0]
    np.testing.assert_array_equal(pb.Xs[0, :20, :24], X)
    assert pb.Xs[0, 20:, :].max() == 0 and pb.Xs[0, :, 24:].max() == 0
    assert pb.Xs[1:].max() == 0           # dummy slots all-zero
    assert pb.lam[0, 24:].max() == 0      # λ tail zero-padded
    np.testing.assert_array_equal(pb.sigmas[1], np.ones(6))
    with pytest.raises(ValueError):
        pad_batch([(X, y, lam, sig)], n_rows=16, n_cols=32, n_slots=4)


# ---------------------------------------------------------------------------
# batcher + λ canonicalization
# ---------------------------------------------------------------------------

def test_microbatcher_fill_and_deadline():
    mb = MicroBatcher(max_batch=3, max_delay=0.5)
    assert not mb.admit("g", 0, "a", now=0.0)
    assert not mb.admit("g", 1, "b", now=0.1)
    assert mb.admit("g", 2, "c", now=0.2)        # fill trigger
    assert mb.due(now=0.3) == []                 # not yet overdue
    assert mb.due(now=0.51) == ["g"]             # oldest passed deadline
    batch = mb.take("g")
    assert [p.rid for p in batch] == [0, 1, 2]   # FIFO
    assert mb.pending() == 0 and mb.take("g") == []


def test_lambda_canonicalizer_shares_arrays():
    canon = LambdaCanonicalizer()
    a = canon.get("bh", 0.1, 50)
    b = canon.get("bh", 0.1, 50)
    assert a is b and not a.flags.writeable
    assert canon.get("bh", 0.2, 50) is not a
    np.testing.assert_array_equal(a, np.asarray(bh_sequence(50, q=0.1)))
    np.testing.assert_array_equal(canon.get("lasso", 0.0, 8), np.ones(8))
    with pytest.raises(ValueError):
        canon.get("nope", 0.1, 50)
    with pytest.raises(ValueError):
        canon.get("gaussian", 0.1, 50)  # needs n
    assert len(canon.get("gaussian", 0.1, 50, n=40)) == 50


# ---------------------------------------------------------------------------
# compiled-program cache
# ---------------------------------------------------------------------------

def test_program_cache_aot_matches_jit_and_evicts():
    from repro.core.engine import batched_path_engine
    import jax.numpy as jnp

    cache = ProgramCache(capacity=1)
    spec = ProgramSpec(family=ols, batch=2, n_rows=16, n_cols=16,
                       path_length=4, solver_tol=1e-9, max_iter=2000)
    prog, hit = cache.get(spec)
    assert not hit and prog.build_seconds > 0
    _, hit = cache.get(spec)
    assert hit
    # AOT executable == jit dispatch, bitwise
    probs = [_problem(12, 14, seed=s) for s in range(2)]
    pb = pad_batch([(X, y, lam, np.linspace(1, 0.3, 4)) for X, y, lam in probs],
                   n_rows=16, n_cols=16, n_slots=2)
    aot = prog(pb.Xs, pb.ys, pb.lam, pb.sigmas, pb.p_valid)
    jit_out = batched_path_engine(
        jnp.asarray(pb.Xs), jnp.asarray(pb.ys), jnp.asarray(pb.lam),
        jnp.asarray(pb.sigmas), ols, jnp.asarray(pb.p_valid),
        screening="strong", max_iter=2000, tol=1e-9, kkt_tol=1e-4,
        max_refits=32)
    np.testing.assert_array_equal(np.asarray(aot.betas),
                                  np.asarray(jit_out.betas))
    # capacity 1: a second spec evicts the first
    spec2 = ProgramSpec(family=ols, batch=2, n_rows=16, n_cols=16,
                        path_length=5, solver_tol=1e-9, max_iter=2000)
    cache.get(spec2)
    assert spec not in cache and spec2 in cache
    st = cache.stats()
    assert st["evictions"] == 1 and st["hits"] == 1 and st["misses"] == 2
    # warmup: one resident, one fresh
    out = cache.warmup([spec2, spec])
    assert out[spec2.short()] == 0.0 and out[spec.short()] > 0


# ---------------------------------------------------------------------------
# the tentpole contract: served == direct padded call, bit for bit
# ---------------------------------------------------------------------------

def test_served_bit_identical_masked(shared_cache):
    """Mixed native widths co-batched in one bucket: every response must be
    bit-identical to its own unpadded fit_path_batched(pad='bucket') call,
    and to serving the same request alone (batch composition must not leak
    into results)."""
    reqs = [_problem(20, 24, seed=0), _problem(18, 30, seed=1),
            _problem(20, 24, seed=2)]
    svc = _svc(shared_cache)
    rids = [svc.submit(X, y, lam=lam, **SVC_KW) for X, y, lam in reqs]
    svc.flush()
    resps = [svc.poll(r) for r in rids]
    assert all(r is not None for r in resps)
    assert resps[0].batch_size == 3
    assert resps[0].batch_occupancy == pytest.approx(3 / 4)
    for (X, y, lam), resp in zip(reqs, resps):
        direct = fit_path_batched(X[None], y[None], lam, ols,
                                  pad="bucket", **KW)
        assert resp.betas.shape == direct.betas[0].shape  # native, unpadded
        np.testing.assert_array_equal(resp.betas, direct.betas[0])
        np.testing.assert_array_equal(resp.n_screened, direct.n_screened[0])
        np.testing.assert_array_equal(resp.n_violations,
                                      direct.n_violations[0])
        assert resp.kkt_ok
    # solo submission: same program, dummy-filled slots -> identical bits
    solo = _svc(shared_cache)
    rid = solo.submit(reqs[1][0], reqs[1][1], lam=reqs[1][2], **SVC_KW)
    resp = solo.poll(rid, flush=True)
    np.testing.assert_array_equal(resp.betas, resps[1].betas)
    assert resp.batch_size == 1 and resp.cache_hit  # shared cache residency


def test_served_bit_identical_compact(shared_cache):
    """Same contract through the compact working-set backend (no overflow:
    fallback coupling across co-batched members is the documented exception
    to bit-identity, so the test uses p ≫ n sparse problems with a shallow
    σ grid, where W=32 sits above the peak demand)."""
    def sparse(n, p, seed):
        X, y, _ = make_regression(n, p, k=3, rho=0.2, seed=seed, noise=0.3)
        return X, y, np.asarray(bh_sequence(p, q=0.05))

    kw = dict(KW, sigma_ratio=0.5)
    svc_kw = dict(SVC_KW, sigma_ratio=0.5)
    reqs = [sparse(16, 60, seed=3), sparse(14, 55, seed=4)]
    svc = _svc(shared_cache)
    rids = [svc.submit(X, y, lam=lam, working_set=32, **svc_kw)
            for X, y, lam in reqs]
    svc.flush()
    resps = [svc.poll(r) for r in rids]
    for (X, y, lam), resp in zip(reqs, resps):
        assert resp.working_set == 32
        assert not resp.compact_fallback.any()
        assert resp.ws_size.max() > 0
        direct = fit_path_batched(X[None], y[None], lam, ols, working_set=32,
                                  pad="bucket", **kw)
        assert not direct.compact_fallback.any()
        np.testing.assert_array_equal(resp.betas, direct.betas[0])


def test_served_two_tier_compact_matches_direct(shared_cache):
    """A two-tier compact request resolves (W, 2W) through the shared tier
    recipe, compiles a two-tier program (working_set_top in the spec), and
    stays bit-identical to the direct padded call at the same widths."""
    X, y, _ = make_regression(16, 60, k=3, rho=0.2, seed=5, noise=0.3)
    lam = np.asarray(bh_sequence(60, q=0.05))
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, lam=lam, working_set=8, sigma_ratio=0.5,
                     **SVC_KW)
    resp = svc.poll(rid, flush=True)
    assert (resp.working_set, resp.working_set_top) == (8, 16)
    assert resp.ws_tier is not None and resp.ws_tier.shape == resp.ws_size.shape
    direct = fit_path_batched(X[None], y[None], lam, ols, working_set=8,
                              pad="bucket", sigma_ratio=0.5, **KW)
    assert (direct.working_set, direct.working_set_top) == (8, 16)
    np.testing.assert_array_equal(resp.betas, direct.betas[0])
    np.testing.assert_array_equal(resp.ws_tier, direct.ws_tier[0])


def test_served_bit_identical_all_zero_column(shared_cache):
    """Degenerate user data: a request whose X already contains all-zero
    columns must unpad cleanly (real zero columns are not confused with
    bucket padding) and stay bit-identical to the direct padded call."""
    X, y, lam = _problem(20, 24, seed=5)
    X = X.copy()
    X[:, [3, 17]] = 0.0
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, lam=lam, **SVC_KW)
    resp = svc.poll(rid, flush=True)
    direct = fit_path_batched(X[None], y[None], lam, ols, pad="bucket", **KW)
    np.testing.assert_array_equal(resp.betas, direct.betas[0])
    assert resp.betas.shape == (6, 24)
    assert np.abs(resp.betas[:, [3, 17]]).max() == 0.0  # inert, exactly


def test_served_logistic_exact_rows(shared_cache):
    """Non-OLS families must NOT get row padding (zero rows shift their
    loss): the bucket keeps the exact n, columns still pad, and the served
    result stays bit-identical to the direct padded call and tolerance-close
    to the native-shape engine."""
    X, y, _ = make_classification(20, 24, k=3, rho=0.1, seed=17)
    lam = np.asarray(bh_sequence(24, q=0.1))
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, family=logistic, lam=lam, **SVC_KW)
    resp = svc.poll(rid, flush=True)
    direct = fit_path_batched(X[None], y[None], lam, logistic,
                              pad="bucket", **KW)
    assert direct.pad_shape == (2, 20, 32)  # rows exact, columns padded
    np.testing.assert_array_equal(resp.betas, direct.betas[0])
    native = fit_path_batched(X[None], y[None], lam, logistic, **KW)
    np.testing.assert_allclose(resp.betas, native.betas[0], atol=5e-3)
    np.testing.assert_array_equal(resp.n_violations, native.n_violations[0])


def test_padded_semantics_match_native_engine():
    """pad='bucket' is a different execution shape, not different math:
    screening decisions and violation counts must be identical to the
    native-shape engine, coefficients within solver tolerance."""
    X, y, lam = _problem(20, 24, seed=6)
    native = fit_path_batched(X[None], y[None], lam, ols, **KW)
    padded = fit_path_batched(X[None], y[None], lam, ols, pad="bucket", **KW)
    assert padded.pad_shape == (2, 32, 32) and native.pad_shape is None
    np.testing.assert_array_equal(native.n_screened, padded.n_screened)
    np.testing.assert_array_equal(native.n_violations, padded.n_violations)
    np.testing.assert_allclose(native.betas, padded.betas, atol=5e-3)


def test_fit_path_device_pad_bucket():
    X, y, lam = _problem(20, 24, seed=7)
    host = fit_path(X, y, lam, ols, engine="host", early_stop=False, **KW)
    dev = fit_path(X, y, lam, ols, engine="device", pad="bucket",
                   early_stop=False, **KW)
    np.testing.assert_allclose(host.betas, dev.betas, atol=5e-3)
    assert len(dev.steps) == len(host.steps)
    with pytest.raises(ValueError):
        fit_path(X, y, lam, ols, engine="host", pad="bucket", **KW)


def test_per_member_lambda_batched():
    """fit_path_batched with a (B, p·m) λ stack: each member must match the
    same member fitted in a batch where that λ is shared (member results
    cannot depend on a neighbour's λ)."""
    (X0, y0, lamA), (X1, y1, _) = _problem(20, 24, seed=8), _problem(20, 24,
                                                                     seed=9)
    lamB = np.asarray(bh_sequence(24, q=0.02))
    Xs = np.stack([X0, X1])
    ys = np.stack([y0, y1])
    mixed = fit_path_batched(Xs, ys, np.stack([lamA, lamB]), ols, **KW)
    sharedA = fit_path_batched(Xs, ys, lamA, ols, **KW)
    sharedB = fit_path_batched(Xs, ys, lamB, ols, **KW)
    np.testing.assert_array_equal(mixed.betas[0], sharedA.betas[0])
    np.testing.assert_array_equal(mixed.betas[1], sharedB.betas[1])
    with pytest.raises(ValueError):
        fit_path_batched(Xs, ys, np.stack([lamA]), ols, **KW)


# ---------------------------------------------------------------------------
# service mechanics: deadlines, telemetry, registry sharing
# ---------------------------------------------------------------------------

def test_service_deadline_flush(shared_cache):
    clock = {"t": 0.0}
    svc = PathService(max_batch=4, max_delay=0.5, cache=shared_cache,
                      clock=lambda: clock["t"])
    X, y, lam = _problem(20, 24, seed=10)
    rid = svc.submit(X, y, lam=lam, **SVC_KW)
    assert svc.poll(rid) is None            # queued: not full, not overdue
    clock["t"] = 0.6
    resp = svc.poll(rid)                    # deadline passed -> flushed
    assert resp is not None and resp.queue_s >= 0.5
    assert svc.stats()["flush_deadline"] == 1
    assert svc.poll(rid) is None            # responses hand out once


def test_service_fill_flush_and_stats(shared_cache):
    svc = _svc(shared_cache)
    probs = [_problem(20, 24, seed=20 + s) for s in range(4)]
    rids = [svc.submit(X, y, lam=lam, **SVC_KW) for X, y, lam in probs]
    st = svc.stats()
    assert st["flush_fill"] == 1            # 4 submits filled max_batch=4
    assert st["pending"] == 0
    resps = [svc.poll(r) for r in rids]
    assert all(r is not None for r in resps)
    assert resps[0].batch_occupancy == 1.0
    assert {r.rid for r in resps} == set(rids)
    assert st["occupancy_mean"] > 0 and st["latency_ms_p95"] >= 0
    assert st["cache"]["hits"] >= 0 and st["ws_buckets"]["capacity"] == 256


def test_service_validates_requests(shared_cache):
    svc = _svc(shared_cache)
    X, y, lam = _problem(20, 24)
    with pytest.raises(ValueError):
        svc.submit(X[0], y, lam=lam)                 # 1-D X
    with pytest.raises(ValueError):
        svc.submit(X, y, lam=lam[:-1])               # wrong λ length
    with pytest.raises(ValueError):
        svc.submit(X, y, lam=lam, working_set="big")  # bad working_set
    with pytest.raises(ValueError):
        svc.submit(X, y, lam=lam, working_set=0)      # direct path parity


def test_service_grows_shared_ws_registry(shared_cache):
    """An overflowing service batch must grow the SAME registry direct
    calls use (the satellite contract: one BucketRegistry, engine + serve)."""
    X, y, _ = make_regression(20, 40, k=15, rho=0.3, seed=12, noise=0.05)
    lam = np.asarray(bh_sequence(40, q=0.1))
    key = (32, 64, 1, "ols", "strong")  # padded bucket of (20, 40)
    _WS_BUCKETS.pop(key, None)
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, lam=lam, working_set="auto", path_length=10,
                     solver_tol=1e-9, max_iter=8000)
    resp = svc.poll(rid, flush=True)
    if resp.compact_fallback.any():     # overflow happened -> registry grew
        assert key in _WS_BUCKETS
        assert _WS_BUCKETS[key] > 0


# ---------------------------------------------------------------------------
# CV through the service == cv_path (stratified folds, 1-SE selection)
# ---------------------------------------------------------------------------

def test_cv_fold_indices_stratified_balance():
    y = np.array([0] * 15 + [1] * 9)
    trains, vals = cv_fold_indices(y, 3, family=logistic, stratify="auto")
    for tr, va in zip(trains, vals):
        assert len(va) == 8 and len(tr) == 16
        # each fold sees both classes at the full-data ratio (5:3)
        assert (y[va] == 0).sum() == 5 and (y[va] == 1).sum() == 3
        assert np.intersect1d(tr, va).size == 0
    # OLS keeps the contiguous unstratified layout
    trains, vals = cv_fold_indices(y, 3, family=ols, stratify="auto")
    np.testing.assert_array_equal(vals[0], np.arange(8))


def test_cv_path_1se_selection():
    X, y, _ = make_regression(40, 30, k=4, rho=0.0, seed=13, noise=0.3)
    lam = np.asarray(bh_sequence(30, q=0.1))
    cv_min = cv_path(X, y, lam, ols, n_folds=4, path_length=15,
                     solver_tol=1e-9, max_iter=5000)
    cv_1se = cv_path(X, y, lam, ols, n_folds=4, path_length=15,
                     solver_tol=1e-9, max_iter=5000, selection="1se")
    assert cv_min.selection == "min" and cv_1se.selection == "1se"
    np.testing.assert_array_equal(cv_min.val_deviance, cv_1se.val_deviance)
    assert cv_1se.best_index == cv_1se.best_index_1se
    # 1-SE picks the sparser side (larger σ = smaller index) within 1 SE
    assert cv_1se.best_index_1se <= cv_1se.best_index_min
    mean, se = cv_1se.mean_val_deviance, cv_1se.se_val_deviance
    assert mean[cv_1se.best_index_1se] <= (mean[cv_1se.best_index_min]
                                           + se[cv_1se.best_index_min])


def test_cv_stratified_logistic_runs():
    X, y, _ = make_classification(36, 20, k=3, rho=0.1, seed=14)
    lam = np.asarray(bh_sequence(20, q=0.1))
    cv = cv_path(X, y, lam, logistic, n_folds=3, path_length=8,
                 solver_tol=1e-9, max_iter=5000)
    assert cv.val_deviance.shape == (3, 8)
    assert np.isfinite(cv.val_deviance).all()


def test_service_cv_matches_cv_path(shared_cache):
    """A cv_folds request served fold-by-fold through the batcher must
    reproduce cv_path(pad='bucket') exactly: same fold splits, same held-out
    deviances (bit-identical), same min/1-SE selection."""
    X, y, _ = make_regression(30, 24, k=4, rho=0.0, seed=15, noise=0.3)
    lam = np.asarray(bh_sequence(24, q=0.1))
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, lam=lam, cv_folds=3, selection="1se", **SVC_KW)
    assert svc.poll(rid) is None            # folds still queued
    resp = svc.poll(rid, flush=True)
    assert resp is not None
    ref = cv_path(X, y, lam, ols, n_folds=3, pad="bucket", selection="1se",
                  **KW)
    np.testing.assert_array_equal(resp.val_deviance, ref.val_deviance)
    assert resp.best_index == ref.best_index
    assert resp.best_index_min == ref.best_index_min
    assert resp.best_index_1se == ref.best_index_1se
    assert resp.best_sigma == ref.best_sigma
    assert len(resp.fold_responses) == 3
    for fold in resp.fold_responses:
        assert fold.kkt_ok


def test_service_cv_survives_mid_submission_flush(shared_cache):
    """Regression: the K-th fold submit can FILL the group and flush it
    synchronously, before _submit_cv finishes — fold responses must still
    route to the CV aggregation, not leak into the plain-results table."""
    X, y, _ = make_regression(30, 24, k=4, rho=0.0, seed=18, noise=0.3)
    lam = np.asarray(bh_sequence(24, q=0.1))
    svc = PathService(max_batch=3, max_delay=1000.0, cache=shared_cache)
    rid = svc.submit(X, y, lam=lam, cv_folds=3, **SVC_KW)
    assert svc.stats()["flush_fill"] == 1   # folds filled the group inline
    resp = svc.poll(rid)                    # no force flush needed
    assert resp is not None
    assert resp.val_deviance.shape == (3, 6)
    assert len(resp.fold_responses) == 3


def test_response_path_result_view(shared_cache):
    X, y, lam = _problem(20, 24, seed=16)
    svc = _svc(shared_cache)
    rid = svc.submit(X, y, lam=lam, **SVC_KW)
    resp = svc.poll(rid, flush=True)
    pr = resp.path_result(early_stop=False)
    np.testing.assert_array_equal(pr.betas, resp.betas)
    assert len(pr.steps) == 6
    assert pr.total_violations == resp.total_violations


# ---------------------------------------------------------------------------
# compare_sweeps --bench: clean first-run summary (CI satellite)
# ---------------------------------------------------------------------------

def test_compare_sweeps_bench_no_previous(tmp_path, capsys):
    import json

    from benchmarks.compare_sweeps import main_bench

    new = tmp_path / "BENCH_ci.json"
    new.write_text(json.dumps([{"name": "serve/x", "us_per_call": 12.5,
                                "derived": "rps=1"}]))
    rc = main_bench(str(tmp_path / "missing.json"), str(new))
    out = capsys.readouterr().out
    assert rc == 0
    assert "No previous artifact" in out and "serve/x" in out
    # corrupt previous artifact: same clean path
    prev = tmp_path / "prev.json"
    prev.write_text("{not json")
    rc = main_bench(str(prev), str(new))
    assert rc == 0
    assert "baseline recorded" in capsys.readouterr().out
    # healthy diff still works and flags new rows
    prev.write_text(json.dumps([{"name": "serve/x", "us_per_call": 10.0}]))
    rc = main_bench(str(prev), str(new))
    out = capsys.readouterr().out
    assert rc == 0 and "+25%" in out
