"""Screening: Algorithm 1/2 oracles, the cumsum-argmax closed form, the
strong rule (Propositions 1–3)."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: fall back to seeded random fuzzing
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    algorithm_1_oracle,
    algorithm_2_oracle,
    bh_sequence,
    fista,
    ols,
    screen_k,
    strong_rule,
    support_superset_k,
)
from repro.data import make_regression


@st.composite
def screen_case(draw):
    """Inputs on a dyadic grid (multiples of 1/64): every partial sum is
    exact in f64 AND f32, so sequential (Algorithm 2) and parallel-prefix
    (jnp.cumsum) summation agree bit-for-bit — the equivalence claim is
    about the algorithm, not about float association order on exact ties."""
    p = draw(st.integers(1, 80))
    c = draw(st.lists(st.integers(-320, 320), min_size=p, max_size=p))
    raw = draw(st.lists(st.integers(0, 256), min_size=p, max_size=p))
    lam = np.sort(np.asarray(raw, np.float64))[::-1] / 64.0
    return np.asarray(c, np.float64) / 64.0, lam


@settings(max_examples=300, deadline=None)
@given(screen_case())
def test_closed_form_equals_algorithm_2(case):
    """DESIGN.md §1: k = rightmost argmax of cumsum(c−λ) when max ≥ 0."""
    c, lam = case
    k_oracle = algorithm_2_oracle(c, lam)
    # pad to one fixed jit shape (a MASKED_NEG tail can never host the
    # rightmost argmax), or every drawn size costs a fresh compile
    from repro.core.screening import MASKED_NEG

    pad = 80 - len(c)
    cp = np.concatenate([c, np.full(pad, MASKED_NEG)])
    lamp = np.concatenate([lam, np.zeros(pad)])
    k_fast = int(screen_k(jnp.asarray(cp), jnp.asarray(lamp)))
    assert k_oracle == k_fast


@settings(max_examples=150, deadline=None)
@given(screen_case())
def test_algorithm_1_is_prefix_of_size_k(case):
    c, lam = case
    S = algorithm_1_oracle(c, lam)
    k = algorithm_2_oracle(c, lam)
    assert S == set(range(k))


def test_proposition_3_lasso_equivalence(rng):
    """Constant λ ⇒ strong rule for SLOPE == strong rule for the lasso."""
    # sizes from a fixed palette (one jit shape each), not free-form random
    for trial in range(60):
        p = (2, 3, 5, 13, 31, 59)[trial % 6]
        grad = rng.normal(size=p) * 2
        lam_prev = np.full(p, 1.5)
        lam_next = np.full(p, 1.2)
        k, order = strong_rule(jnp.asarray(grad), jnp.asarray(lam_prev),
                               jnp.asarray(lam_next))
        slope_set = set(np.asarray(order[: int(k)]).tolist())
        # lasso strong rule: keep j iff |g_j| > 2λ_next − λ_prev
        lasso_set = set(np.nonzero(np.abs(grad) >= 2 * 1.2 - 1.5)[0].tolist())
        assert slope_set == lasso_set, (slope_set, lasso_set)


def test_proposition_1_superset_at_solution(rng):
    """Algorithm 1 with the *true* gradient certifies a support superset."""
    n, p = 60, 150
    X, y, _ = make_regression(n, p, k=10, rho=0.3, seed=3)
    lam_base = np.asarray(bh_sequence(p, q=0.1))
    for sigma in (3.0, 1.0, 0.5):
        lam = sigma * lam_base
        res = fista(jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam),
                    jnp.zeros(p), ols, max_iter=20000, tol=1e-14)
        beta = np.asarray(res.beta)
        grad = X.T @ (X @ beta - y)
        k, order = support_superset_k(jnp.asarray(grad), jnp.asarray(lam), tol=1e-7)
        kept = set(np.asarray(order[: int(k)]).tolist())
        active = set(np.nonzero(np.abs(beta) > 1e-10)[0].tolist())
        assert active <= kept, (sorted(active - kept), int(k), len(active))


def test_strong_rule_screens_most_predictors(rng):
    """p ≫ n: the screened set should be a small fraction of p (paper §3.2.1)."""
    n, p = 50, 2000
    X, y, _ = make_regression(n, p, k=5, rho=0.0, seed=0)
    lam = np.asarray(bh_sequence(p, q=0.01))
    grad0 = X.T @ (X @ np.zeros(p) - y)
    from repro.core import path_start_sigma

    s1 = float(path_start_sigma(jnp.asarray(grad0), jnp.asarray(lam)))
    k, order = strong_rule(jnp.asarray(grad0), jnp.asarray(s1 * lam),
                           jnp.asarray(0.9 * s1 * lam))
    assert int(k) < p // 10
