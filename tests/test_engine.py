"""Device-resident batched path engine vs the host driver.

The contract under test (ISSUE 1): ``fit_path_batched`` over B independent
problems agrees with per-problem ``fit_path`` — same betas within solver
tolerance, same violation counts — and the masked screening scan equals the
paper's Algorithm 2 run on the unmasked prefix alone.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal image: fall back to seeded random fuzzing
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    algorithm_2_oracle,
    bh_sequence,
    cv_path,
    fit_path,
    fit_path_batched,
    get_family,
    ols,
    screen_masked,
)
from repro.data import make_multinomial, make_regression

# tight solves, default-width KKT guard: the violation check must sit well
# clear of fp noise so host and device flag identical sets
KW = dict(path_length=10, solver_tol=1e-12, max_iter=30000, kkt_tol=1e-4)


def _batch_problems(B, n, p, *, k=5, rho=0.2, noise=1.0):
    probs = [make_regression(n, p, k=k, rho=rho, seed=s, noise=noise)[:2]
             for s in range(B)]
    return np.stack([X for X, _ in probs]), np.stack([y for _, y in probs])


@pytest.mark.parametrize("screening", ["strong", "previous", "none"])
def test_batched_agrees_with_fit_path(screening):
    B, n, p = 3, 40, 60
    Xs, ys = _batch_problems(B, n, p)
    lam = np.asarray(bh_sequence(p, q=0.1))
    batched = fit_path_batched(Xs, ys, lam, ols, screening=screening, **KW)
    assert not batched.kkt_unrepaired.any()  # repair loop always finished
    for b in range(B):
        single = fit_path(Xs[b], ys[b], lam, ols, screening=screening,
                          engine="host", early_stop=False, **KW)
        np.testing.assert_allclose(batched.betas[b], single.betas, atol=5e-3)
        assert int(batched.total_violations[b]) == single.total_violations
        # screened/active sets may flip by a coefficient sitting exactly at
        # the zero boundary between two tol-accurate solutions
        np.testing.assert_allclose(
            batched.n_screened[b], [s.n_screened for s in single.steps], atol=2)
        np.testing.assert_allclose(
            batched.n_active[b], [s.n_active for s in single.steps], atol=2)


def test_device_engine_matches_host_single_problem():
    """fit_path(engine='device') is a drop-in for the host backend."""
    n, p = 40, 60
    X, y, _ = make_regression(n, p, k=5, rho=0.3, seed=9)
    lam = np.asarray(bh_sequence(p, q=0.1))
    host = fit_path(X, y, lam, ols, engine="host", early_stop=False, **KW)
    dev = fit_path(X, y, lam, ols, engine="device", early_stop=False, **KW)
    np.testing.assert_allclose(host.betas, dev.betas, atol=5e-3)
    assert host.total_violations == dev.total_violations
    assert len(host.steps) == len(dev.steps)
    for hs, ds in zip(host.steps, dev.steps):
        assert abs(hs.n_screened - ds.n_screened) <= 2
        assert abs(hs.n_active - ds.n_active) <= 2


def test_device_engine_early_stop_truncates_like_host():
    n, p = 25, 50
    X, y, _ = make_regression(n, p, k=20, rho=0.0, seed=5, noise=0.01)
    lam = np.ones(p)
    r = fit_path(X, y, lam, ols, engine="device", path_length=100,
                 solver_tol=1e-10, max_iter=5000)
    assert len(r.sigmas) < 100  # saturation rules applied post-hoc


def test_multinomial_engine_agrees_with_host():
    """Engine-vs-host on the multinomial family: the (p, m) mask-broadcast
    logic in fista_masked/_engine (every class column of a screened
    predictor shares the mask row) must reproduce the host driver's
    gathered sub-problems, violations included."""
    B, n, p, m = 2, 30, 36, 3
    probs = [make_multinomial(n, p, k=4, m=m, rho=0.2, seed=s)[:2]
             for s in range(B)]
    Xs = np.stack([X for X, _ in probs])
    ys = np.stack([y for _, y in probs])
    fam = get_family("multinomial", m)
    lam = np.asarray(bh_sequence(p * m, q=0.1))
    kw = dict(path_length=8, solver_tol=1e-11, max_iter=20000, kkt_tol=1e-4)
    batched = fit_path_batched(Xs, ys, lam, fam, screening="strong", **kw)
    assert not batched.kkt_unrepaired.any()
    for b in range(B):
        single = fit_path(Xs[b], ys[b], lam, fam, screening="strong",
                          engine="host", early_stop=False, **kw)
        assert single.betas.shape == (8, p, m)
        np.testing.assert_allclose(batched.betas[b], single.betas, atol=5e-3)
        assert int(batched.total_violations[b]) == single.total_violations
        np.testing.assert_allclose(
            batched.n_screened[b], [s.n_screened for s in single.steps], atol=2)
        np.testing.assert_allclose(
            batched.n_active[b], [s.n_active for s in single.steps], atol=2)


# ---------------------------------------------------------------------------
# compact working-set engine (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------

def test_compact_engine_matches_masked():
    """With W above the peak working set the compact engine must follow the
    masked engine step for step — same betas, same violation accounting —
    while solving at (n, W) instead of (n, p)."""
    B, n, p = 3, 40, 96
    Xs, ys = _batch_problems(B, n, p)
    lam = np.asarray(bh_sequence(p, q=0.1))
    masked = fit_path_batched(Xs, ys, lam, ols, **KW)
    compact = fit_path_batched(Xs, ys, lam, ols, working_set=64, **KW)
    assert compact.working_set == 64
    assert compact.ws_size is not None and compact.ws_size.max() > 0
    np.testing.assert_allclose(compact.betas, masked.betas, atol=1e-8)
    np.testing.assert_array_equal(compact.n_violations, masked.n_violations)
    np.testing.assert_array_equal(compact.n_screened, masked.n_screened)
    # every non-fallback step honoured the bucket
    honored = ~compact.compact_fallback
    assert (compact.ws_size[honored] <= 64).all()


def test_compact_engine_overflow_falls_back():
    """A bucket below the peak working set must flip the scalar lax.cond to
    the masked full-width solve — flagged per step, results identical."""
    B, n, p = 3, 40, 96
    Xs, ys = _batch_problems(B, n, p)
    lam = np.asarray(bh_sequence(p, q=0.1))
    masked = fit_path_batched(Xs, ys, lam, ols, **KW)
    over = fit_path_batched(Xs, ys, lam, ols, working_set=4, **KW)
    assert over.compact_fallback.any()  # overflow demonstrably happened
    np.testing.assert_allclose(over.betas, masked.betas, atol=1e-8)
    np.testing.assert_array_equal(over.n_violations, masked.n_violations)
    # overflow recorded the true demand so the bucket cache can grow
    assert over.ws_size.max() > 4


# ---------------------------------------------------------------------------
# two-tier working sets (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def test_resolve_ws_tiers_recipe():
    """The ONE tier recipe: 2W second tier when it fits under p, single
    tier when pinned or when 2W would span p (the masked fallback IS the
    top tier there)."""
    from repro.core.engine import _WS_BUCKETS, resolve_ws_tiers

    key = ("tier-recipe-test",)
    _WS_BUCKETS.pop(key, None)
    assert resolve_ws_tiers(16, "auto", 40, 256, key) == (16, 32)
    assert resolve_ws_tiers(16, 2, 40, 256, key) == (16, 32)
    assert resolve_ws_tiers(16, 1, 40, 256, key) == (16, None)
    # 2W ≥ p degenerates to single tier under every policy
    assert resolve_ws_tiers(16, "auto", 40, 32, key) == (16, None)
    assert resolve_ws_tiers(16, 2, 40, 32, key) == (16, None)
    with pytest.raises(ValueError):
        resolve_ws_tiers(16, 3, 40, 256, key)
    with pytest.raises(ValueError):
        resolve_ws_tiers(16, "both", 40, 256, key)


def test_two_tier_per_member_promotion_and_fallback_cut():
    """The two-tier contract on one p ≫ n batch, single vs two tier:

    * a member whose screened set outgrows W (but fits 2W) is served at
      tier 2 while another member of the SAME step stays at tier 1;
    * steps whose peak demand lands in (W, 2W] stop falling back, so the
      two-tier fallback count is strictly below the single-tier one;
    * both engines match the masked solve, violations included.
    """
    from repro.core.engine import _fit_path_batched

    B, n, p = 4, 40, 256
    probs = [make_regression(n, p, k=5, rho=0.0, seed=s, noise=0.3)[:2]
             for s in range(B)]
    Xs = np.stack([X for X, _ in probs])
    ys = np.stack([y for _, y in probs])
    lam = np.asarray(bh_sequence(p, q=0.05))
    kw = dict(path_length=20, solver_tol=1e-12, max_iter=30000,
              kkt_tol=1e-4, sigma_ratio=0.5)
    masked = _fit_path_batched(Xs, ys, lam, ols, **kw)
    single = _fit_path_batched(Xs, ys, lam, ols, working_set=8, ws_tiers=1,
                               **kw)
    two = _fit_path_batched(Xs, ys, lam, ols, working_set=8,
                            ws_tiers="auto", **kw)
    assert (two.working_set, two.working_set_top) == (8, 16)
    assert single.working_set_top is None
    fb_single = int(single.compact_fallback.any(axis=0).sum())
    fb_two = int(two.compact_fallback.any(axis=0).sum())
    assert fb_single > fb_two  # the second tier absorbed real steps
    np.testing.assert_allclose(single.betas, masked.betas, atol=1e-9)
    np.testing.assert_allclose(two.betas, masked.betas, atol=1e-9)
    np.testing.assert_array_equal(two.n_violations, masked.n_violations)
    # some step promoted only part of the batch: one member runs at tier 2
    # while another member of the same step is served at tier 1
    mixed = (two.ws_tier == 2).any(axis=0) & (two.ws_tier == 1).any(axis=0)
    assert mixed.any()
    # tier accounting is consistent with demand: tier-1 steps fit W,
    # tier-2 steps need (W, 2W], fallback (tier 0) only past the top tier
    assert (two.ws_size[two.ws_tier == 1] <= 8).all()
    assert (two.ws_size[two.ws_tier == 2] <= 16).all()
    assert (two.ws_size[two.ws_tier == 2] > 8).all()
    fb_cols = two.compact_fallback.any(axis=0)
    assert ((two.ws_tier == 0) == fb_cols[None, :].repeat(B, axis=0)).all()
    assert (two.ws_size.max(axis=0)[fb_cols] > 16).all()


def test_two_tier_overflow_past_top_falls_back_whole_batch():
    """Demand beyond the top tier still sends the WHOLE batch to the
    masked solve — flagged in CompactStats.fell_back / tier 0 — and the
    forced per-member overflow reproduces the masked results."""
    from repro.core.engine import _fit_path_batched

    B, n, p = 3, 40, 96
    Xs, ys = _batch_problems(B, n, p)
    lam = np.asarray(bh_sequence(p, q=0.1))
    masked = fit_path_batched(Xs, ys, lam, ols, **KW)
    over = _fit_path_batched(Xs, ys, lam, ols, working_set=2, ws_tiers=2,
                             **KW)
    assert (over.working_set, over.working_set_top) == (2, 4)
    assert over.compact_fallback.any()
    # fallback steps are tier 0 for every member (the fallback is batch-
    # wide by construction — the scalar gate is what keeps it a real branch)
    fb = over.compact_fallback.any(axis=0)
    assert (over.ws_tier[:, fb] == 0).all()
    assert (over.ws_size.max(axis=0)[fb] > 4).all()
    # demand exceeds the top tier at EVERY fitted step here, so the whole
    # trajectory ran the masked solve — the forced per-member overflow is
    # BIT-identical to the masked engine, not merely tolerance-close
    assert over.compact_fallback[:, 1:].all()
    np.testing.assert_array_equal(over.betas, masked.betas)
    np.testing.assert_array_equal(over.n_violations, masked.n_violations)


def test_compact_engine_multinomial():
    """Compact gather/scatter through the (p, m) coefficient block."""
    B, n, p, m = 2, 30, 40, 3
    probs = [make_multinomial(n, p, k=4, m=m, rho=0.2, seed=s)[:2]
             for s in range(B)]
    Xs = np.stack([X for X, _ in probs])
    ys = np.stack([y for _, y in probs])
    fam = get_family("multinomial", m)
    lam = np.asarray(bh_sequence(p * m, q=0.1))
    kw = dict(path_length=6, solver_tol=1e-10, max_iter=10000)
    masked = fit_path_batched(Xs, ys, lam, fam, **kw)
    compact = fit_path_batched(Xs, ys, lam, fam, working_set=16, **kw)
    np.testing.assert_allclose(compact.betas, masked.betas, atol=1e-7)


def test_compact_auto_bucket_grows_on_overflow():
    """working_set='auto' starts at min(2^⌈log₂ max(2n, 64)⌉, p); an
    overflowing auto run writes the grown bucket to the cache and the next
    same-shape auto call picks it up.  Explicit-int runs never touch the
    cache (an undersized overflow probe must not shrink auto's default)."""
    from repro.core.engine import _WS_BUCKETS, _ws_bucket

    B, n, p = 2, 20, 256
    # dense signal + a σ grid deep enough that screening keeps ≥ p/2 and
    # the engine widens E to full-p: guaranteed overflow of the 64 bucket
    probs = [make_regression(n, p, k=20, rho=0.3, seed=s, noise=0.05)[:2]
             for s in range(B)]
    Xs = np.stack([X for X, _ in probs])
    ys = np.stack([y for _, y in probs])
    lam = np.asarray(bh_sequence(p, q=0.1))
    key = (n, p, 1, "ols", "strong")
    _WS_BUCKETS.pop(key, None)
    assert _ws_bucket("auto", n, p, key) == 64  # 2^⌈log₂ max(40, 64)⌉
    kw = dict(path_length=12, solver_tol=1e-9, max_iter=5000)
    res = fit_path_batched(Xs, ys, lam, ols, working_set="auto", **kw)
    assert res.working_set == 64
    assert res.compact_fallback.any()          # the 64 bucket overflowed
    grown = _WS_BUCKETS[key]                   # ... and the cache grew
    assert grown > 64
    assert grown == min(2 ** (int(res.ws_size.max()) - 1).bit_length(), p)
    # the next same-shape auto call starts from the grown bucket
    res2 = fit_path_batched(Xs, ys, lam, ols, working_set="auto", **kw)
    assert res2.working_set == grown
    np.testing.assert_allclose(res2.betas, res.betas, atol=1e-8)
    # explicit ints are pow-2 bucketed, capped at p, and never write the cache
    _WS_BUCKETS.pop(key, None)
    fit_path_batched(Xs, ys, lam, ols, working_set=4, **kw)
    assert key not in _WS_BUCKETS
    assert _ws_bucket(48, n, p, key) == 64
    assert _ws_bucket(1024, n, p, key) == p


def test_batched_multinomial_runs():
    B, n, p, m = 3, 30, 40, 3
    probs = [make_multinomial(n, p, k=4, m=m, rho=0.2, seed=s)[:2]
             for s in range(B)]
    Xs = np.stack([X for X, _ in probs])
    ys = np.stack([y for _, y in probs])
    fam = get_family("multinomial", m)
    lam = np.asarray(bh_sequence(p * m, q=0.1))
    res = fit_path_batched(Xs, ys, lam, fam, path_length=6,
                           solver_tol=1e-9, max_iter=5000)
    assert res.betas.shape == (B, 6, p, m)
    assert np.isfinite(res.betas).all()


def test_batched_path_results_views():
    B, n, p = 3, 30, 40
    Xs, ys = _batch_problems(B, n, p)
    lam = np.asarray(bh_sequence(p, q=0.1))
    res = fit_path_batched(Xs, ys, lam, ols, path_length=8,
                           solver_tol=1e-9, max_iter=5000)
    paths = res.path_results(early_stop=False)
    assert len(paths) == B
    for b, pr in enumerate(paths):
        np.testing.assert_array_equal(pr.betas, res.betas[b])
        assert len(pr.steps) == 8
        assert pr.total_violations == int(res.total_violations[b])
    # the default view applies the early-stopping rules post-hoc
    for pr in res.path_results():
        assert 1 <= len(pr.steps) <= 8


def test_cv_path_selects_signal_recovering_sigma():
    n, p = 60, 50
    X, y, _ = make_regression(n, p, k=4, rho=0.0, seed=2, noise=0.3)
    lam = np.asarray(bh_sequence(p, q=0.1))
    cv = cv_path(X, y, lam, ols, n_folds=4, path_length=25,
                 solver_tol=1e-9, max_iter=5000)
    assert cv.val_deviance.shape == (4, 25)
    assert np.isfinite(cv.mean_val_deviance).all()
    # with real signal, some amount of fitting must beat the null model
    assert cv.best_index > 0
    assert cv.mean_val_deviance[cv.best_index] < cv.mean_val_deviance[0]


# ---------------------------------------------------------------------------
# screen_masked == Algorithm 2 on the unmasked prefix (satellite property)
# ---------------------------------------------------------------------------

@st.composite
def masked_screen_case(draw):
    """Dyadic-grid inputs (exact in f64) plus a random mask."""
    p = draw(st.integers(1, 60))
    c = draw(st.lists(st.integers(-320, 320), min_size=p, max_size=p))
    raw = draw(st.lists(st.integers(0, 256), min_size=p, max_size=p))
    keep = draw(st.lists(st.integers(0, 1), min_size=p, max_size=p))
    lam = np.sort(np.asarray(raw, np.float64))[::-1] / 64.0
    return (np.asarray(c, np.float64) / 64.0, lam,
            np.asarray(keep, bool))


@settings(max_examples=200, deadline=None)
@given(masked_screen_case())
def test_screen_masked_equals_oracle_on_unmasked_prefix(case):
    c, lam, mask = case
    p = len(c)
    # pad to one fixed jit shape; padded entries are masked out, which is
    # exactly the property under test
    pad = 60 - p
    cp = jnp.asarray(np.concatenate([c, np.zeros(pad)]))
    lamp = jnp.asarray(np.concatenate([lam, np.zeros(pad)]))
    maskp = jnp.asarray(np.concatenate([mask, np.zeros(pad, bool)]))
    keep, k = screen_masked(cp, lamp, maskp, jnp.zeros_like(cp))
    keep = np.asarray(keep)[:p]
    k = int(k)
    # oracle: run Algorithm 2 on the unmasked entries alone (sorted), with
    # the leading λ entries — masking must be exactly problem truncation
    sub = np.sort(c[mask])[::-1]
    k_oracle = algorithm_2_oracle(sub, lam[: len(sub)])
    assert k == k_oracle
    assert keep.sum() == k
    assert not keep[~mask].any()
    # kept set = k largest unmasked magnitudes
    if k:
        kept_vals = np.sort(c[keep])[::-1]
        np.testing.assert_array_equal(kept_vals, sub[:k])
