"""FISTA solver + path drivers: optimality, screening-invariance, stopping."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    bh_sequence,
    fista,
    fista_compact,
    fista_masked,
    fit_path,
    get_family,
    kkt_optimal,
    lasso_sequence,
    ols,
    prox_sorted_l1,
    sorted_l1_norm,
)
from repro.data import (
    make_classification,
    make_multinomial,
    make_poisson,
    make_regression,
)


def test_fista_orthonormal_closed_form(rng):
    """X orthonormal ⇒ β̂ = prox(Xᵀy; λ) exactly."""
    n, p = 60, 40
    Q, _ = np.linalg.qr(rng.normal(size=(n, p)))
    X = Q
    y = rng.normal(size=n)
    lam = np.sort(np.abs(rng.normal(size=p)))[::-1] * 0.5
    res = fista(jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam),
                jnp.zeros(p), ols, max_iter=20000, tol=1e-15)
    want = np.asarray(prox_sorted_l1(jnp.asarray(X.T @ y), jnp.asarray(lam)))
    np.testing.assert_allclose(np.asarray(res.beta), want, atol=1e-7)


@pytest.mark.parametrize("family_name,maker", [
    ("ols", make_regression),
    ("logistic", make_classification),
    ("poisson", make_poisson),
])
def test_fista_kkt_optimal(family_name, maker):
    n, p = 80, 60
    X, y, _ = maker(n, p, k=5, rho=0.2, seed=1)
    fam = get_family(family_name)
    lam = np.asarray(bh_sequence(p, q=0.2)) * (2.0 if family_name != "poisson" else 5.0)
    res = fista(jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam),
                jnp.zeros(p), fam, max_iter=30000, tol=1e-15)
    beta = np.asarray(res.beta)
    grad = np.asarray(fam.gradient(jnp.asarray(X), jnp.asarray(y), jnp.asarray(beta)))
    assert kkt_optimal(grad, beta, lam, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("screening", ["strong", "previous"])
def test_path_screening_invariance(screening):
    """Screened and unscreened paths reach the same objectives."""
    n, p = 50, 200
    X, y, _ = make_regression(n, p, k=8, rho=0.3, seed=7)
    lam = np.asarray(bh_sequence(p, q=0.1))
    # kkt_tol bounds how far a guarded-but-accepted solution may sit from
    # the unscreened optimum — tighten it to make invariance testable
    kw = dict(path_length=20, solver_tol=1e-12, max_iter=20000, kkt_tol=1e-7)
    r_scr = fit_path(X, y, lam, ols, screening=screening, **kw)
    r_ref = fit_path(X, y, lam, ols, screening="none", **kw)
    # early stopping can trigger one step apart at fp noise of the threshold
    assert abs(len(r_scr.steps) - len(r_ref.steps)) <= 1
    for i, (s1, s2) in enumerate(zip(r_scr.steps, r_ref.steps)):
        o1 = s1.deviance + float(sorted_l1_norm(jnp.asarray(r_scr.betas[i]),
                                                jnp.asarray(s1.sigma * lam)))
        o2 = s2.deviance + float(sorted_l1_norm(jnp.asarray(r_ref.betas[i]),
                                                jnp.asarray(s2.sigma * lam)))
        assert abs(o1 - o2) <= 1e-5 * max(1.0, abs(o2)), (i, o1, o2)
    L = min(len(r_scr.betas), len(r_ref.betas))
    np.testing.assert_allclose(r_scr.betas[:L], r_ref.betas[:L], atol=2e-3)


def test_path_multinomial_runs():
    n, p, m = 40, 60, 3
    X, y, _ = make_multinomial(n, p, k=5, m=m, rho=0.2, seed=2)
    fam = get_family("multinomial", m)
    lam = np.asarray(bh_sequence(p * m, q=0.1))
    r = fit_path(X, y, lam, fam, screening="strong", path_length=8,
                 solver_tol=1e-9, max_iter=4000)
    assert r.betas.shape[1:] == (p, m)
    assert np.isfinite(r.betas).all()


def test_path_screened_set_contains_active():
    n, p = 50, 400
    X, y, _ = make_regression(n, p, k=6, rho=0.0, seed=11)
    lam = np.asarray(bh_sequence(p, q=0.05))
    r = fit_path(X, y, lam, ols, screening="strong", path_length=15,
                 solver_tol=1e-11, max_iter=10000)
    # efficiency ≥ 1 whenever anything is active and no violation occurred
    for s in r.steps[1:]:
        if s.n_active and not s.n_violations:
            assert s.n_screened + 1e-9 >= 0  # screened count recorded
    assert r.total_violations <= 2  # rare by Fig. 3


@pytest.mark.parametrize("family_name,m", [("ols", 1), ("multinomial", 3)])
def test_fista_masked_zero_invariant(family_name, m, rng):
    """Masked coordinates come back EXACTLY 0 with no exit re-mask: zeroed
    columns have identically-zero gradient and the sorted-ℓ1 prox preserves
    exact zeros, so the solver never perturbs them (the re-mask this
    replaces was a redundant (p, m) multiply per solve)."""
    n, p = 40, 80
    if family_name == "ols":
        X, y, _ = make_regression(n, p, k=5, rho=0.3, seed=3)
    else:
        X, y, _ = make_multinomial(n, p, k=5, m=m, rho=0.3, seed=3)
    fam = get_family(family_name, m)
    # weak penalty so the unmasked columns actually activate
    lam = np.asarray(bh_sequence(p * m, q=0.1)) * 0.05
    mask = rng.random(p) < 0.15
    mask[0] = True  # keep the working set non-empty
    beta0 = np.zeros(p) if m == 1 else np.zeros((p, m))
    res = fista_masked(jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam),
                       jnp.asarray(beta0), jnp.asarray(mask), fam,
                       max_iter=5000, tol=1e-12)
    beta = np.asarray(res.beta)
    assert (beta[~mask] == 0.0).all()  # exact, not just small
    assert np.abs(beta[mask]).max() > 0  # the solve did something


def test_fista_compact_matches_masked(rng):
    """The compact (n, W) gather solve equals the masked full-width solve;
    padding columns beyond |mask| stay inert."""
    n, p, W = 40, 150, 16
    X, y, _ = make_regression(n, p, k=5, rho=0.2, seed=9)
    lam = np.asarray(bh_sequence(p, q=0.1)) * 1.5
    mask = np.zeros(p, bool)
    mask[rng.choice(p, size=9, replace=False)] = True
    args = (jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam),
            jnp.zeros(p), jnp.asarray(mask), ols)
    kw = dict(max_iter=20000, tol=1e-14)
    r_masked = fista_masked(*args, **kw)
    r_compact = fista_compact(*args, width=W, **kw)
    beta_c = np.asarray(r_compact.beta)
    assert beta_c.shape == (p,)
    assert (beta_c[~mask] == 0.0).all()
    np.testing.assert_allclose(beta_c, np.asarray(r_masked.beta), atol=1e-9)
    np.testing.assert_allclose(float(r_compact.objective),
                               float(r_masked.objective), rtol=1e-10)


def test_path_early_stop_on_saturation():
    n, p = 25, 50
    X, y, _ = make_regression(n, p, k=20, rho=0.0, seed=5, noise=0.01)
    lam = np.asarray(lasso_sequence(p)) * 1.0
    r = fit_path(X, y, lam, ols, screening="strong", path_length=100,
                 solver_tol=1e-10, max_iter=5000)
    assert len(r.sigmas) < 100  # stopped early (rules 1–3)
