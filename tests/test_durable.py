"""Crash-safe serving (ISSUE 10).

The contracts under test, in dependency order:

1. **Durable program store.**  A saved executable loads without compiling
   (``builds == 0`` on the warm path) and executes bit-identically to a
   freshly-built program; corrupt or fingerprint-mismatched entries are
   discarded — never trusted — and the caller rebuilds.
2. **Manifest replay.**  A second boot against the same store replays the
   warmup manifest and compiles ZERO programs before serving traffic.
3. **Checkpoint/restore.**  A service killed mid-chunk (checkpoint) and
   restored on a fresh process completes every captured request
   bit-identical to an uninterrupted run (maxdiff == 0).
4. **Watchdog.**  An injected ``kind="hang"`` past ``solve_timeout_ms``
   trips the watchdog; the cohort recovers through retry/bisection and
   every result stays bit-identical.
5. **Circuit breaker.**  K consecutive compile faults open the circuit
   (``Rejection(reason="circuit_open")``); after the cooldown a half-open
   probe closes it again.
6. **Load shedding.**  The shed verdict is a deterministic function of the
   latency window: lowest-priority deadline-carrying admissions shed,
   higher priorities and budget-less requests never.
"""

import os
import pickle

import numpy as np
import pytest

from repro.serve import (
    AsyncPathService,
    CircuitBreaker,
    DurableProgramStore,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    PathService,
    ProgramCache,
    Rejection,
    RejectionError,
    ServiceCheckpoint,
)
from repro.serve.cache import ProgramSpec
from repro.serve.durable import LoadShedGovernor, backend_fingerprint
from repro.core import ols

L = 6
C = 2
SVC_KW = dict(path_length=L, solver_tol=1e-10, max_iter=20000)


def _problem(n, p, seed=0, k=4):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[:k] = rng.normal(size=k) * 2.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    return X, y


PROBLEMS = [_problem(18 + 2 * i, 22 + i, seed=70 + i) for i in range(6)]


def _asvc(cache=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 0.005)
    kw.setdefault("step_chunk", C)
    return AsyncPathService(cache=cache, **kw)


def _result(fut, timeout=180):
    resp = fut.result(timeout=timeout)
    assert not isinstance(resp, Rejection), resp
    return resp


@pytest.fixture(scope="module")
def reference():
    """The uninterrupted run every crash scenario is compared against."""
    svc = _asvc(ProgramCache(capacity=16))
    try:
        futs = [svc.submit(X, y, **SVC_KW) for X, y in PROBLEMS]
        return [_result(f) for f in futs]
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# 1. durable store: skip-compile load, bitwise execution, integrity checks
# ---------------------------------------------------------------------------

def test_store_round_trip_skips_compile_bitwise(tmp_path):
    X, y = PROBLEMS[0]
    store = DurableProgramStore(tmp_path / "store")
    svc = _asvc(store=store)
    try:
        cold = _result(svc.submit(X, y, **SVC_KW))
        cold_stats = svc.stats()["cache"]
    finally:
        svc.close()
    if not store.serializable:
        pytest.skip("executable serialization unavailable on this backend")
    assert cold_stats["builds"] == cold_stats["misses"] > 0
    assert store.stats()["saved"] == cold_stats["builds"]

    # fresh cache, same store: loads, zero compiles, bitwise-equal result
    svc2 = _asvc(store=DurableProgramStore(tmp_path / "store"))
    try:
        warm = _result(svc2.submit(X, y, **SVC_KW))
        warm_stats = svc2.stats()["cache"]
    finally:
        svc2.close()
    assert warm_stats["builds"] == 0
    assert warm_stats["store"]["loaded"] > 0
    np.testing.assert_array_equal(cold.betas, warm.betas)
    np.testing.assert_array_equal(cold.deviance, warm.deviance)


def test_store_discards_corrupt_and_mismatched_entries(tmp_path):
    X, y = PROBLEMS[1]
    store = DurableProgramStore(tmp_path / "store")
    svc = _asvc(store=store)
    try:
        ref = _result(svc.submit(X, y, **SVC_KW))
    finally:
        svc.close()
    if not store.serializable:
        pytest.skip("executable serialization unavailable on this backend")
    entries = [f for f in os.listdir(store.path) if f.endswith(".prog")]
    assert entries

    # corrupt one entry's payload bytes; tamper another's fingerprint
    first = os.path.join(store.path, entries[0])
    with open(first, "rb") as fh:
        entry = pickle.load(fh)
    entry["payload"] = b"garbage" + entry["payload"][7:]
    with open(first, "wb") as fh:
        pickle.dump(entry, fh)
    if len(entries) > 1:
        second = os.path.join(store.path, entries[1])
        with open(second, "rb") as fh:
            entry2 = pickle.load(fh)
        entry2["fingerprint"] = "jax=0.0.0|jaxlib=0.0.0|backend=nope"
        with open(second, "wb") as fh:
            pickle.dump(entry2, fh)

    store2 = DurableProgramStore(tmp_path / "store")
    svc2 = _asvc(store=store2)
    try:
        again = _result(svc2.submit(X, y, **SVC_KW))
        cache_stats = svc2.stats()["cache"]
    finally:
        svc2.close()
    # tampered entries were discarded and rebuilt from source — the result
    # is still bitwise-correct and the store is repopulated
    assert store2.stats()["discarded"] >= 1
    assert cache_stats["builds"] >= 1
    np.testing.assert_array_equal(ref.betas, again.betas)


def test_store_load_rejects_unpicklable_garbage(tmp_path):
    store = DurableProgramStore(tmp_path / "store")
    if not store.serializable:
        pytest.skip("executable serialization unavailable on this backend")
    spec = ProgramSpec(family=ols, batch=1, n_rows=32, n_cols=32,
                       path_length=L, screening="strong", solver_tol=1e-10,
                       max_iter=200, kkt_tol=1e-4, max_refits=32,
                       dtype="float64", y_dtype="float64")
    target = store._entry_path(spec)
    with open(target, "wb") as fh:
        fh.write(b"\x00not a pickle at all")
    assert store.load(spec) is None
    assert store.stats()["discarded"] == 1
    assert not os.path.exists(target)


# ---------------------------------------------------------------------------
# 2. manifest replay: second boot compiles zero programs
# ---------------------------------------------------------------------------

def test_manifest_replay_second_boot_compiles_nothing(tmp_path):
    store = DurableProgramStore(tmp_path / "store")
    svc = _asvc(store=store)
    try:
        for X, y in PROBLEMS[:3]:
            _result(svc.submit(X, y, **SVC_KW))
    finally:
        svc.close()
    if not store.serializable:
        pytest.skip("executable serialization unavailable on this backend")
    manifest = store.manifest_specs()
    assert manifest  # live traffic recorded what it compiled

    # boot a fresh service: __init__ replays the manifest through the store
    store2 = DurableProgramStore(tmp_path / "store")
    svc2 = _asvc(store=store2)
    try:
        boot = svc2.stats()["cache"]
        assert boot["builds"] == 0          # zero XLA compiles at boot
        assert boot["misses"] == len(manifest)
        assert store2.stats()["loaded"] == len(manifest)
        assert store2.stats()["replayed"] == len(manifest)
        # traffic after boot is all cache hits — still zero compiles
        for X, y in PROBLEMS[:3]:
            _result(svc2.submit(X, y, **SVC_KW))
        assert svc2.stats()["cache"]["builds"] == 0
    finally:
        svc2.close()


def test_manifest_skips_undecodable_lines(tmp_path):
    store = DurableProgramStore(tmp_path / "store")
    with open(store._manifest_path, "w") as fh:
        fh.write("not json\n")
        fh.write('{"family": "martian"}\n')
        fh.write('{"family": "ols", "no_such_field": 1}\n')
        fh.write("[1, 2, 3]\n")
    assert store.manifest_specs() == []


# ---------------------------------------------------------------------------
# 3. checkpoint/restore: kill mid-chunk, restore, maxdiff == 0
# ---------------------------------------------------------------------------

def test_checkpoint_restore_bit_identical(reference):
    cache = ProgramCache(capacity=16)
    svc = _asvc(cache)
    futs = [svc.submit(X, y, **SVC_KW) for X, y in PROBLEMS]
    # checkpoint races the dispatcher: with 6 requests on 4 slots some are
    # typically mid-chunk and some still queued — both capture paths run
    ckpt = svc.checkpoint(timeout=180)
    undelivered = {f.rid for f in futs if not f.done()}
    assert {q.rid for q in ckpt.queued} | {s.rid for s in ckpt.inflight} \
        == undelivered
    assert ckpt.fingerprint == backend_fingerprint()
    assert svc.stats()["checkpoints"] == 1
    # the checkpointed process is abandoned (no close-flush: that would
    # serve the leftovers and defeat the point)

    results = {}
    for i, f in enumerate(futs):
        if f.done():
            results[i] = _result(f)
    rid_to_index = {f.rid: i for i, f in enumerate(futs)}
    svc2 = _asvc(cache)
    try:
        restored = svc2.restore(ckpt)
        assert set(restored) == undelivered
        for old_rid, fut in restored.items():
            results[rid_to_index[old_rid]] = _result(fut)
        assert svc2.stats()["restored"] == len(undelivered)
    finally:
        svc2.close()

    assert len(results) == len(PROBLEMS)
    for i, want in enumerate(reference):
        got = results[i]
        np.testing.assert_array_equal(got.betas, want.betas)
        np.testing.assert_array_equal(got.deviance, want.deviance)
        np.testing.assert_array_equal(got.sigmas, want.sigmas)


def test_checkpoint_pickles_through_disk(reference, tmp_path):
    cache = ProgramCache(capacity=16)
    svc = _asvc(cache)
    futs = [svc.submit(X, y, **SVC_KW) for X, y in PROBLEMS]
    ckpt = svc.checkpoint(timeout=180)
    ckpt.save(tmp_path / "svc.ckpt")
    loaded = ServiceCheckpoint.load(tmp_path / "svc.ckpt")
    assert len(loaded) == len(ckpt)

    results = {}
    for i, f in enumerate(futs):
        if f.done():
            results[i] = _result(f)
    rid_to_index = {f.rid: i for i, f in enumerate(futs)}
    svc2 = _asvc(cache)
    try:
        for old_rid, fut in svc2.restore(loaded).items():
            results[rid_to_index[old_rid]] = _result(fut)
    finally:
        svc2.close()
    for i, want in enumerate(reference):
        np.testing.assert_array_equal(results[i].betas, want.betas)


def test_restore_refuses_foreign_fingerprint():
    ckpt = ServiceCheckpoint(queued=[], inflight=[],
                             fingerprint="jax=0.0.0|jaxlib=0.0.0|backend=x")
    svc = _asvc(ProgramCache(capacity=4), autostart=False)
    try:
        with pytest.raises(RuntimeError, match="fingerprint"):
            svc.restore(ckpt)
    finally:
        svc.close(flush=False)


# ---------------------------------------------------------------------------
# 4. watchdog: a hung chunk fails only its cohort, recovery is bitwise
# ---------------------------------------------------------------------------

def test_watchdog_recovers_hung_cohort_bit_identical(reference):
    plan = FaultPlan([FaultSpec(site="worker", kind="hang", delay_s=3.0,
                                times=1)])
    svc = _asvc(ProgramCache(capacity=16), faults=plan,
                solve_timeout_ms=500.0, retry_backoff=0.001)
    try:
        futs = [svc.submit(X, y, **SVC_KW) for X, y in PROBLEMS]
        got = [_result(f) for f in futs]
        stats = svc.stats()
    finally:
        svc.close()
    # the hang tripped the watchdog (not the sleep) and retry recovered
    assert stats["watchdog_timeouts"] >= 1
    assert stats["retries"] >= 1
    assert stats["poisoned"] == 0
    assert stats["completed"] == len(PROBLEMS)
    for got_r, want in zip(got, reference):
        np.testing.assert_array_equal(got_r.betas, want.betas)
        np.testing.assert_array_equal(got_r.deviance, want.deviance)


def test_solve_timeout_validation():
    with pytest.raises(ValueError, match="solve_timeout_ms"):
        PathService(solve_timeout_ms=0.0)
    svc = PathService()
    X, y = PROBLEMS[0]
    with pytest.raises(ValueError, match="solve_timeout_ms"):
        svc.submit(X, y, solve_timeout_ms=-5.0, **SVC_KW)


# ---------------------------------------------------------------------------
# 5. circuit breaker: open -> reject -> half-open probe -> closed
# ---------------------------------------------------------------------------

def test_breaker_opens_after_consecutive_faults_and_recloses():
    t = [0.0]

    def clock():
        t[0] += 1e-4
        return t[0]

    plan = FaultPlan([FaultSpec(site="compile", kind="error", times=3)])
    svc = PathService(max_batch=1, max_delay=0.0, faults=plan, clock=clock,
                      breaker_threshold=3, breaker_cooldown=10.0)
    X, y = PROBLEMS[0]
    for _ in range(3):
        # max_batch=1: admission fill-flushes synchronously, so the
        # injected compile fault surfaces from submit itself
        with pytest.raises(InjectedFault):
            svc.submit(X, y, **SVC_KW)
    assert svc.stats()["breaker"]["open"] == 1
    assert svc.stats()["breaker"]["opens"] == 1

    # open: admission rejected with the structured verdict
    with pytest.raises(RejectionError) as ei:
        svc.submit(X, y, **SVC_KW)
    assert ei.value.rejection.reason == "circuit_open"
    assert ei.value.rejection.max_queue is None
    assert svc.stats()["breaker"]["rejected"] == 1
    assert svc.stats()["rejected"] == 1

    # past the cooldown: ONE probe admission is let through; the fault plan
    # is exhausted so it succeeds and closes the circuit
    t[0] += 20.0
    rid = svc.submit(X, y, **SVC_KW)
    resp = svc.poll(rid, flush=True)
    assert resp is not None
    assert svc.stats()["breaker"]["open"] == 0
    rid2 = svc.submit(X, y, **SVC_KW)   # closed again: normal admission
    assert svc.poll(rid2, flush=True) is not None


def test_breaker_unit_semantics():
    t = [0.0]
    br = CircuitBreaker(threshold=2, cooldown=5.0, clock=lambda: t[0])
    key = "g"
    assert br.allow(key)
    assert br.record_failure(key) == "closed"   # 1 of 2
    br.record_success(key)                       # interleaved success resets
    assert br.record_failure(key) == "closed"   # consecutive count restarts
    assert br.record_failure(key) == "open"
    assert not br.allow(key)                     # open, inside cooldown
    t[0] += 6.0
    assert br.allow(key)                         # half-open probe
    assert not br.allow(key)                     # one probe at a time
    assert br.record_failure(key) == "open"     # probe failed: re-open
    t[0] += 6.0
    assert br.allow(key)
    assert br.record_success(key) == "closed"
    assert br.allow(key)
    assert br.stats()["opens"] == 2


# ---------------------------------------------------------------------------
# 6. load shedding: deterministic, priority-ordered, fault-injectable
# ---------------------------------------------------------------------------

def test_shed_deterministic_under_fixed_latency_window():
    svc = PathService(max_batch=8, max_delay=10.0, shed_window=8)
    X, y = PROBLEMS[0]
    # fixed window: p95 == 1 s, well past 90% of a 500 ms budget
    for _ in range(20):
        svc.metrics.observe("latency_s", 1.0, scope="user")
    for _ in range(3):  # deterministic: same window -> same verdict
        with pytest.raises(RejectionError) as ei:
            svc.submit(X, y, deadline_ms=500.0, **SVC_KW)
        assert ei.value.rejection.reason == "shed"
    # higher priority is never shed; no deadline -> no shed basis
    assert isinstance(svc.submit(X, y, deadline_ms=500.0, priority=1,
                                 **SVC_KW), int)
    assert isinstance(svc.submit(X, y, **SVC_KW), int)
    # a budget the window comfortably meets is admitted
    assert isinstance(svc.submit(X, y, deadline_ms=60_000.0, **SVC_KW), int)
    assert svc.stats()["shed"] == 3


def test_shed_needs_min_window():
    gov = LoadShedGovernor(threshold=0.9, priority_cutoff=0, min_window=8)
    assert not gov.should_shed(10.0, 100.0, 0, window=7)   # window too small
    assert gov.should_shed(10.0, 100.0, 0, window=8)
    assert not gov.should_shed(10.0, 100.0, 1, window=8)   # priority exempt
    assert not gov.should_shed(10.0, None, 0, window=8)    # no budget
    assert not gov.should_shed(0.05, 100.0, 0, window=8)   # p95 under bar


def test_overload_fault_forces_shed_async():
    plan = FaultPlan([FaultSpec(site="overload", kind="error", times=1)])
    svc = _asvc(ProgramCache(capacity=4), faults=plan, autostart=False)
    X, y = PROBLEMS[0]
    try:
        fut = svc.submit(X, y, **SVC_KW)
        verdict = fut.result(timeout=5)
        assert isinstance(verdict, Rejection)
        assert verdict.reason == "shed"
        assert svc.stats()["shed"] == 1
        # the next admission (spec exhausted) queues normally
        fut2 = svc.submit(X, y, **SVC_KW)
        assert not fut2.done()
    finally:
        svc.close(flush=False)
