"""Per-arch smoke tests (reduced configs) + decode↔forward equivalence.

The equivalence test is the strongest correctness check in the LM substrate:
teacher-forced full-sequence logits must match step-by-step cached decode —
it exercises causal masks, RoPE indexing, the SWA ring buffer, MLA's
absorbed decode, and the SSD chunked-vs-recurrent duality.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    lm_loss,
)
from repro.models.model import prefill_cross_cache

# per-arch forward/decode sweeps take minutes: scheduled tier only
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(KEY, (B, cfg.enc_frames, cfg.d_model),
                                            jnp.float32)
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_loss_and_grad(name):
    cfg = get_config(name).reduced()
    params = init_params(cfg, KEY)
    batch = _batch_for(cfg)

    loss, metrics = jax.jit(lambda p, b: lm_loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab)

    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    gnorm = sum(float(jnp.vdot(g, g)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_full_config_shapes_exist(name):
    """Full configs instantiate (shape-only, no allocation) with sane counts."""
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda: init_params(cfg, KEY))
    total = sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(shapes))
    analytic = cfg.n_params()
    assert abs(total - analytic) / analytic < 0.02, (total, analytic)


@pytest.mark.parametrize("name", [
    "smollm-360m",            # GQA with non-divisible heads
    "h2o-danube-1.8b",        # SWA ring buffer
    "deepseek-v2-lite-16b",   # MLA absorbed decode + MoE + dense prologue
    "mamba2-1.3b",            # SSD chunked vs recurrent
    "jamba-1.5-large-398b",   # hybrid superblock
    "gemma-7b",               # GeGLU MHA
])
def test_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.attention == "swa":
        cfg = dataclasses.replace(cfg, window=8)  # exercise the ring buffer
    if cfg.moe is not None:
        # capacity drops differ between batched forward and one-token decode
        # (expected for capacity-based MoE); equivalence needs no-drop capacity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    B, S = 2, 20
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    ref_logits, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, tokens)

    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)
    ref = np.asarray(ref_logits, np.float32)[:, :, :got.shape[-1]]
    np.testing.assert_allclose(got, ref, atol=5e-3, rtol=5e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-medium").reduced()
    B, S = 2, 12
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    frames = jax.random.normal(KEY, (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    enc_out = jax.jit(lambda p, f: encode(p, f, cfg))(params, frames)
    ref_logits, _ = jax.jit(lambda p, t, e: forward(p, t, cfg, enc_out=e))(
        params, tokens, enc_out)

    cache = init_cache(cfg, B, S, enc_frames=cfg.enc_frames)
    cache = prefill_cross_cache(params, enc_out, cfg, cache)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(ref_logits, np.float32), atol=5e-3,
                               rtol=5e-3)


def test_llava_prefix_only_affects_text_loss():
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, KEY)
    batch = _batch_for(cfg, B=2, S=24)
    loss, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    logits, _ = forward(params, batch["tokens"], cfg,
                        patch_embeds=batch["patch_embeds"])
    assert logits.shape[1] == 24 + cfg.n_patches
