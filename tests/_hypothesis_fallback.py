"""Minimal stand-in for the slice of `hypothesis` these tests use.

With ``pip install -e .[test]`` the real hypothesis is present and the test
modules import it directly.  Without it (bare containers, minimal CI
images) the property-test modules fall back to this shim so the suite still
COLLECTS and the properties still run — as seeded random fuzzing with a
bounded example count rather than coverage-guided search.

Only what the test modules need is implemented: ``given`` over positional
strategies, ``settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``lists`` / ``composite`` strategies.
"""

from __future__ import annotations

import os
import types

import numpy as np

# Fallback fuzzing is bounded so the fast tier stays fast; the real
# hypothesis (CI) runs each test's full max_examples.
_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "25"))
_SEED = 0x5107E


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_ignored):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        k = int(rng.integers(min_size, hi + 1))
        return [elements.draw(rng) for _ in range(k)]

    return _Strategy(draw)


def _composite(fn):
    def builder(*args, **kwargs):
        def draw_case(rng):
            return fn(lambda strategy: strategy.draw(rng), *args, **kwargs)

        return _Strategy(draw_case)

    return builder


strategies = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    lists=_lists,
    composite=_composite,
)


def settings(max_examples=100, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        # deliberately no functools.wraps: pytest must see the (*args)
        # signature, not the wrapped one, or it would try to inject the
        # strategy parameters as fixtures
        def wrapper(*args, **kwargs):
            n = min(
                getattr(wrapper, "_fallback_max_examples", 100),
                _MAX_EXAMPLES_CAP,
            )
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                drawn = [s.draw(rng) for s in strats]
                fn(*args, *drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._fallback_max_examples = getattr(fn, "_fallback_max_examples", 100)
        return wrapper

    return deco
