"""Trainer: checkpoint/restart exactness, preemption hook, SLOPE-path reg."""

import dataclasses
import os
import signal

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.slope_reg import SlopeRegConfig
from repro.optim import AdamWHyper
from repro.train import TrainConfig, Trainer, latest_step

# LM training loops: scheduled tier only
pytestmark = pytest.mark.slow


def _tiny_cfg():
    return dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=2,
                               vocab=128)


def test_loss_decreases(tmp_path):
    tc = TrainConfig(steps=30, ckpt_every=100, log_every=5,
                     ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(_tiny_cfg(), tc, hyper=AdamWHyper(lr=3e-3), global_batch=8,
                 seq_len=32)
    out = tr.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_restart_is_exact(tmp_path):
    """Interrupted-and-resumed training equals uninterrupted training."""
    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")
    cfg = _tiny_cfg()
    hyper = AdamWHyper(lr=1e-3)

    # uninterrupted: 12 steps
    tc = TrainConfig(steps=12, ckpt_every=100, log_every=1, ckpt_dir=ck_a)
    ref = Trainer(cfg, tc, hyper=hyper, global_batch=4, seq_len=16).run()

    # interrupted at 6, resumed to 12
    tc1 = TrainConfig(steps=6, ckpt_every=100, log_every=1, ckpt_dir=ck_b)
    Trainer(cfg, tc1, hyper=hyper, global_batch=4, seq_len=16).run()
    assert latest_step(ck_b) == 5
    tc2 = TrainConfig(steps=12, ckpt_every=100, log_every=1, ckpt_dir=ck_b)
    res = Trainer(cfg, tc2, hyper=hyper, global_batch=4, seq_len=16).run()

    ref_p = jax.tree.leaves(ref["params"])
    res_p = jax.tree.leaves(res["params"])
    d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref_p, res_p))
    assert d < 1e-5, d
    assert res["final_step"] == ref["final_step"] == 11


def test_preemption_checkpoints_and_exits(tmp_path):
    tc = TrainConfig(steps=500, ckpt_every=1000, log_every=50,
                     ckpt_dir=str(tmp_path / "ck"))
    tr = Trainer(_tiny_cfg(), tc, global_batch=4, seq_len=16)

    orig = tr.train_step

    calls = {"n": 0}

    def wrapped(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 5:
            os.kill(os.getpid(), signal.SIGTERM)  # simulate preemption
        return orig(*a, **kw)

    tr.train_step = wrapped
    out = tr.run()
    assert out["preempted"]
    assert latest_step(tc.ckpt_dir) is not None  # checkpoint written on the way out
    assert out["final_step"] < 20


def test_slope_path_training_sparsifies_embedding(tmp_path):
    cfg = _tiny_cfg()
    slope = SlopeRegConfig(targets=("embed",), q=0.2, sigma0=0.5,
                           sigma_ratio=1e-1, total_steps=25, screen_every=5)
    tc = TrainConfig(steps=25, ckpt_every=100, log_every=5,
                     ckpt_dir=str(tmp_path / "ck"), slope=slope)
    tr = Trainer(cfg, tc, hyper=AdamWHyper(lr=3e-3), global_batch=4, seq_len=16)
    out = tr.run()
    total = out["params"]["embed"].size
    # the σ path starts strong: the prox must create exact zeros somewhere
    # along the path (σ decays, so end-state sparsity may be lower)
    nnzs = [m["slope/embed/nnz"] for m in out["metrics"] if "slope/embed/nnz" in m]
    assert nnzs, "screen stats were not recorded"
    assert min(nnzs) < total * 0.98, (min(nnzs), total)
    # strong-rule prediction is recorded alongside
    assert any("slope/embed/strong_k" in m for m in out["metrics"])
    losses = [m["loss"] for m in out["metrics"]]
    assert np.isfinite(losses).all()
