"""repro.serve async front-end (ISSUE 6).

The contracts under test, in dependency order:

1. **Chunked == monolithic, bitwise.**  `chunk_path_engine` advancing
   carried state C steps at a time (with `path_init_engine` prefill)
   reproduces `batched_path_engine` exactly — every EnginePath array, every
   member — because both scan the SAME per-step traced body and dead chunk
   steps hold the carry exactly.
2. **Async == sync, bitwise.**  A request served by `AsyncPathService`
   (worker thread, continuous batching, slot recycling) equals the same
   request served by the synchronous `PathService` at tolerance 0.
3. The operational layer around that: timer-driven deadline flush with no
   further service calls, priority/bounded-queue admission, rejection
   statuses, slot-recycle accounting, CV aggregation through futures, the
   user/internal latency split, and a threaded stress run with no
   lost or duplicated responses.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import ols
from repro.core.engine import (
    EnginePath,
    batched_path_engine,
    chunk_path_engine,
    null_sigma_grid,
    path_init_engine,
)
from repro.serve import (
    AsyncPathService,
    MicroBatcher,
    PathService,
    ProgramCache,
    ProgramSpec,
    QueueFull,
    Rejection,
    pad_batch,
)

# one bucket shape (32, 32), one path length, one chunk size: every AOT
# program in this module is shared through the module-scoped cache
L = 6
C = 3
SVC_KW = dict(path_length=L, solver_tol=1e-10, max_iter=20000)
ENG_KW = dict(screening="strong", max_iter=20000, tol=1e-10, kkt_tol=1e-4,
              max_refits=32)


@pytest.fixture(scope="module")
def shared_cache():
    return ProgramCache(capacity=16)


def _problem(n, p, seed=0, k=4):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[:k] = rng.normal(size=k) * 2.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    return X, y


def _asvc(shared_cache, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_delay", 0.005)
    kw.setdefault("step_chunk", C)
    return AsyncPathService(cache=shared_cache, **kw)


def _result(fut, timeout=180):
    resp = fut.result(timeout=timeout)
    assert not isinstance(resp, Rejection), resp
    return resp


# ---------------------------------------------------------------------------
# 1. chunked engine == monolithic engine, bitwise
# ---------------------------------------------------------------------------

def test_chunk_engine_bitwise_equals_monolithic():
    """C-step chunks with host round-tripped carry reproduce the monolithic
    scan bit-for-bit, including the init-program null head."""
    problems = []
    for i in range(4):
        X, y = _problem(20 + 2 * i, 24 + i, seed=10 + i)
        lam = np.linspace(2.0, 0.5, X.shape[1])
        sig = np.asarray(null_sigma_grid(X, y, lam, ols, path_length=L,
                                         sigma_ratio=None))
        problems.append((X, y, lam, sig))
    pb = pad_batch(problems, n_rows=32, n_cols=32, n_slots=4, n_classes=1)

    mono = batched_path_engine(pb.Xs, pb.ys, pb.lam, pb.sigmas, ols,
                               pb.p_valid, **ENG_KW)
    mono = EnginePath(*(np.asarray(a) for a in mono))

    grad0, null_dev, L0, h0 = (np.asarray(a)
                               for a in path_init_engine(pb.Xs, pb.ys, ols))
    np.testing.assert_array_equal(null_dev, mono.deviance[:, 0])
    np.testing.assert_array_equal(h0, 0)  # clean inputs: healthy at init

    B, P = 4, 32
    beta = np.zeros((B, P, 1))
    grad = grad0.copy()
    active = np.zeros((B, P), bool)
    Lc = L0.copy()
    Hc = h0.copy()
    chunks = []
    cursor = 1
    while cursor < L:
        take = min(C, L - cursor)
        sp = np.ones((B, C))
        sn = np.ones((B, C))
        lv = np.zeros((B, C), bool)
        for c in range(take):
            sp[:, c] = np.asarray(pb.sigmas)[:, cursor - 1 + c]
            sn[:, c] = np.asarray(pb.sigmas)[:, cursor + c]
            lv[:, c] = True
        (beta, grad, active, Lc, Hc), ep = chunk_path_engine(
            pb.Xs, pb.ys, pb.lam, sp, sn, lv, beta, grad, active, Lc, Hc,
            ols, pb.p_valid, **ENG_KW)
        beta, grad, active, Lc, Hc = (np.asarray(a)
                                      for a in (beta, grad, active, Lc, Hc))
        chunks.append(EnginePath(*(np.asarray(a)[:, :take] for a in ep)))
        cursor += take

    for field in EnginePath._fields:
        got = np.concatenate([getattr(ch, field) for ch in chunks], axis=1)
        want = getattr(mono, field)[:, 1:]  # steps only; null head above
        np.testing.assert_array_equal(got, want, err_msg=field)


# ---------------------------------------------------------------------------
# 2. async service == sync service, bitwise
# ---------------------------------------------------------------------------

def test_async_bit_identity_vs_sync(shared_cache):
    problems = [_problem(18 + 2 * i, 22 + i, seed=30 + i, k=3)
                for i in range(5)]
    asvc = _asvc(shared_cache)
    try:
        futs = [asvc.submit(X, y, family=ols, **SVC_KW)
                for X, y in problems]
        async_resps = [_result(f) for f in futs]
    finally:
        asvc.close()

    svc = PathService(cache=shared_cache, max_batch=4, max_delay=1000.0)
    rids = [svc.submit(X, y, family=ols, **SVC_KW) for X, y in problems]
    sync_resps = [svc.poll(r, flush=True) for r in rids]

    for a, s in zip(async_resps, sync_resps):
        ra = a.path_result(early_stop=True)
        rs = s.path_result(early_stop=True)
        assert ra.betas.shape == rs.betas.shape
        np.testing.assert_array_equal(ra.betas, rs.betas)
        np.testing.assert_array_equal(ra.sigmas, rs.sigmas)
        assert a.kkt_ok == s.kkt_ok


def test_slot_recycling_joins_running_cohort(shared_cache):
    """More same-bucket requests than slots, all queued before the worker
    starts: the extras must join mid-flight (slot_recycles ≥ 1) and still
    match the synchronous service bitwise."""
    problems = [_problem(16 + i, 20 + i, seed=50 + i, k=2 + i % 3)
                for i in range(6)]
    asvc = _asvc(shared_cache, max_batch=4, autostart=False)
    before = asvc.stats()["slot_recycles"]
    futs = [asvc.submit(X, y, family=ols, **SVC_KW) for X, y in problems]
    asvc.start()
    try:
        resps = [_result(f) for f in futs]
    finally:
        asvc.close()
    assert asvc.stats()["slot_recycles"] > before

    svc = PathService(cache=shared_cache, max_batch=4, max_delay=1000.0)
    rids = [svc.submit(X, y, family=ols, **SVC_KW) for X, y in problems]
    for resp, rid in zip(resps, rids):
        ref = svc.poll(rid, flush=True)
        np.testing.assert_array_equal(resp.path_result().betas,
                                      ref.path_result().betas)


# ---------------------------------------------------------------------------
# 3. timer-driven flush, admission control, priorities
# ---------------------------------------------------------------------------

def test_timer_flushes_idle_queue(shared_cache):
    """One lone request, NO further service calls: the dispatcher must
    flush it on the deadline timer (the sync service would hold it)."""
    X, y = _problem(20, 24, seed=77)
    asvc = _asvc(shared_cache, max_delay=0.01)
    try:
        fut = asvc.submit(X, y, family=ols, **SVC_KW)
        resp = _result(fut)  # no flush()/poll() anywhere
        assert resp.rid == fut.rid
        assert asvc.stats()["flush_deadline"] >= 1
    finally:
        asvc.close()


def test_rejection_past_queue_capacity(shared_cache):
    # worker not started: the queue holds regardless of max_delay, and the
    # 2-deep bound rejects the overflow immediately at admission
    asvc = _asvc(shared_cache, max_queue=2, max_delay=0.01, autostart=False)
    futs = [asvc.submit(*_problem(20, 24, seed=80 + i), family=ols, **SVC_KW)
            for i in range(4)]
    rejected = [f for f in futs if f.done()
                and isinstance(f.result(), Rejection)]
    assert len(rejected) == 2
    rej = rejected[0].result()
    assert rej.max_queue == 2 and "capacity" in rej.reason
    assert asvc.stats()["rejected"] == 2
    # the two admitted requests still get served once the worker runs
    asvc.start()
    try:
        served = [_result(f) for f in futs if f not in rejected]
        assert len(served) == 2
    finally:
        asvc.close()


def test_batcher_priority_and_fifo_order():
    b = MicroBatcher(max_batch=8, max_delay=1.0)
    for rid, prio in [(0, 0), (1, 5), (2, 0), (3, 5), (4, 1)]:
        b.admit("g", rid, f"item{rid}", now=0.0, priority=prio)
    order = [p.rid for p in b.take("g")]
    # priority desc, FIFO within a priority
    assert order == [1, 3, 4, 0, 2]


def test_batcher_queue_full_and_next_deadline():
    b = MicroBatcher(max_batch=8, max_delay=0.5, max_queue=2)
    b.admit("g", 0, "a", now=0.0)
    b.admit("h", 1, "b", now=0.0, deadline=0.2)
    with pytest.raises(QueueFull):
        b.admit("g", 2, "c", now=0.0)
    assert b.next_deadline() == pytest.approx(0.2)
    assert b.pending() == 2
    b.take("h")
    assert b.next_deadline() == pytest.approx(0.5)


def test_program_spec_variants_validate():
    kw = dict(family=ols, batch=4, n_rows=32, n_cols=32, path_length=L)
    spec = ProgramSpec(**kw, variant="chunk", step_chunk=C)
    assert f"chunk{C}" in spec.short()
    assert "init" in ProgramSpec(**kw, variant="init").short()
    with pytest.raises(ValueError):
        ProgramSpec(**kw, variant="chunk")  # needs step_chunk
    with pytest.raises(ValueError):
        ProgramSpec(**kw, variant="chunk", step_chunk=C, working_set=16)
    with pytest.raises(ValueError):
        ProgramSpec(**kw, variant="path", step_chunk=C)
    with pytest.raises(ValueError):
        ProgramSpec(**kw, variant="bogus")


def test_async_poll_is_disabled(shared_cache):
    asvc = _asvc(shared_cache, autostart=False)
    with pytest.raises(TypeError):
        asvc.poll(0)


# ---------------------------------------------------------------------------
# 4. CV through futures + the latency split
# ---------------------------------------------------------------------------

def test_async_cv_matches_sync_service(shared_cache):
    X, y = _problem(30, 24, seed=90, k=3)
    asvc = _asvc(shared_cache)
    try:
        cv_async = _result(asvc.submit(X, y, family=ols, cv_folds=3,
                                       **SVC_KW))
        st = asvc.stats()
        # the satellite fix: fold fits are internal, the CV request itself
        # never enters the user-facing latency window either (it has no
        # solve of its own) — so SLO percentiles measure caller traffic
        assert st["internal_latency_count"] == 3
    finally:
        asvc.close()

    svc = PathService(cache=shared_cache, max_batch=4, max_delay=1000.0)
    rid = svc.submit(X, y, family=ols, cv_folds=3, **SVC_KW)
    cv_sync = svc.poll(rid, flush=True)
    np.testing.assert_array_equal(cv_async.val_deviance, cv_sync.val_deviance)
    assert cv_async.best_index == cv_sync.best_index
    for fa, fs in zip(cv_async.fold_responses, cv_sync.fold_responses):
        np.testing.assert_array_equal(fa.betas, fs.betas)


def test_latency_split_user_vs_internal(shared_cache):
    svc = PathService(cache=shared_cache, max_batch=4, max_delay=1000.0)
    X, y = _problem(26, 24, seed=91, k=3)
    rid_cv = svc.submit(X, y, family=ols, cv_folds=3, **SVC_KW)
    rid = svc.submit(X, y, family=ols, **SVC_KW)
    assert svc.poll(rid_cv, flush=True) is not None
    assert svc.poll(rid) is not None
    st = svc.stats()
    assert st["internal_latency_count"] == 3  # the fold fits
    assert st["latency_count"] == 1           # the one user request
    assert st["internal_latency_ms_p95"] >= 0.0
    assert st["latency_ms_p50"] > 0.0


# ---------------------------------------------------------------------------
# 5. threaded stress: no lost or duplicated responses (time-bounded)
# ---------------------------------------------------------------------------

def test_threaded_stress_no_lost_or_duplicate_responses(shared_cache):
    n_threads, per_thread = 4, 6
    asvc = _asvc(shared_cache, max_batch=4, max_delay=0.002,
                 max_queue=None,  # unbounded: every submit must complete
                 tracing=True)    # every response must carry a full timeline
    results: dict[int, object] = {}
    res_lock = threading.Lock()
    errors: list[BaseException] = []

    def client(t):
        try:
            futs = []
            for j in range(per_thread):
                X, y = _problem(16 + (t + j) % 8, 20 + (t * j) % 8,
                                seed=1000 + t * 100 + j, k=2)
                futs.append(asvc.submit(X, y, family=ols, **SVC_KW))
            for f in futs:
                resp = f.result(timeout=180)
                with res_lock:
                    assert resp.rid not in results, "duplicate rid"
                    results[resp.rid] = resp
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=180)
    try:
        assert not errors, errors
        total = n_threads * per_thread
        assert len(results) == total
        assert all(not isinstance(r, Rejection) for r in results.values())
        assert asvc.drain(timeout=30)
        st = asvc.stats()
        assert st["submitted"] == total
        assert st["completed"] == total
        assert st["rejected"] == 0
        assert st["inflight"] == 0
        assert st["pending"] == 0
        cache_stats = asvc.cache.stats()
        assert cache_stats["hits"] + cache_stats["misses"] >= 2
        # under 4-thread contention every trace is still per-request
        # coherent: gap-free admit→deliver, children parented in order
        for resp in results.values():
            tr = resp.trace
            assert tr is not None and tr.rid == resp.rid
            names = tr.span_names()
            assert names[0] == "admit" and names[-1] == "deliver"
            assert tr.contiguous(), names
            assert tr.well_parented()
        assert time.monotonic() - t0 < 180
    finally:
        asvc.close()
