"""repro.obs — metrics registry, request tracing, solver introspection
(ISSUE 8).

Contracts under test:

* `MetricsRegistry` counts exactly under N-thread contention on one
  labeled series (the registry is the single accounting surface for the
  whole serving stack, so a lost increment is a lost request);
* histograms are bounded (one eviction policy for every telemetry window)
  while `total` stays monotonic;
* `Trace` timelines are gap-free by construction and children are
  parented to spans that exist;
* `PathService.stats()` / `AsyncPathService.stats()` key schemas are
  snapshot-pinned, and the async schema is a STRICT superset of the sync
  one (both are read-through views over the same registry — they cannot
  drift independently);
* a traced request carries an admit→deliver timeline with no gaps, and
  tracing stays OFF by default (`resp.trace is None`);
* `SolverPolicy(telemetry=...)` attaches a `PathTrace` whose screened-set
  counts match the fit's own arrays;
* exporters round-trip through JSONL and render Prometheus text.
"""

import json
import threading

import numpy as np
import pytest

from repro.api import PathSpec, Problem, SolverPolicy, slope_path
from repro.core import bh_sequence, ols
from repro.obs import (
    MetricsRegistry,
    PathTrace,
    Trace,
    prometheus_text,
    registry_events,
    trace_events,
    write_jsonl,
)
from repro.serve import AsyncPathService, PathService, ProgramCache

KW = dict(path_length=6, solver_tol=1e-10, max_iter=20000)


@pytest.fixture(scope="module")
def shared_cache():
    return ProgramCache(capacity=16)


def _problem(n=24, p=20, seed=0, k=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[:k] = 2.0
    y = X @ beta + 0.1 * rng.normal(size=n)
    return X, y


# ---------------------------------------------------------------------------
# MetricsRegistry: counters, gauges, histograms, labels
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    m = MetricsRegistry("t")
    assert m.inc("a") == 1
    assert m.inc("a", 4) == 5
    assert m.value("a") == 5
    assert m.value("missing") == 0
    assert m.value("missing", default=-1) == -1
    m.set_gauge("depth", 3.5)
    assert m.gauge("depth").value == 3.5
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("lat", v)
    h = m.histogram("lat")
    assert h.retained == 4 and h.total == 4
    assert h.mean() == 2.5
    assert h.percentile(50) == pytest.approx(2.5)
    snap = m.snapshot()
    assert snap["namespace"] == "t"
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["depth"] == 3.5
    assert snap["histograms"]["lat"]["count"] == 4


def test_registry_labeled_series_are_distinct():
    m = MetricsRegistry("t")
    m.inc("flush", trigger="fill")
    m.inc("flush", 2, trigger="deadline")
    assert m.value("flush", trigger="fill") == 1
    assert m.value("flush", trigger="deadline") == 2
    assert m.value("flush") == 0  # the unlabeled series is its own
    assert m.label_values("flush", "trigger") == {"fill": 1, "deadline": 2}


def test_histogram_window_is_bounded_total_is_not():
    m = MetricsRegistry("t")
    for i in range(100):
        m.observe("w", float(i), maxlen=16)
    h = m.histogram("w", maxlen=16)
    assert h.retained == 16
    assert h.maxlen == 16
    assert h.total == 100          # monotonic despite eviction
    assert min(h.values()) == 84.0  # oldest observations evicted


def test_registry_exact_counts_under_thread_contention():
    m = MetricsRegistry("t")
    n_threads, per_thread = 8, 2500

    def worker():
        for _ in range(per_thread):
            m.inc("hits", op="x")
            m.observe("lat", 1.0, op="x")

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.value("hits", op="x") == n_threads * per_thread
    assert m.histogram("lat", op="x").total == n_threads * per_thread


# ---------------------------------------------------------------------------
# Trace: gap-free spans, parented children
# ---------------------------------------------------------------------------

def test_trace_is_contiguous_by_construction():
    tr = Trace(rid=7, t0=100.0)
    tr.mark("admit", 100.5)
    tr.mark("queue", 101.0)
    tr.mark("execute", 103.0, batch=4)
    tr.child("retry", t0=102.0, t1=102.0, attempt=1)
    tr.mark("deliver", 103.25)
    assert tr.span_names() == ["admit", "queue", "execute", "deliver"]
    assert [s.name for s in tr.children()] == ["retry"]
    assert tr.children()[0].parent == "execute"
    assert tr.contiguous()
    assert tr.well_parented()
    assert tr.total_s == pytest.approx(3.25)
    # a non-monotonic clock cannot open a gap: t_end clamps to the cursor
    tr2 = Trace(rid=0, t0=10.0)
    tr2.mark("a", 11.0)
    tr2.mark("b", 10.5)   # behind the cursor
    assert tr2.contiguous()
    assert tr2.top()[-1].duration_s == 0.0


def test_trace_events_and_render():
    tr = Trace(rid=1, t0=0.0)
    tr.mark("admit", 0.25)
    tr.mark("deliver", 1.0)
    evs = trace_events(tr, run="x")
    assert all(e["rid"] == 1 and e["run"] == "x" for e in evs)
    assert [e["name"] for e in evs] == ["admit", "deliver"]
    out = tr.render()
    assert "admit" in out and "deliver" in out


# ---------------------------------------------------------------------------
# stats() schema snapshots: sync pinned, async a strict superset
# ---------------------------------------------------------------------------

SYNC_STATS_KEYS = {
    "submitted", "completed", "pending", "unclaimed", "results_evicted",
    "batches", "flush_fill", "flush_deadline", "flush_forced", "flush_retry",
    "rejected", "validation_rejected", "shed", "watchdog_timeouts",
    "breaker", "kkt_violations", "max_queue",
    "faults", "slots", "occupancy_mean", "padding_ratio_mean",
    "latency_ms_p50", "latency_ms_p95", "latency_count",
    "internal_latency_ms_p50", "internal_latency_ms_p95",
    "internal_latency_count", "cache", "plans", "ws_buckets", "resample",
}

ASYNC_ONLY_KEYS = {
    "slot_recycles", "chunk_batches", "step_chunk", "inflight", "retries",
    "bisections", "poisoned", "checkpoints", "restored",
    "retry_limit", "retry_backoff", "worker_alive",
}


def test_stats_schema_snapshot(shared_cache):
    svc = PathService(cache=shared_cache)
    assert set(svc.stats().keys()) == SYNC_STATS_KEYS
    asvc = AsyncPathService(cache=shared_cache, autostart=False)
    try:
        async_keys = set(asvc.stats().keys())
    finally:
        asvc.close(flush=False)
    # strict superset: every sync key present, plus exactly the async keys
    assert async_keys > SYNC_STATS_KEYS
    assert async_keys - SYNC_STATS_KEYS == ASYNC_ONLY_KEYS


def test_cache_and_bucket_stats_schema(shared_cache):
    assert set(shared_cache.stats().keys()) == {
        "size", "capacity", "hits", "misses", "hit_rate", "evictions",
        "builds", "build_seconds", "store", "programs"}
    from repro.core.engine import _WS_BUCKETS
    assert set(_WS_BUCKETS.stats().keys()) == {
        "name", "size", "capacity", "hits", "misses", "updates",
        "evictions", "entries"}


# ---------------------------------------------------------------------------
# end-to-end: a traced sync request, tracing off by default
# ---------------------------------------------------------------------------

def test_sync_request_trace_covers_admit_to_deliver(shared_cache):
    X, y = _problem()
    svc = PathService(max_batch=2, cache=shared_cache, tracing=True)
    rid = svc.submit(X, y, family=ols, **KW)
    resp = svc.poll(rid, flush=True)
    tr = resp.trace
    assert tr is not None and tr.rid == rid
    names = tr.span_names()
    assert names[0] == "admit" and names[-1] == "deliver"
    assert {"queue", "flush", "compile", "execute", "harvest"} <= set(names)
    assert tr.contiguous()
    assert tr.well_parented()
    # registry agrees with delivery
    assert svc.metrics.value("submitted") == 1
    assert svc.metrics.value("completed") == 1


def test_tracing_off_by_default(shared_cache):
    X, y = _problem(seed=1)
    svc = PathService(max_batch=2, cache=shared_cache)
    resp = svc.poll(svc.submit(X, y, family=ols, **KW), flush=True)
    assert resp.trace is None
    assert not svc._traces  # no per-request state retained


# ---------------------------------------------------------------------------
# solver introspection: SolverPolicy.telemetry → PathTrace
# ---------------------------------------------------------------------------

def test_policy_telemetry_attaches_path_trace():
    rng = np.random.default_rng(3)
    B, n, p = 3, 20, 24
    Xs = rng.normal(size=(B, n, p))
    beta = np.zeros(p)
    beta[:4] = 2.0
    ys = Xs @ beta + 0.1 * rng.normal(size=(B, n))
    lam = np.asarray(bh_sequence(p, q=0.1))
    spec = PathSpec(lam=lam, path_length=6)
    pol = SolverPolicy(backend="compact", working_set=8, pad=None,
                       telemetry="steps", **{"solver_tol": 1e-10})
    res = slope_path(Problem(Xs, ys), spec, pol)
    pt = res.path_trace
    assert isinstance(pt, PathTrace)
    assert pt.mode == "steps"
    assert pt.n_members == B and pt.n_steps == 6 and pt.p == p
    # steps mode retains the raw arrays and they match the result's own
    np.testing.assert_array_equal(pt.n_screened, res.n_screened)
    np.testing.assert_array_equal(pt.n_violations, res.n_violations)
    assert pt.screened_peak.shape == (B,)
    assert pt.tier_steps.shape == (B, 3)
    np.testing.assert_array_equal(
        pt.tier_steps.sum(axis=1), np.full(B, 6))
    assert (0.0 <= pt.screened_occupancy).all()
    assert (pt.screened_occupancy <= 1.0).all()
    assert "screened_occupancy_mean" in pt.summary()
    assert "sigma" in pt.render(0)

    # summary mode drops the per-step arrays; off attaches nothing —
    # and NEITHER perturbs the coefficients
    pol_sum = SolverPolicy(backend="compact", working_set=8, pad=None,
                           telemetry="summary", solver_tol=1e-10)
    res_sum = slope_path(Problem(Xs, ys), spec, pol_sum)
    assert res_sum.path_trace.mode == "summary"
    assert res_sum.path_trace.n_screened is None
    pol_off = SolverPolicy(backend="compact", working_set=8, pad=None,
                           solver_tol=1e-10)
    res_off = slope_path(Problem(Xs, ys), spec, pol_off)
    assert res_off.path_trace is None
    np.testing.assert_array_equal(res.betas, res_off.betas)
    np.testing.assert_array_equal(res_sum.betas, res_off.betas)


def test_path_trace_mode_validation():
    with pytest.raises(ValueError, match="telemetry"):
        SolverPolicy(telemetry="everything")
    with pytest.raises(ValueError):
        PathTrace.from_arrays(
            mode="off", p=4, sigmas=np.ones((1, 2)),
            n_screened=np.ones((1, 2)), n_active=np.ones((1, 2)),
            n_violations=np.zeros((1, 2)), refits=np.zeros((1, 2)),
            solver_iters=np.ones((1, 2)), health=np.zeros((1, 2)))


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_jsonl_export_roundtrip(tmp_path):
    m = MetricsRegistry("exp")
    m.inc("reqs", 3, route="a")
    m.set_gauge("depth", 2.0)
    m.observe("lat", 0.5)
    tr = Trace(rid=9, t0=0.0)
    tr.mark("admit", 0.5)
    tr.mark("deliver", 1.0)
    path = tmp_path / "metrics.jsonl"
    n = write_jsonl(str(path), registry_events(m, run="ci"))
    n += write_jsonl(str(path), trace_events(tr), append=True)
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == n == 5
    kinds = {ln["kind"] for ln in lines if "kind" in ln}
    assert kinds == {"counter", "gauge", "histogram"}
    assert lines[0]["run"] == "ci"
    span_lines = [ln for ln in lines if "rid" in ln]
    assert [s["name"] for s in span_lines] == ["admit", "deliver"]


def test_prometheus_text_exposition():
    m = MetricsRegistry("serve")
    m.inc("completed", 7)
    m.inc("flush", 2, trigger="fill")
    m.inc("flush", 1, trigger="deadline")
    m.observe("latency_s", 0.25, scope="user")
    text = prometheus_text(m)
    assert "# TYPE serve_completed counter" in text
    assert "serve_completed 7" in text
    assert 'serve_flush{trigger="fill"} 2' in text
    assert 'serve_flush{trigger="deadline"} 1' in text
    # one TYPE line per metric name even with several labeled series
    assert text.count("# TYPE serve_flush counter") == 1
    assert 'quantile="0.95"' in text
    assert text.endswith("\n")
