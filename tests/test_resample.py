"""repro.resample — materialize-free replicate engine tests.

Covers the PR-9 subsystem end to end: deterministic ResamplePlan
expansion (prefix-stable per-member PRNG), the weight-fused replicate
engines against the materialized row-duplication reference (the property
the whole design rests on), the compact gather variant, the weighted
Pallas kernel wrappers, the API/planner seams (PathSpec.resample,
Problem.weights) and the served replicate fan-out (sync + async).

Runs in the test-minimal CI job: stdlib + NumPy only on top of the repo
(hypothesis is optional via tests/_hypothesis_fallback).
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.api import (
    PathSpec,
    Problem,
    ResamplePlan,
    SolverPolicy,
    plan_execution,
    slope_path,
)
from repro.core.engine import (
    null_sigma_grid,
    replicate_compact_path_engine,
    replicate_path_engine,
)
from repro.core.lambda_seq import bh_sequence
from repro.core.losses import logistic, ols
from repro.resample import (
    bagged_slope,
    fit_replicates,
    permutation_pvalues,
    resample_stats,
    selection_frequencies,
    stability_selection,
)

ENG_KW = dict(screening="strong", max_iter=20000, tol=1e-10, kkt_tol=1e-4,
              max_refits=32)
POL = dict(solver_tol=1e-10, max_iter=20000, kkt_tol=1e-4)


def _problem(n, p, seed=0, k=3, noise=0.5):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    beta = np.zeros(p)
    beta[:k] = 2.0 * np.sign(rng.standard_normal(k))
    y = X @ beta + noise * rng.standard_normal(n)
    lam = np.asarray(bh_sequence(p, q=0.1))
    return X, y, lam


def _sigmas(X, y, lam, L=6, family=ols):
    return np.asarray(null_sigma_grid(X, y, lam, family, path_length=L,
                                      sigma_ratio=None))


# ---------------------------------------------------------------------------
# ResamplePlan: validation, determinism, prefix stability
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="unknown resample kind"):
        ResamplePlan(kind="jackknife")
    with pytest.raises(ValueError, match="positive int"):
        ResamplePlan(n_replicates=0)
    with pytest.raises(ValueError, match="positive int"):
        ResamplePlan(n_replicates=True)
    with pytest.raises(ValueError, match="fraction"):
        ResamplePlan(kind="subsample", fraction=0.0)
    with pytest.raises(ValueError, match="fraction"):
        ResamplePlan(kind="subsample", fraction=1.5)


@pytest.mark.parametrize("kind", ["bootstrap", "subsample", "permutation"])
def test_plan_deterministic_and_prefix_stable(kind):
    n = 20
    w1 = np.asarray(ResamplePlan(kind=kind, n_replicates=8,
                                 seed=7).row_weights(n))
    w2 = np.asarray(ResamplePlan(kind=kind, n_replicates=8,
                                 seed=7).row_weights(n))
    np.testing.assert_array_equal(w1, w2)          # same seed → same draws
    # member b depends only on (seed, b), never on B: a B=16 plan's first
    # 8 members ARE the B=8 plan (incremental B sweeps are reproducible)
    w16 = np.asarray(ResamplePlan(kind=kind, n_replicates=16,
                                  seed=7).row_weights(n))
    np.testing.assert_array_equal(w1, w16[:8])
    if kind != "permutation":
        w3 = np.asarray(ResamplePlan(kind=kind, n_replicates=8,
                                     seed=8).row_weights(n))
        assert not np.array_equal(w1, w3)          # seed actually matters


def test_plan_weight_semantics():
    n = 25
    wb = np.asarray(ResamplePlan(kind="bootstrap", n_replicates=6,
                                 seed=1).row_weights(n))
    # n multinomial draws with replacement: counts sum to n per member
    np.testing.assert_array_equal(wb.sum(axis=1), np.full(6, float(n)))
    assert (wb >= 0).all() and (wb == np.round(wb)).all()

    ws = np.asarray(ResamplePlan(kind="subsample", n_replicates=6, seed=1,
                                 fraction=0.4).row_weights(n))
    assert set(np.unique(ws)) <= {0.0, 1.0}
    np.testing.assert_array_equal(ws.sum(axis=1), np.full(6, 10.0))  # ⌈.4n⌉

    wp = np.asarray(ResamplePlan(kind="permutation", n_replicates=4,
                                 seed=1).row_weights(n))
    np.testing.assert_array_equal(wp, np.ones((4, n)))


def test_replicate_indices_agree_with_weights():
    n = 18
    boot = ResamplePlan(kind="bootstrap", n_replicates=5, seed=3)
    w = np.asarray(boot.row_weights(n))
    for b, idx in enumerate(boot.replicate_indices(n)):
        np.testing.assert_array_equal(np.bincount(idx, minlength=n), w[b])
    sub = ResamplePlan(kind="subsample", n_replicates=5, seed=3, fraction=0.5)
    ws = np.asarray(sub.row_weights(n))
    for b, idx in enumerate(sub.replicate_indices(n)):
        np.testing.assert_array_equal(np.flatnonzero(ws[b]), idx)
    perm = ResamplePlan(kind="permutation", n_replicates=3, seed=3)
    y = np.arange(n, dtype=float)
    yp = np.asarray(perm.permuted_targets(y))
    for b, idx in enumerate(perm.replicate_indices(n)):
        np.testing.assert_array_equal(y[idx], yp[b])


def test_plan_is_static_pytree():
    plan = ResamplePlan(kind="subsample", n_replicates=12, seed=5,
                        fraction=0.7)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert leaves == []                        # fully static: four scalars
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    assert again.kind == plan.kind and again.seed == plan.seed
    assert again.n_replicates == 12 and again.fraction == 0.7


# ---------------------------------------------------------------------------
# Weight-fused engines vs the materialized reference
# ---------------------------------------------------------------------------

def test_zero_weight_member_is_exactly_null():
    X, y, lam = _problem(20, 12, seed=2)
    sig = _sigmas(X, y, lam)
    W = jnp.asarray(ResamplePlan(kind="bootstrap", n_replicates=3,
                                 seed=1).row_weights(20))
    W = W.at[0].set(0.0)                       # an all-zero count vector
    res = replicate_path_engine(jnp.asarray(X), jnp.asarray(y),
                                jnp.asarray(lam), jnp.asarray(sig), W, ols,
                                **ENG_KW)
    # exact null member (no data → β ≡ 0), not merely small
    assert float(jnp.max(jnp.abs(res.betas[0]))) == 0.0
    assert float(jnp.max(jnp.abs(res.betas[1:]))) > 0.0


def test_ones_weights_match_unweighted_path():
    from repro.core.path import fit_path

    X, y, lam = _problem(24, 16, seed=4)
    sig = _sigmas(X, y, lam)
    ref = fit_path(X, y, lam, ols, engine="device", sigmas=sig,
                   early_stop=False, screening="strong", solver_tol=1e-10,
                   max_iter=20000, kkt_tol=1e-4, max_refits=32)
    W = jnp.ones((2, 24))
    res = replicate_path_engine(jnp.asarray(X), jnp.asarray(y),
                                jnp.asarray(lam), jnp.asarray(sig), W, ols,
                                **ENG_KW)
    # the weighted code path evaluates the same math through different
    # expressions (w⊙r contraction), so tight-tol — not bitwise
    ref_b = np.asarray(ref.betas).reshape(len(sig), -1)
    for b in range(2):
        np.testing.assert_allclose(np.asarray(res.betas[b]).reshape(
            len(sig), -1), ref_b, atol=1e-10)


def test_compact_matches_masked_bitwise():
    X, y, lam = _problem(20, 30, seed=6)
    sig = _sigmas(X, y, lam)
    W = jnp.asarray(ResamplePlan(kind="bootstrap", n_replicates=4,
                                 seed=2).row_weights(20))
    masked = replicate_path_engine(jnp.asarray(X), jnp.asarray(y),
                                   jnp.asarray(lam), jnp.asarray(sig), W,
                                   ols, **ENG_KW)
    compact, stats = replicate_compact_path_engine(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(lam), jnp.asarray(sig),
        W, ols, width=16, width2=None, **ENG_KW)
    # the gather engine falls back to the masked solve when the working
    # set overflows, and agrees bit-for-bit when it does not — either way
    # the results are identical
    np.testing.assert_array_equal(np.asarray(compact.betas),
                                  np.asarray(masked.betas))
    assert stats is not None


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(8, 14), st.integers(4, 10),
       st.integers(2, 3))
def test_property_weighted_equals_materialized(seed, n, p, B):
    """The load-bearing identity: a count-weighted replicate path equals
    the path fit on the materialized row-duplicated bootstrap sample."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    y = X[:, 0] - 0.5 * X[:, p // 2] + 0.3 * rng.standard_normal(n)
    lam = np.asarray(bh_sequence(p, q=0.1))
    sig = _sigmas(X, y, lam, L=4)
    plan = ResamplePlan(kind="bootstrap", n_replicates=B, seed=seed % 997)
    W = plan.row_weights(n, dtype=jnp.float64)
    fused = replicate_path_engine(jnp.asarray(X), jnp.asarray(y),
                                  jnp.asarray(lam), jnp.asarray(sig), W,
                                  ols, **ENG_KW)
    for b, idx in enumerate(plan.replicate_indices(n)):
        # same engine, ones-weights, on the duplicated rows — the σ grid
        # is shared so both solve the identical sequence of problems
        ref = replicate_path_engine(
            jnp.asarray(X[idx]), jnp.asarray(y[idx]), jnp.asarray(lam),
            jnp.asarray(sig), jnp.ones((1, len(idx))), ols, **ENG_KW)
        np.testing.assert_allclose(np.asarray(fused.betas[b]),
                                   np.asarray(ref.betas[0]), atol=1e-8)


def test_subsample_weights_equal_materialized_subset():
    X, y, lam = _problem(22, 10, seed=9)
    sig = _sigmas(X, y, lam, L=4)
    plan = ResamplePlan(kind="subsample", n_replicates=3, seed=11,
                        fraction=0.6)
    W = plan.row_weights(22, dtype=jnp.float64)
    fused = replicate_path_engine(jnp.asarray(X), jnp.asarray(y),
                                  jnp.asarray(lam), jnp.asarray(sig), W,
                                  ols, **ENG_KW)
    for b, idx in enumerate(plan.replicate_indices(22)):
        ref = replicate_path_engine(
            jnp.asarray(X[idx]), jnp.asarray(y[idx]), jnp.asarray(lam),
            jnp.asarray(sig), jnp.ones((1, len(idx))), ols, **ENG_KW)
        np.testing.assert_allclose(np.asarray(fused.betas[b]),
                                   np.asarray(ref.betas[0]), atol=1e-8)


# ---------------------------------------------------------------------------
# Weighted Pallas kernel wrappers vs per-member host weighting
# ---------------------------------------------------------------------------

def test_replicate_kernel_ops_bitwise():
    from repro.kernels.ops import (
        slope_gradient,
        slope_gradient_replicate,
        slope_residual,
        slope_residual_replicate,
    )

    rng = np.random.default_rng(13)
    n, p, B = 32, 24, 3
    X = jnp.asarray(rng.standard_normal((n, p)))
    R = jnp.asarray(rng.standard_normal((B, n)))
    Bv = jnp.asarray(rng.standard_normal((B, p)))
    Y = jnp.asarray(rng.standard_normal((B, n)))
    W = jnp.asarray(ResamplePlan(kind="bootstrap", n_replicates=B,
                                 seed=4).row_weights(n, dtype=jnp.float64))
    g = slope_gradient_replicate(X, R, W)
    r = slope_residual_replicate(X, Bv, Y, W, family="ols")
    for b in range(B):
        # Xᵀ(w⊙r): weighting the residual on the host and running the
        # unweighted kernel is the same contraction in the same order
        g_ref = slope_gradient(X, W[b] * R[b])
        np.testing.assert_array_equal(np.asarray(g[b]), np.asarray(g_ref))
        r_ref = W[b] * slope_residual(X, Bv[b], Y[b], family="ols")
        np.testing.assert_array_equal(np.asarray(r[b]), np.asarray(r_ref))


def test_replicate_loss_residual_zero_weight_guard():
    from repro.kernels.ops import slope_loss_residual_replicate

    rng = np.random.default_rng(14)
    n, p = 16, 8
    X = jnp.asarray(rng.standard_normal((3, n, p))[0])
    Bv = jnp.asarray(rng.standard_normal((2, p)))
    Y = jnp.asarray(rng.standard_normal((2, n)))
    W = jnp.ones((2, n)).at[0].set(0.0)        # an exact-null member
    loss, r = slope_loss_residual_replicate(X, Bv, Y, W, family="ols")
    assert float(loss[0]) == 0.0 and float(jnp.max(jnp.abs(r[0]))) == 0.0
    assert np.isfinite(float(loss[1]))


# ---------------------------------------------------------------------------
# API seams: PathSpec.resample, planner rules, Problem.weights
# ---------------------------------------------------------------------------

def test_pathspec_resample_validation():
    with pytest.raises(ValueError, match="ResamplePlan"):
        PathSpec(resample="bootstrap")
    with pytest.raises(ValueError, match="mutually exclusive"):
        PathSpec(resample=ResamplePlan(n_replicates=4), cv_folds=3)


def test_planner_resample_rules():
    X, y, lam = _problem(20, 12)
    rs = ResamplePlan(n_replicates=4)
    spec = PathSpec(lam=lam, resample=rs)
    pln = plan_execution(Problem(X, y), spec, SolverPolicy())
    assert pln.backend in ("device", "serve")
    assert any("resampling" in r for r in pln.reasons)

    with pytest.raises(ValueError, match="single \\(n, p\\) problem"):
        plan_execution(Problem(np.stack([X, X]), np.stack([y, y])), spec,
                       SolverPolicy())
    with pytest.raises(ValueError, match="backend='host'"):
        plan_execution(Problem(X, y), spec, SolverPolicy(backend="host"))
    with pytest.raises(ValueError, match="backend='serve'"):
        plan_execution(Problem(X, y), spec,
                       SolverPolicy(backend="masked", pad="bucket"))


def test_slope_path_resample_matches_fit_replicates():
    X, y, lam = _problem(20, 12, seed=21)
    rs = ResamplePlan(kind="bootstrap", n_replicates=4, seed=5)
    out = slope_path(Problem(X, y),
                     PathSpec(lam=lam, path_length=5, resample=rs),
                     SolverPolicy(backend="masked", **POL))
    assert out.resample is rs
    direct = fit_replicates(X, y, lam, rs, ols, path_length=5,
                            solver_tol=1e-10, max_iter=20000)
    assert np.asarray(out.betas).shape[0] == 4
    np.testing.assert_array_equal(
        np.asarray(out.betas).reshape(4, 5, -1),
        direct.betas.reshape(4, 5, -1))
    out_sig = np.asarray(out.sigmas)
    if out_sig.ndim == 2:                      # batched results broadcast
        out_sig = out_sig[0]                   # the shared grid per member
    np.testing.assert_array_equal(out_sig, direct.sigmas)


def test_weighted_problem_matches_scaled_design():
    X, y, lam = _problem(20, 12, seed=30)
    w = np.random.default_rng(31).uniform(0.5, 2.0, size=20)
    spec = PathSpec(lam=lam, path_length=5, early_stop=False)
    weighted = slope_path(Problem(X, y, weights=w), spec,
                          SolverPolicy(backend="masked", **POL))
    sw = np.sqrt(w)
    scaled = slope_path(Problem(X * sw[:, None], y * sw), spec,
                        SolverPolicy(backend="masked", **POL))
    # the rw device route computes its σ grid from the √w-scaled problem,
    # so both runs solve the identical path
    np.testing.assert_array_equal(np.asarray(weighted.sigmas),
                                  np.asarray(scaled.sigmas))
    np.testing.assert_allclose(np.asarray(weighted.betas),
                               np.asarray(scaled.betas), atol=1e-10)


def test_weights_rejected_for_non_ols():
    X, y, lam = _problem(20, 8)
    yb = (y > 0).astype(float)
    prob = Problem(X, yb, family=logistic,
                   weights=np.ones(20))
    with pytest.raises(ValueError, match="OLS"):
        slope_path(prob, PathSpec(lam=np.asarray(bh_sequence(8, q=0.1))),
                   SolverPolicy(backend="masked", **POL))
    with pytest.raises(ValueError, match="strictly positive"):
        slope_path(Problem(X, y, weights=np.zeros(20)),
                   PathSpec(lam=lam), SolverPolicy(backend="masked", **POL))


# ---------------------------------------------------------------------------
# Workload drivers
# ---------------------------------------------------------------------------

def test_stability_selection_recovers_support():
    X, y, lam = _problem(60, 16, seed=17, k=3, noise=0.3)
    res = stability_selection(
        X, y, lam,
        ResamplePlan(kind="subsample", n_replicates=16, seed=1, fraction=0.5),
        path_length=6, solver_tol=1e-8, max_iter=5000)
    assert res.frequencies.shape == (6, 16)
    assert res.max_frequency.shape == (16,)
    assert ((0.0 <= res.frequencies) & (res.frequencies <= 1.0)).all()
    # the planted predictors are selected in (almost) every replicate;
    # most noise predictors never reach the threshold
    assert res.selected[:3].all()
    assert res.max_frequency[:3].min() > res.max_frequency[3:].mean()
    assert res.replicates.n_replicates == 16

    with pytest.raises(ValueError, match="permutation"):
        stability_selection(X, y, lam, ResamplePlan(kind="permutation",
                                                    n_replicates=4))


def test_stability_selection_compact_backend():
    X, y, lam = _problem(40, 20, seed=18, k=2, noise=0.3)
    res = stability_selection(
        X, y, lam,
        ResamplePlan(kind="subsample", n_replicates=8, seed=2, fraction=0.5),
        path_length=5, working_set=8, ws_tiers=2,
        solver_tol=1e-8, max_iter=5000)
    assert res.replicates.stats is not None    # compact engine ran
    assert res.selected[:2].all()


def test_selection_frequencies_shape_and_tol():
    betas = np.zeros((4, 3, 5, 1))
    betas[:2, :, 0, 0] = 1.0                    # predictor 0 in half
    betas[:, :, 1, 0] = 1e-12                   # sub-tol noise
    freq = selection_frequencies(betas, tol=1e-8)
    np.testing.assert_allclose(freq[:, 0], 0.5)
    np.testing.assert_allclose(freq[:, 1], 0.0)


def test_permutation_pvalues():
    X, y, _ = _problem(50, 12, seed=23, k=2, noise=0.3)
    res = permutation_pvalues(X, y, ResamplePlan(kind="permutation",
                                                 n_replicates=99, seed=3))
    assert res.pvalues.shape == (12,)
    assert ((0.0 < res.pvalues) & (res.pvalues <= 1.0)).all()
    assert res.null_max.shape == (99,)
    # planted predictors beat every permutation-null max-|gradient| draw
    assert (res.pvalues[:2] == 1.0 / 100.0).all()
    assert res.pvalues[2:].mean() > 0.2        # nulls are not small

    with pytest.raises(ValueError, match="permutation plan"):
        permutation_pvalues(X, y, ResamplePlan(kind="bootstrap"))


def test_bagged_slope():
    X, y, lam = _problem(40, 10, seed=29, k=2, noise=0.3)
    res = bagged_slope(X, y, lam,
                       ResamplePlan(kind="bootstrap", n_replicates=8, seed=4),
                       path_length=5, solver_tol=1e-8, max_iter=5000)
    L = len(res.replicates.sigmas)
    assert res.betas_mean.shape[:2] == (L, 10) or \
        res.betas_mean.shape[0] == L
    assert res.betas_sd.shape == res.betas_mean.shape
    assert (res.betas_sd >= 0.0).all()
    # bagged means still carry the planted signal
    dense = np.abs(res.betas_mean).reshape(L, -1)
    assert dense[-1, :2].min() > dense[-1, 2:].max()

    with pytest.raises(ValueError, match="bootstrap/subsample"):
        bagged_slope(X, y, lam, ResamplePlan(kind="permutation"))


def test_resample_stats_keys():
    X, y, lam = _problem(30, 8, seed=31)
    fit_replicates(X, y, lam, ResamplePlan(n_replicates=2, seed=1),
                   path_length=4, solver_tol=1e-8, max_iter=3000)
    st_ = resample_stats()
    assert set(st_) == {"replicates_in_flight", "replicates",
                        "selection_frequency", "null_calibration_draws"}
    assert st_["replicates_in_flight"] == 0     # nothing mid-flight
    assert st_["replicates"].get("bootstrap", 0) >= 2


# ---------------------------------------------------------------------------
# Served replicates (sync + async)
# ---------------------------------------------------------------------------

def _served_case(n=32, p=128, seed=41):
    # bucket-aligned shapes: the served program then runs at native size
    X, y, lam = _problem(n, p, seed=seed)
    sig = _sigmas(X, y, lam, L=5)
    rs = ResamplePlan(kind="bootstrap", n_replicates=5, seed=9)
    spec = PathSpec(lam=lam, sigmas=sig, early_stop=False, resample=rs)
    return X, y, lam, sig, rs, spec


def test_served_resample_sync():
    from repro.serve import PathService, ResampleResponse

    X, y, lam, sig, rs, spec = _served_case()
    svc = PathService(max_batch=4, max_delay=60.0)
    rid = svc.submit(problem=Problem(X, y), path=spec,
                     policy=SolverPolicy(**POL))
    resp = svc.poll(rid, flush=True)
    assert isinstance(resp, ResampleResponse)
    assert resp.n_replicates == 5
    assert resp.betas.shape[:2] == (5, len(sig))
    assert resp.weights.shape == (5, X.shape[0])
    assert resp.resample is rs
    assert len(resp.member_responses) == 5
    freq = resp.selection_frequencies()
    assert freq.shape == (len(sig), X.shape[1])

    direct = slope_path(Problem(X, y), spec,
                        SolverPolicy(backend="masked", **POL))
    # served members stack per-member y (vmap axis 0) where direct
    # broadcasts the shared vector — same math, different HLO, so
    # tight-tol rather than bitwise
    np.testing.assert_allclose(
        resp.betas.reshape(5, len(sig), -1),
        np.asarray(direct.betas).reshape(5, len(sig), -1), atol=1e-9)

    st_ = svc.stats()
    assert "resample" in st_
    assert st_["resample"]["replicates_in_flight"] == 0


def test_served_resample_async_future():
    from repro.serve import AsyncPathService, ResampleResponse

    X, y, lam, sig, rs, spec = _served_case(seed=43)
    svc = AsyncPathService(max_batch=4, max_delay=0.005)
    try:
        fut = svc.submit(problem=Problem(X, y), path=spec,
                         policy=SolverPolicy(**POL))
        resp = fut.result(timeout=300)
        assert isinstance(resp, ResampleResponse)
        assert resp.betas.shape[:2] == (5, len(sig))
        sync_direct = slope_path(Problem(X, y), spec,
                                 SolverPolicy(backend="masked", **POL))
        np.testing.assert_allclose(
            resp.betas.reshape(5, len(sig), -1),
            np.asarray(sync_direct.betas).reshape(5, len(sig), -1),
            atol=1e-9)
        assert "resample" in svc.stats()
    finally:
        svc.close()


def test_served_resample_internal_members_hidden():
    from repro.serve import PathService

    X, y, lam, sig, rs, spec = _served_case(seed=47)
    svc = PathService(max_batch=4, max_delay=60.0)
    before = svc.stats()["completed"]
    rid = svc.submit(problem=Problem(X, y), path=spec,
                     policy=SolverPolicy(**POL))
    resp = svc.poll(rid, flush=True)
    assert resp is not None
    # member fits are internal bookkeeping: unclaimed-response and
    # latency accounting must not leak B member entries to the client
    st_ = svc.stats()
    assert st_["unclaimed"] == 0
    assert st_["completed"] >= before + rs.n_replicates
